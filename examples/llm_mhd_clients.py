"""MHD on language-model clients (beyond-paper, DESIGN.md §7.4).

Two *different* reduced assigned architectures — a gemma3-style sliding-
window transformer and a mamba2 SSM — co-train as MHD clients on synthetic
text: private next-token CE on their own domains + confidence-gated
multi-head distillation on a public text pool. Demonstrates that the paper's
technique is architecture-agnostic (attention vs attention-free).

    PYTHONPATH=src python examples/llm_mhd_clients.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.lm_adapter import lm_mhd_loss, lm_mhd_outputs
from repro.core.mhd import MHDConfig
from repro.data import make_synthetic_text
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def main():
    steps, B, T, vocab = 120, 8, 32, 256
    # two clients with different architectures but a shared vocab/embed width
    cfg_a = dataclasses.replace(get_reduced("gemma3-12b"), vocab_size=vocab,
                                d_model=128, num_aux_heads=2)
    cfg_b = dataclasses.replace(get_reduced("mamba2-370m"), vocab_size=vocab,
                                d_model=128, num_aux_heads=2)
    bundles = [build_bundle(cfg_a), build_bundle(cfg_b)]
    names = [cfg_a.name, cfg_b.name]

    # private domains: different bigram languages; public pool: a third mix
    priv = [make_synthetic_text(1, 64, T, vocab, seed=s) for s in (0, 1)]
    pub = make_synthetic_text(2, 64, T, vocab, seed=2)

    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=2, delta=1)
    opt = make_optimizer(OptimizerConfig(init_lr=0.02, total_steps=steps,
                                         grad_clip_norm=1.0))
    params = [b.init(jax.random.PRNGKey(i)) for i, b in enumerate(bundles)]
    opt_states = [opt.init(p) for p in params]

    @jax.jit
    def teacher_fwd_a(p, tokens):
        o = lm_mhd_outputs(bundles[0], p, {"tokens": tokens})
        return {k: o[k] for k in ("embedding", "logits", "aux_logits")}

    @jax.jit
    def teacher_fwd_b(p, tokens):
        o = lm_mhd_outputs(bundles[1], p, {"tokens": tokens})
        return {k: o[k] for k in ("embedding", "logits", "aux_logits")}

    teacher_fwds = [teacher_fwd_a, teacher_fwd_b]

    def make_update(i):
        bundle = bundles[i]

        @jax.jit
        def update(p, s, priv_tokens, pub_tokens, teachers, step):
            (loss, metrics), g = jax.value_and_grad(
                lambda p_: lm_mhd_loss(bundle, p_, {"tokens": priv_tokens},
                                       {"tokens": pub_tokens}, teachers, mhd),
                has_aux=True)(p)
            p, s = opt.update(g, s, p, step)
            return p, s, loss

        return update

    updates = [make_update(i) for i in range(2)]
    rng = np.random.default_rng(0)
    for t in range(steps):
        pub_batch = jnp.asarray(
            pub.tokens[rng.integers(0, len(pub.tokens), B)])
        for i in range(2):
            j = 1 - i  # the other client is the teacher
            t_out = teacher_fwds[j](params[j], pub_batch)
            teachers = jax.tree.map(lambda x: x[None], t_out)
            priv_batch = jnp.asarray(
                priv[i].tokens[rng.integers(0, len(priv[i].tokens), B)])
            params[i], opt_states[i], loss = updates[i](
                params[i], opt_states[i], priv_batch, pub_batch, teachers,
                jnp.asarray(t))
        if t % 30 == 0:
            print(f"step {t:3d}  {names[0]} loss {float(loss):.3f}")

    # evaluate each client's next-token accuracy on the OTHER's domain
    # (this short demo shows the cross-architecture mechanics; meaningful
    # accuracies need far more steps — see benchmarks/ for measured runs)
    print("\ncross-domain next-token accuracy (aux2 head vs main head):")
    for i in range(2):
        other = priv[1 - i].tokens[:32]
        out = jax.jit(bundles[i].apply)(params[i],
                                        {"tokens": jnp.asarray(other)})
        labels = other[:, 1:]
        main_acc = float(np.mean(np.argmax(
            np.asarray(out["logits"][:, :-1]), -1) == labels))
        aux_acc = float(np.mean(np.argmax(
            np.asarray(out["aux_heads"][-1][:, :-1]), -1) == labels))
        print(f"  {names[i]:24s} main={main_acc:.3f}  last_aux={aux_acc:.3f}")


if __name__ == "__main__":
    main()
