"""Quickstart: 3 decentralized clients learn from each other with
Multi-Headed Distillation — no data, weights or gradients exchanged.

    PYTHONPATH=src python examples/quickstart.py

Takes ~2 minutes on CPU. Expected output: each client's MAIN head is good on
its private classes; the AUX heads approach the ensemble's knowledge of ALL
classes (β_sh well above what any isolated client can reach).

The whole experiment is one declarative `ExperimentSpec` — swap the
algorithm, topology, transport or schedule by editing the spec (see
docs/experiment_api.md); `spec.to_json()` is a complete, shareable record
of the run.
"""
import sys

sys.path.insert(0, "src")

from repro.exp import (
    AlgorithmSpec,
    DataSpec,
    Experiment,
    ExperimentSpec,
    OptimizerSpec,
    PartitionSpec,
    TrainSpec,
)


def main():
    K, labels, steps = 3, 12, 400

    spec = ExperimentSpec(
        name="quickstart",
        algorithm=AlgorithmSpec("mhd", {
            "nu_emb": 1.0, "nu_aux": 1.0, "delta": 1,
            "pool_size": K, "pool_update_every": 10}),
        # a labeled corpus, split into a public unlabeled pool + skewed shards
        data=DataSpec(num_labels=labels, samples_per_label=200, noise=2.0,
                      seed=0),
        partition=PartitionSpec(labels_per_client=4, assignment="random",
                                skew=100.0, gamma_pub=0.1),
        clients=ExperimentSpec.uniform_fleet(K, aux_heads=2),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=steps, batch_size=32, public_batch_size=32,
                        seed=0))

    def on_step(t, metrics):
        if t % 100 == 0:
            print(f"step {t:4d}  client-0 loss {metrics['c0/loss']:.3f}")

    ev = Experiment(spec).run(on_step=on_step).metrics

    print("\nfinal accuracies (ensemble means):")
    for head in ("main", "aux1", "aux2"):
        print(f"  {head:5s}  private β_priv={ev[f'mean/{head}/beta_priv']:.3f}"
              f"  shared β_sh={ev[f'mean/{head}/beta_sh']:.3f}")
    print("\nThe aux heads' β_sh should clearly beat the main head's — that "
          "is the knowledge the clients\nabsorbed from each other without "
          "sharing data or weights.")


if __name__ == "__main__":
    main()
