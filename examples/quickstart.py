"""Quickstart: 3 decentralized clients learn from each other with
Multi-Headed Distillation — no data, weights or gradients exchanged.

    PYTHONPATH=src python examples/quickstart.py

Takes ~2 minutes on CPU. Expected output: each client's MAIN head is good on
its private classes; the AUX heads approach the ensemble's knowledge of ALL
classes (β_sh well above what any isolated client can reach).
"""
import sys

sys.path.insert(0, "src")

from repro.core import (
    MHDConfig,
    DecentralizedTrainer,
    RunConfig,
    complete_graph,
)
from repro.data import PartitionConfig, make_synthetic_vision, partition_dataset
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def main():
    K, labels, steps = 3, 12, 400

    # a labeled corpus, split into a public unlabeled pool + skewed shards
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=200,
                               noise=2.0, seed=0)
    test = make_synthetic_vision(num_labels=labels, samples_per_label=15,
                                 noise=2.0, seed=991, prototype_seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=4,
        assignment="random", skew=100.0, gamma_pub=0.1, seed=0))

    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=2))
               for _ in range(K)]
    optimizer = make_optimizer(OptimizerConfig(
        init_lr=0.05, total_steps=steps, grad_clip_norm=1.0))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=2,
                    delta=1, pool_size=K, pool_update_every=10)

    trainer = DecentralizedTrainer(
        bundles, optimizer, mhd,
        RunConfig(steps=steps, batch_size=32, public_batch_size=32, seed=0),
        {"images": ds.images, "labels": ds.labels},
        part.client_indices, part.public_indices,
        complete_graph(K), labels)

    for t in range(steps):
        metrics = trainer.step(t)
        if t % 100 == 0:
            print(f"step {t:4d}  client-0 loss {metrics['c0/loss']:.3f}")

    ev = trainer.evaluate({"images": test.images, "labels": test.labels})
    print("\nfinal accuracies (ensemble means):")
    for head in ("main", "aux1", "aux2"):
        print(f"  {head:5s}  private β_priv={ev[f'mean/{head}/beta_priv']:.3f}"
              f"  shared β_sh={ev[f'mean/{head}/beta_sh']:.3f}")
    print("\nThe aux heads' β_sh should clearly beat the main head's — that "
          "is the knowledge the clients\nabsorbed from each other without "
          "sharing data or weights.")


if __name__ == "__main__":
    main()
