"""Gossip over a real (simulated) network: a 4-client directed ring
exchanging ONLY top-k predictions through `repro.comm` — with per-edge
latency, a bandwidth cap, 10% message loss, AND heterogeneous client
speeds driven by the async scheduler.

    PYTHONPATH=src python examples/comm_gossip.py

Clients 0-2 run at full speed; client 3 is a 4× slower straggler (think a
phone among servers). Nobody waits for it: each client publishes an
encoded window of top-5 predictions (f16 values, u16 indices, int8
embeddings) every S_P of its *own* local steps, and a bounded-staleness
gate (``max_staleness``) decides per teacher whether surviving mail is
still fresh enough to distill from — stale or lost mail degrades a step
to supervised-only instead of blocking. The straggler's uplink is also
4× slower on the simulated link (``client_rates``), so its neighbors see
old predictions both because it publishes rarely and because its bytes
crawl. Expected output: training proceeds despite drops and skew, the
staleness column shows the straggler's successor living further in the
past, and the metering ledger stays at kilobytes per edge per step.
"""
import sys

sys.path.insert(0, "src")

from repro.comm import CommConfig, SimulatedNetwork
from repro.core import (
    AsyncScheduler,
    MHDConfig,
    DecentralizedTrainer,
    RunConfig,
    ScheduleConfig,
    cycle_graph,
)
from repro.data import PartitionConfig, make_synthetic_vision, partition_dataset
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.common.pytree import tree_size


def main():
    K, labels, ticks, s_p = 4, 12, 200, 10
    rates = (1, 1, 1, 4)  # client 3 is the 4× straggler
    max_staleness = 3 * s_p

    ds = make_synthetic_vision(num_labels=labels, samples_per_label=200,
                               noise=2.0, seed=0)
    test = make_synthetic_vision(num_labels=labels, samples_per_label=15,
                                 noise=2.0, seed=991, prototype_seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=3,
        assignment="random", skew=100.0, gamma_pub=0.1, seed=0))

    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=2))
               for _ in range(K)]
    optimizer = make_optimizer(OptimizerConfig(
        init_lr=0.05, total_steps=ticks, grad_clip_norm=1.0))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=2,
                    delta=1, pool_size=2, pool_update_every=s_p)

    # a lossy, capped, laggy ring link: 1-tick propagation delay, 64 KiB of
    # bandwidth per wall tick, 10% of messages vanish — and the straggler's
    # uplink serializes 4× slower than everyone else's
    net = SimulatedNetwork(latency=1, bandwidth=64 * 1024, drop_prob=0.10,
                           seed=7, client_rates={3: rates[3]})
    trainer = DecentralizedTrainer(
        bundles, optimizer, mhd,
        RunConfig(steps=ticks, batch_size=32, public_batch_size=32, seed=0,
                  max_staleness=max_staleness),
        {"images": ds.images, "labels": ds.labels},
        part.client_indices, part.public_indices,
        cycle_graph(K), labels,
        exchange="prediction_topk",
        comm=CommConfig(topk=5, val_dtype="float16", emb_encoding="int8",
                        horizon=s_p * rates[3]),  # cover the straggler's gap
        transport=net)
    sched = AsyncScheduler(trainer, ScheduleConfig(rates))

    for t in range(ticks):
        metrics = sched.tick()
        if t % 50 == 0:
            stales = [metrics.get(f"c{i}/mail_staleness") for i in range(K)]
            shown = ["  -" if s is None else
                     ("new" if s < 0 else f"{s:3.0f}") for s in stales]
            print(f"tick {t:4d}  client-0 loss {metrics['c0/loss']:.3f}  "
                  f"mailbox staleness per client [{' '.join(shown)}] ticks")

    print(f"\nlocal steps taken: {sched.local_steps} "
          f"(rates {list(rates)}; nobody waited for client 3)")
    gs = trainer.meter.gate_summary()
    for cid in range(K):
        g = gs.get(cid, {"fresh": 0, "stale": 0, "stale_frac": 0.0})
        print(f"  client {cid}: {g['fresh']:.0f} fresh teachers, "
              f"{g['stale']:.0f} gated stale ({g['stale_frac']:.0%})")

    ev = trainer.evaluate({"images": test.images, "labels": test.labels})
    print("\nfinal accuracies (ensemble means):")
    for head in ("main", "aux1", "aux2"):
        print(f"  {head:5s}  private β_priv={ev[f'mean/{head}/beta_priv']:.3f}"
              f"  shared β_sh={ev[f'mean/{head}/beta_sh']:.3f}")

    print(f"\nnetwork: {net.sent_count} messages sent, "
          f"{net.dropped_count} dropped ({net.dropped_count/net.sent_count:.0%})")
    print("\nmetered traffic (predictions only — params stayed home):")
    print(trainer.meter.format_table())
    n_params = tree_size(trainer.clients[0].params)
    print(f"\nper-client inbound ≈ "
          f"{trainer.meter.total_bytes / K / ticks:,.0f} B/tick; one FedAvg "
          f"round of this model would be {2 * 4 * n_params:,} B per client.")


if __name__ == "__main__":
    main()
