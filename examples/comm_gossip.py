"""Gossip over a real (simulated) network: a 4-client directed ring
exchanging ONLY top-k predictions through `repro.comm` — with per-edge
latency, a bandwidth cap, 10% message loss, AND heterogeneous client
speeds driven by the async scheduler.

    PYTHONPATH=src python examples/comm_gossip.py

Clients 0-2 run at full speed; client 3 is a 4× slower straggler (think a
phone among servers). Nobody waits for it: each client publishes an
encoded window of top-5 predictions (f16 values, u16 indices, int8
embeddings) every S_P of its *own* local steps, and a bounded-staleness
gate (``max_staleness``) decides per teacher whether surviving mail is
still fresh enough to distill from — stale or lost mail degrades a step
to supervised-only instead of blocking. The straggler's uplink is also
4× slower on the simulated link (``client_rates``), so its neighbors see
old predictions both because it publishes rarely and because its bytes
crawl. Expected output: training proceeds despite drops and skew, the
staleness column shows the straggler's successor living further in the
past, and the metering ledger stays at kilobytes per edge per step.

The entire scenario — ring topology, async rates, lossy transport, top-k
wire format, staleness gate — is the declarative ``"gossip"`` preset
(`repro.exp.presets`); this script only adds the progress printing and
the post-run drill-downs, which ride out-of-band on the result.
"""
import sys

sys.path.insert(0, "src")

from repro.common.pytree import tree_size
from repro.exp import Experiment, get_preset


def main():
    spec = get_preset("gossip")
    K, ticks = spec.num_clients, spec.train.steps
    rates = spec.schedule.rates

    def on_step(t, metrics):
        if t % 50 == 0:
            stales = [metrics.get(f"c{i}/mail_staleness") for i in range(K)]
            shown = ["  -" if s is None else
                     ("new" if s < 0 else f"{s:3.0f}") for s in stales]
            print(f"tick {t:4d}  client-0 loss {metrics['c0/loss']:.3f}  "
                  f"mailbox staleness per client [{' '.join(shown)}] ticks")

    result = Experiment(spec).run(on_step=on_step)
    trainer, sched, net = result.trainer, result.scheduler, result.transport

    print(f"\nlocal steps taken: {sched.local_steps} "
          f"(rates {list(rates)}; nobody waited for client 3)")
    gs = trainer.meter.gate_summary()
    for cid in range(K):
        g = gs.get(cid, {"fresh": 0, "stale": 0, "stale_frac": 0.0})
        print(f"  client {cid}: {g['fresh']:.0f} fresh teachers, "
              f"{g['stale']:.0f} gated stale ({g['stale_frac']:.0%})")

    ev = result.metrics
    print("\nfinal accuracies (ensemble means):")
    for head in ("main", "aux1", "aux2"):
        print(f"  {head:5s}  private β_priv={ev[f'mean/{head}/beta_priv']:.3f}"
              f"  shared β_sh={ev[f'mean/{head}/beta_sh']:.3f}")

    print(f"\nnetwork: {net.sent_count} messages sent, "
          f"{net.dropped_count} dropped ({net.dropped_count/net.sent_count:.0%})")
    print("\nmetered traffic (predictions only — params stayed home):")
    print(trainer.meter.format_table())
    n_params = tree_size(trainer.clients[0].params)
    # inbound = *delivered* bytes: a dropped message costs the sender
    # (offered) but never the student
    print(f"\nper-client inbound ≈ "
          f"{ev['comm/delivered_bytes'] / K / ticks:,.0f} B/tick (of "
          f"{ev['comm/total_bytes'] / K / ticks:,.0f} offered); one FedAvg "
          f"round of this model would be {2 * 4 * n_params:,} B per client.")


if __name__ == "__main__":
    main()
