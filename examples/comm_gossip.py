"""Gossip over a real (simulated) network: a 4-client directed ring
exchanging ONLY top-k predictions through `repro.comm` — with per-edge
latency, a bandwidth cap, and 10% message loss.

    PYTHONPATH=src python examples/comm_gossip.py

Every S_P steps each client publishes an encoded window of top-5
predictions (f16 values, u16 indices, int8 embeddings) on upcoming public
batches; its ring successor decodes whatever survives the link. Params
never cross the wire. Expected output: training proceeds despite drops
(clients fall back to supervised-only steps while their mailbox is stale),
and the metering ledger shows per-edge traffic of a few kilobytes per
step — versus megabytes for shipping the ResNet itself every round.
"""
import sys

sys.path.insert(0, "src")

from repro.comm import CommConfig, SimulatedNetwork
from repro.core import (
    MHDConfig,
    DecentralizedTrainer,
    RunConfig,
    cycle_graph,
)
from repro.data import PartitionConfig, make_synthetic_vision, partition_dataset
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.common.pytree import tree_size


def main():
    K, labels, steps, s_p = 4, 12, 200, 10

    ds = make_synthetic_vision(num_labels=labels, samples_per_label=200,
                               noise=2.0, seed=0)
    test = make_synthetic_vision(num_labels=labels, samples_per_label=15,
                                 noise=2.0, seed=991, prototype_seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=3,
        assignment="random", skew=100.0, gamma_pub=0.1, seed=0))

    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=2))
               for _ in range(K)]
    optimizer = make_optimizer(OptimizerConfig(
        init_lr=0.05, total_steps=steps, grad_clip_norm=1.0))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=2,
                    delta=1, pool_size=2, pool_update_every=s_p)

    # a lossy, capped, laggy ring link: 1-step propagation delay, 64 KiB
    # of bandwidth per training step, 10% of messages vanish
    net = SimulatedNetwork(latency=1, bandwidth=64 * 1024, drop_prob=0.10,
                           seed=7)
    trainer = DecentralizedTrainer(
        bundles, optimizer, mhd,
        RunConfig(steps=steps, batch_size=32, public_batch_size=32, seed=0),
        {"images": ds.images, "labels": ds.labels},
        part.client_indices, part.public_indices,
        cycle_graph(K), labels,
        exchange="prediction_topk",
        comm=CommConfig(topk=5, val_dtype="float16", emb_encoding="int8",
                        horizon=s_p),
        transport=net)

    for t in range(steps):
        metrics = trainer.step(t)
        if t % 50 == 0:
            stale = sum(metrics[f"c{i}/mail_staleness"]
                        for i in range(K)) / K
            print(f"step {t:4d}  client-0 loss {metrics['c0/loss']:.3f}  "
                  f"mean mailbox staleness {stale:.1f} steps")

    ev = trainer.evaluate({"images": test.images, "labels": test.labels})
    print("\nfinal accuracies (ensemble means):")
    for head in ("main", "aux1", "aux2"):
        print(f"  {head:5s}  private β_priv={ev[f'mean/{head}/beta_priv']:.3f}"
              f"  shared β_sh={ev[f'mean/{head}/beta_sh']:.3f}")

    print(f"\nnetwork: {net.sent_count} messages sent, "
          f"{net.dropped_count} dropped ({net.dropped_count/net.sent_count:.0%})")
    print("\nmetered traffic (predictions only — params stayed home):")
    print(trainer.meter.format_table())
    n_params = tree_size(trainer.clients[0].params)
    print(f"\nper-client inbound ≈ "
          f"{trainer.meter.total_bytes / K / steps:,.0f} B/step; one FedAvg "
          f"round of this model would be {2 * 4 * n_params:,} B per client.")


if __name__ == "__main__":
    main()
