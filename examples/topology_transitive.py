"""Transitive distillation across a ring (paper §4.4, Figs. 5-6).

Four clients in a directed cycle — client 0 can only *directly* learn from
client 1, yet information from clients 2 and 3 reaches it through the chain
of auxiliary heads. We print each head's accuracy on the primary labels of
clients at 1, 2 and 3 hops.

    PYTHONPATH=src python examples/topology_transitive.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import MHDConfig, DecentralizedTrainer, RunConfig, cycle_graph
from repro.core.graph import graph_distance_matrix
from repro.core.supervised import eval_per_label_accuracy
from repro.data import PartitionConfig, make_synthetic_vision, partition_dataset
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def main():
    K, labels, steps, m = 4, 16, 500, 3
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=200,
                               noise=2.0, seed=0)
    test = make_synthetic_vision(num_labels=labels, samples_per_label=15,
                                 noise=2.0, seed=991, prototype_seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=4,
        skew=1000.0, gamma_pub=0.1, seed=0))
    graph = cycle_graph(K)

    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=m))
               for _ in range(K)]
    trainer = DecentralizedTrainer(
        bundles,
        make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=steps,
                                       grad_clip_norm=1.0)),
        MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=m, delta=1,
                  pool_size=K, pool_update_every=10),
        RunConfig(steps=steps, batch_size=32, public_batch_size=32, seed=0),
        {"images": ds.images, "labels": ds.labels},
        part.client_indices, part.public_indices, graph, labels)

    for t in range(steps):
        trainer.step(t)

    test_arrays = {"images": test.images, "labels": test.labels}
    dist = graph_distance_matrix(graph)
    heads = ["main"] + [f"aux{h+1}" for h in range(m)]
    print(f"{'head':6s} " + "  ".join(f"hop-{h}" for h in (1, 2, 3)))
    for head in heads:
        by_hop = {1: [], 2: [], 3: []}
        for i, c in enumerate(trainer.clients):
            per_label, _ = eval_per_label_accuracy(
                c.bundle, c.params, test_arrays, labels, head=head)
            for j in range(K):
                if i == j:
                    continue
                by_hop[int(dist[i, j])].append(
                    per_label[part.primary_labels[j]].mean())
        print(f"{head:6s} " + "  ".join(
            f"{np.mean(by_hop[h]):.3f}" for h in (1, 2, 3)))
    print("\nLater aux heads should hold up better at 2-3 hops — knowledge "
          "arriving through intermediaries\n(the paper's transitive "
          "distillation).")


if __name__ == "__main__":
    main()
