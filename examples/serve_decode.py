"""Batched serving example: prefill + greedy decode with per-family caches
(sliding-window ring buffers for gemma3, SSM state for mamba2).

    PYTHONPATH=src python examples/serve_decode.py [arch]
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.zoo import build_bundle


def main(arch: str = "gemma3-12b"):
    cfg = get_reduced(arch)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    B, prompt_len, gen = 4, 24, 16
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (B, prompt_len), dtype=np.int32))
    caches = bundle.init_cache(B, prompt_len + gen, jnp.float32)
    step = jax.jit(bundle.decode_step)

    t0 = time.time()
    logits = None
    for t in range(prompt_len):  # cache warmup (prefill)
        logits, caches = step(params, prompts[:, t:t + 1], caches)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    generated = []
    for _ in range(gen):
        generated.append(np.asarray(tok)[:, 0])
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    dt = time.time() - t0
    gen_tokens = np.stack(generated, 1)
    print(f"{cfg.name}: {B} requests, {prompt_len}+{gen} tokens "
          f"in {dt:.2f}s ({B*(prompt_len+gen)/dt:.0f} tok/s on CPU)")
    for b in range(2):
        print(f"  request {b}: {gen_tokens[b][:10].tolist()}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "gemma3-12b")
