#!/usr/bin/env python
"""Phase-attribution report for a (merged) Chrome trace.

    PYTHONPATH=src python scripts/trace_report.py artifacts/trace/trace_merged.json
    PYTHONPATH=src python scripts/trace_report.py trace_r0.json --top 5

Loads a trace written by `repro.obs` (a gossip child's per-rank file or
the launcher's merged fleet timeline) and prints:

  * one row per rank: wall-clock extent and seconds attributed to each
    phase (distill / encode / wire / drain-wait / barrier / setup /
    other / idle). Self-times — nested spans never double-count — so
    each row sums exactly to its wall column;
  * the top-N *stall* spans (drain waits, connect retries, barriers) —
    the individual waits that ate the timeline;
  * flow-event coverage: how many send→delivery pairs matched across
    tracks (a merged multi-process trace should pair nearly all of them).

The same trace loads in Perfetto (https://ui.perfetto.dev) for the
zoomable view; this report is the terminal summary.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def print_report(data, top: int = 10) -> None:
    from repro.obs.metrics import (PHASE_ORDER, flow_coverage,
                                   phase_attribution, stall_attribution,
                                   stall_spans)

    events = data.get("traceEvents", [])
    phases = phase_attribution(events)
    # a gossip trace has no serve phases and a serve trace no gossip
    # phases — show only the columns with any time, so neither report
    # widens past a terminal (idle always prints: its absence is a bug)
    cols = ["wall"] + [
        c for c in PHASE_ORDER
        if c == "idle" or any(row.get(c, 0.0) > 0.0
                              for row in phases.values())]
    hdr = "rank  " + "".join(f"{c:>11}" for c in cols)
    print(hdr)
    print("-" * len(hdr))
    for pid in sorted(phases):
        row = phases[pid]
        print(f"{pid:>4}  " + "".join(f"{row.get(c, 0.0):>11.3f}"
                                      for c in cols))
    print("(seconds; phases + idle sum to wall — self-times, nested "
          "spans never double-count)")

    stalls = stall_spans(events, top=top)
    if stalls:
        print(f"\ntop {len(stalls)} stall spans:")
        for s in stalls:
            args = " ".join(f"{k}={v}" for k, v in sorted(s["args"].items()))
            print(f"  rank {s['rank']}: {s['name']:<22} "
                  f"{s['dur_s']:>8.3f}s at t={s['start_s']:.3f}s"
                  f"{'  ' + args if args else ''}")

    # scheduler stall attribution: every wait, grouped by span x the op
    # (or reason) it was gating — which op class paid the scoreboard's
    # waiting, not just the longest individual spans above
    by_op = stall_attribution(events)
    if by_op:
        print("\nscheduler stall attribution (all spans, by op):")
        print(f"  {'span':<22}{'op':<14}{'count':>7}{'total_s':>10}"
              f"{'max_s':>9}")
        for row in by_op:
            print(f"  {row['name']:<22}{row['op']:<14}"
                  f"{row['count']:>7.0f}{row['total_s']:>10.3f}"
                  f"{row['max_s']:>9.3f}")

    cov = flow_coverage(events)
    if cov["flow_starts"] or cov["flow_ends"]:
        frac = (cov["flow_pairs"] / cov["flow_starts"]
                if cov["flow_starts"] else 0.0)
        print(f"\nflow events: {cov['flow_pairs']:.0f} matched "
              f"send→delivery pairs / {cov['flow_starts']:.0f} sends "
              f"({frac:.0%})")

    od = data.get("otherData", {})
    per_rank = od.get("per_rank", {})
    dropped = sum(r.get("stats", {}).get("dropped", 0.0)
                  for r in per_rank.values()) or \
        od.get("stats", {}).get("dropped", 0.0)
    if dropped:
        print(f"\nWARNING: {dropped:.0f} events dropped by ring buffers — "
              "phase sums undercount; raise the tracer capacity")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("trace", help="trace JSON (per-rank or merged)")
    p.add_argument("--top", type=int, default=10,
                   help="how many stall spans to list (default 10)")
    args = p.parse_args(argv)

    from repro.obs import load_trace

    print_report(load_trace(args.trace), top=args.top)
    return 0


if __name__ == "__main__":
    sys.exit(main())
