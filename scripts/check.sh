#!/usr/bin/env bash
# Tier-1 check, deterministic and offline: CPU-only jax, no network, no TPU.
#
#   scripts/check.sh           # fast tier (skips tests marked slow)
#   scripts/check.sh --full    # everything, including slow tier
#
# The fast tier includes the async-scheduler suite (tests/test_scheduler.py:
# lockstep equivalence + staleness gating) — those tests are sized to stay
# in the slow-excluded tier; do not mark them slow without moving the
# bitwise-equivalence acceptance elsewhere.
#
# Extra args after the mode flag are passed straight to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
    MARK=()
    shift
fi

# the scheduler suite is the async-runtime acceptance gate: fail loudly if
# a refactor ever empties it out of the fast tier (and show pytest's own
# output when collection itself breaks — import errors must stay visible)
collected=$(python -m pytest -q --collect-only -m "not slow" \
    tests/test_scheduler.py 2>&1) || {
    printf '%s\n' "$collected" >&2
    echo "check.sh: collecting tests/test_scheduler.py failed" >&2
    exit 1
}
if ! grep -q "test_async_equals_sync" <<<"$collected"; then
    printf '%s\n' "$collected" >&2
    echo "check.sh: async equivalence tests missing from the fast tier" >&2
    exit 1
fi

# experiment-API smoke: spec parsing, JSON round-trip, and algorithm/arch
# registry wiring must hold on every push (no training — this is seconds)
python scripts/run_experiment.py --preset quick --dry-run >/dev/null || {
    echo "check.sh: experiment spec dry-run failed" >&2
    exit 1
}

# socket-transport smoke: 2 OS processes gossiping over real TCP. The hard
# `timeout` guarantees a hung socket can never wedge the fast tier; the
# script itself fails if a client never distilled, if delivered > offered,
# or if any edge delivered less than it offered (localhost loses nothing —
# the finish barrier must drain every in-flight frame).
# Tracing is on (repro.obs): the script also asserts the merged Chrome
# trace parses, every rank contributed distill spans, the cross-process
# flow events pair up, and the traced drain_wait + barrier phases stay
# under 25% of wall — artifacts/trace_smoke/ is the CI artifact a red run
# ships for post-mortem.
rm -rf artifacts/trace_smoke
timeout 150 python scripts/run_gossip_procs.py --smoke \
    --trace-dir artifacts/trace_smoke >/dev/null || {
    echo "check.sh: 2-process socket gossip smoke failed" >&2
    exit 1
}

# elastic-fleet smoke: 3 processes, rank 1 crashed mid-run (os._exit) —
# the crash must be reaped promptly with the rank named, and the resumed
# fleet must restore rank 1 from its own snapshot and distill again
# post-restore (repro.fleet; docs/elastic_fleets.md). ~45s uncontended;
# the smoke's own 50s-per-launch timeouts are the real budget, the
# wrapper is headroom against a loaded machine (a flaky gate is worse)
timeout 120 python scripts/run_gossip_procs.py --churn-smoke >/dev/null || {
    echo "check.sh: 3-process kill-and-restore smoke failed" >&2
    exit 1
}

# scoreboard smoke: 3 processes with schedule.mode="scoreboard" and one
# heavily throttled straggler (launch/gossip.py GossipPacer). Lock-step
# would drag every rank to the straggler's wall; the script fails unless
# the fast ranks' step loops finish < 0.5x the straggler's wall and
# delivery stays lossless edge-by-edge. ~50s: one warm + a ~40s launch
# dominated by the straggler's 16 x 2s pacing.
timeout 180 python scripts/run_gossip_procs.py --scoreboard-smoke \
    >/dev/null || {
    echo "check.sh: 3-process scoreboard straggler smoke failed" >&2
    exit 1
}

# LM fleet smoke: 3 processes, three *different* architectures (ssm /
# dense transformer / moe) distilling next-token predictions over TCP
# on the entropy-adaptive, delta-compressed wire (repro.lm;
# docs/lm_distillation.md). Fails unless every client distilled,
# delivery was lossless edge-by-edge, and the measured mean frame
# stayed inside the bytes/token budget's shape-computed ceiling.
timeout 300 python scripts/run_gossip_procs.py --lm-smoke >/dev/null || {
    echo "check.sh: 3-process heterogeneous LM smoke failed" >&2
    exit 1
}

# serve smoke: the bounded serve→distill loop (repro.serve) — train a
# tiny fleet, snapshot it, serve 8 mixed requests plus generations
# through the continuous-batching engine, then distill one step from the
# served traffic. Asserts every request completes, the teacher cache
# hits on repeated windows, and the feedback step moved metered bytes.
timeout 240 python -m benchmarks.serve --smoke >/dev/null || {
    echo "check.sh: serve smoke failed" >&2
    exit 1
}

exec python -m pytest -x -q "${MARK[@]}" "$@"
