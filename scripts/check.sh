#!/usr/bin/env bash
# Tier-1 check, deterministic and offline: CPU-only jax, no network, no TPU.
#
#   scripts/check.sh           # fast tier (skips tests marked slow)
#   scripts/check.sh --full    # everything, including slow tier
#
# Extra args after the mode flag are passed straight to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
    MARK=()
    shift
fi

exec python -m pytest -x -q "${MARK[@]}" "$@"
