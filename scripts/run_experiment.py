#!/usr/bin/env python
"""Run one declarative experiment: spec file (or preset) in, metrics out.

    PYTHONPATH=src python scripts/run_experiment.py --preset quick
    PYTHONPATH=src python scripts/run_experiment.py --spec my_exp.json \
        --out metrics.json
    PYTHONPATH=src python scripts/run_experiment.py --preset gossip \
        --save-spec gossip.json          # write the spec, don't run
    PYTHONPATH=src python scripts/run_experiment.py --preset quick --dry-run

``--dry-run`` exercises the whole declarative surface without training:
spec JSON round-trip, algorithm/arch registry resolution, capability
checks, graph/transport/optimizer construction. CI runs it on every push
(scripts/check.sh) so a spec-schema or registry regression fails fast.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def load_spec(args):
    from repro.exp import ExperimentSpec
    from repro.exp.presets import get_preset

    if args.spec:
        with open(args.spec) as f:
            return ExperimentSpec.from_json(f.read())
    return get_preset(args.preset)


def dry_run(spec) -> int:
    """Validate everything constructible without touching data or jit."""
    from repro.exp import (ExperimentSpec, Experiment, build_bundles,
                           build_graph, build_optimizer, build_transport,
                           make_algorithm)

    roundtrip = ExperimentSpec.from_json(spec.to_json())
    assert roundtrip == spec, "spec JSON round-trip changed the spec"
    spec.validate()
    algo = make_algorithm(spec)
    Experiment(spec)._check_capabilities(algo)
    bundles = build_bundles(spec)
    graph = build_graph(spec)
    build_optimizer(spec)
    transport = build_transport(spec)  # built last: a socket kind binds
    if transport is not None:          # real listeners — release them now
        transport.close()
    print(f"spec OK: {spec.name}")
    print(f"  algorithm: {spec.algorithm.name} "
          f"(capabilities: {algo.capabilities})")
    print(f"  fleet: {len(bundles)} clients "
          f"[{', '.join(b.name for b in bundles)}]")
    print(f"  topology: {spec.topology.name} ({sum(map(len, graph))} edges)"
          f"  schedule: {spec.schedule.mode}")
    print(f"  wire: {spec.wire.exchange}  transport: "
          f"{type(transport).__name__ if transport else 'loopback'}")
    print(f"  train: {spec.train.steps} steps × batch "
          f"{spec.train.batch_size}")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    src = p.add_mutually_exclusive_group(required=True)
    src.add_argument("--spec", help="path to an ExperimentSpec JSON file")
    src.add_argument("--preset", help="named preset (see --list-presets)")
    src.add_argument("--list-presets", action="store_true")
    p.add_argument("--dry-run", action="store_true",
                   help="parse/validate/wire only; no training")
    p.add_argument("--save-spec", metavar="PATH",
                   help="write the resolved spec JSON and exit")
    p.add_argument("--out", metavar="PATH",
                   help="write result payload (spec+metrics+history) JSON")
    p.add_argument("--log-every", type=int, default=100,
                   help="print a loss line every N steps (0 = quiet)")
    args = p.parse_args(argv)

    if args.list_presets:
        from repro.exp.presets import preset_names

        for name in preset_names():
            print(name)
        return 0

    spec = load_spec(args)
    if args.save_spec:
        with open(args.save_spec, "w") as f:
            f.write(spec.to_json() + "\n")
        print(f"wrote {args.save_spec}")
        return 0
    if args.dry_run:
        return dry_run(spec)

    from repro.exp import Experiment

    def on_step(t, metrics):
        if args.log_every and t % args.log_every == 0 and metrics:
            losses = [v for k, v in metrics.items() if k.endswith("/loss")]
            if losses:
                print(f"step {t}: mean client loss "
                      f"{sum(losses) / len(losses):.4f}")

    result = Experiment(spec).run(on_step=on_step)
    print(f"\n{spec.name}: {spec.train.steps} steps, "
          f"{result.us_per_step:.0f} us/step")
    for k in sorted(result.metrics):
        if k.startswith("mean/") or k.startswith("comm/"):
            print(f"  {k} = {result.metrics[k]:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            f.write(result.to_json() + "\n")
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
