#!/usr/bin/env python
"""Run a socket-transport gossip experiment as one OS process per client.

    PYTHONPATH=src python scripts/run_gossip_procs.py               # 4-proc ring
    PYTHONPATH=src python scripts/run_gossip_procs.py --preset gossip_socket \
        --steps 20 --throttle 3:50 --out gossip.json
    PYTHONPATH=src python scripts/run_gossip_procs.py --smoke       # CI: 2 procs

Each client is a real OS process with its own `SocketTransport` listener,
gossiping top-k prediction windows over localhost TCP (`launch/gossip.py`).
``--throttle RANK:MS`` sleeps MS milliseconds after each of that rank's
local steps — a genuine wall-clock straggler, not a simulated one.

``--smoke`` is the bounded CI configuration: 2 clients, 8 steps, hard
60-second internal timeout. The script exits non-zero if any client
finishes without ever distilling from a neighbor, or if the fleet's
delivered bytes exceed its offered bytes (the meter invariant).

``--scoreboard-smoke`` is the out-of-order scheduling CI configuration:
a 3-process ring with ``schedule.mode="scoreboard"`` and one heavily
throttled wall-clock straggler. Lock-step would drag every rank down
to the straggler's wall clock; the smoke exits non-zero unless the
fast ranks finish in well under that bound (< 0.5× the straggler's
step-loop wall) and localhost delivery is lossless (delivered ==
offered on every edge).

``--churn-smoke`` is the elastic-fleet CI configuration (repro.fleet):
a 3-process ring with per-rank fleet snapshots and
``init_scheme="per_client"`` where rank 1 is crashed mid-run
(``os._exit``). Phase 1 must fail *promptly* with rank 1's exit status
(fast fleet reaping, not the hard-timeout backstop); phase 2 relaunches
with ``resume=True`` — every rank restores its own snapshot slice — and
must exit non-zero if the restored client never distills post-restore
or delivered bytes exceed offered.

``--lm-smoke`` is the heterogeneous-LM CI configuration (repro.lm): the
``lm_hetero`` preset's 3-process mixed-architecture fleet — an SSM, a
dense transformer and a small MoE — exchanging next-token predictions
over TCP on the entropy-adaptive, delta-compressed wire. Exits non-zero
unless every client distills from a neighbor, localhost delivery is
lossless (delivered == offered per edge), and the measured mean frame
size stays inside the budget's shape-computed ceiling
(`repro.lm.adaptive_frame_max_nbytes`) — the bytes/token budget holds
on the real wire, not just in the codec's unit tests.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def parse_throttle(items):
    out = {}
    for item in items or ():
        rank, _, ms = item.partition(":")
        out[int(rank)] = float(ms)
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--preset", default="gossip_socket")
    p.add_argument("--spec", help="ExperimentSpec JSON file (overrides "
                   "--preset; must use transport kind 'socket')")
    p.add_argument("--steps", type=int, help="override train.steps")
    p.add_argument("--clients", type=int,
                   help="override fleet size (uniform fleet)")
    p.add_argument("--throttle", action="append", metavar="RANK:MS",
                   help="sleep MS ms after each local step of RANK "
                        "(repeatable) — a real wall-clock straggler")
    p.add_argument("--timeout", type=float, default=300.0,
                   help="hard cap on the whole run (seconds)")
    p.add_argument("--smoke", action="store_true",
                   help="bounded CI config: 2 clients, 8 steps, 60s cap")
    p.add_argument("--churn-smoke", action="store_true",
                   help="bounded CI config: 3-process kill-and-restore "
                        "(crash rank 1, resume the fleet from snapshots)")
    p.add_argument("--scoreboard-smoke", action="store_true",
                   help="bounded CI config: 3-process scoreboard run with "
                        "a 4x-paced straggler; fast ranks must beat the "
                        "lock-step bound")
    p.add_argument("--lm-smoke", action="store_true",
                   help="bounded CI config: 3-process mixed-arch LM fleet "
                        "(ssm/transformer/moe) on the entropy-adaptive "
                        "compressed wire; asserts bytes/token <= budget")
    p.add_argument("--out", metavar="PATH",
                   help="write per-rank results + fleet summary JSON")
    p.add_argument("--trace-dir", metavar="DIR",
                   help="enable repro.obs tracing: per-rank Chrome traces "
                        "+ a merged fleet timeline under DIR, validated "
                        "after the run (merged file parses, every rank "
                        "contributed distill spans, flow coverage)")
    args = p.parse_args(argv)

    from repro.exp import ExperimentSpec, get_preset
    from repro.launch.gossip import fleet_summary, launch_gossip

    if args.churn_smoke:
        return churn_smoke()
    if args.scoreboard_smoke:
        return scoreboard_smoke()
    if args.lm_smoke:
        return lm_smoke()

    if args.spec:
        with open(args.spec) as f:
            spec = ExperimentSpec.from_json(f.read())
    else:
        spec = get_preset(args.preset)
    timeout = args.timeout
    if args.smoke:
        args.clients, args.steps, timeout = 2, 8, 55.0
    if args.clients:
        spec = dataclasses.replace(
            spec, clients=ExperimentSpec.uniform_fleet(
                args.clients, arch=spec.clients[0].arch,
                aux_heads=spec.clients[0].aux_heads,
                width=spec.clients[0].width))
    if args.steps:
        spec = dataclasses.replace(
            spec, train=dataclasses.replace(spec.train, steps=args.steps))
    if args.trace_dir:
        spec = dataclasses.replace(
            spec, train=dataclasses.replace(spec.train,
                                            trace_dir=args.trace_dir))

    if args.smoke:
        # cold CI containers would pay the full per-child jit compile
        # inside the launch timeout; warm the shared persistent cache
        # in-process first so the children load instead of compiling
        os.environ.setdefault(
            "JAX_COMPILATION_CACHE_DIR",
            os.path.join(tempfile.gettempdir(), "repro_jit_cache"))
        _warm_jit_cache(spec)

    K = spec.num_clients
    print(f"{spec.name}: {K} clients as {K} OS processes over TCP, "
          f"{spec.train.steps} local steps each (timeout {timeout:.0f}s)")
    results = launch_gossip(spec, timeout=timeout,
                            throttle_ms=parse_throttle(args.throttle))
    fleet = fleet_summary(results)

    for rank in sorted(results):
        r = results[rank]
        print(f"  client {rank}: {r['steps']} steps in "
              f"{r['wall_seconds']:.1f}s, loss {r['final_loss']:.3f}, "
              f"distilled on {r['distill_steps']}/{r['steps']} steps, "
              f"rx {r['delivered_bytes']:,.0f} B / tx "
              f"{r['offered_bytes']:,.0f} B")
    print(f"fleet: offered {fleet['offered_bytes']:,.0f} B, delivered "
          f"{fleet['delivered_bytes']:,.0f} B, "
          f"{fleet['distill_steps_total']:.0f} distillation steps, "
          f"{fleet['failed_sends']:.0f} failed sends")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"spec": spec.to_dict(),
                       "results": {str(k): v for k, v in results.items()},
                       "fleet": fleet}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    ok = True
    if fleet["delivered_bytes"] > fleet["offered_bytes"]:
        print("FAIL: delivered bytes exceed offered bytes", file=sys.stderr)
        ok = False
    # localhost loses nothing: every edge must deliver exactly what was
    # offered (the finish barrier drains all in-flight frames). Skipped
    # only when the transport *metered* a real loss (failed sends /
    # tombstoned mail) — then delivered < offered is the truth, not a bug.
    if fleet["failed_sends"] == 0 and \
            not any(r.get("tombstoned_bytes", 0) for r in results.values()):
        from repro.launch.gossip import delivery_gaps

        gaps = delivery_gaps(results)
        if gaps:
            print("FAIL: delivered != offered on lossless localhost: "
                  + "; ".join(f"edge {e}: {d}/{o} B"
                              for e, (o, d) in sorted(gaps.items())),
                  file=sys.stderr)
            ok = False
        else:
            print("delivery ok: delivered == offered on every edge")
    if fleet["distill_steps_min"] < 1:
        print("FAIL: a client never distilled from a neighbor",
              file=sys.stderr)
        ok = False
    if args.trace_dir and not check_trace(args.trace_dir, K, fleet):
        ok = False
    return 0 if ok else 1


def check_trace(trace_dir: str, num_ranks: int, fleet) -> bool:
    """Validate the merged fleet trace a traced gossip run must produce:
    it parses as Chrome trace JSON, every rank's track carries at least
    one distill span, and the cross-process flow events pair up for the
    bulk of delivered frames."""
    from repro.obs import load_trace
    from repro.obs.metrics import flow_coverage

    merged = os.path.join(trace_dir, "trace_merged.json")
    if not os.path.exists(merged):
        print(f"FAIL: traced run produced no {merged}", file=sys.stderr)
        return False
    try:
        data = load_trace(merged)
        events = data["traceEvents"]
    except (ValueError, KeyError) as e:
        print(f"FAIL: merged trace unreadable: {e}", file=sys.stderr)
        return False
    distill_ranks = {ev["pid"] for ev in events
                     if ev["ph"] == "X" and ev["name"] == "runtime/distill"}
    ok = True
    missing = sorted(set(range(num_ranks)) - distill_ranks)
    if missing:
        print(f"FAIL: ranks {missing} contributed no distill span to the "
              f"merged trace", file=sys.stderr)
        ok = False
    cov = flow_coverage(events)
    delivered = fleet["delivered_messages"]
    if delivered and cov["flow_pairs"] < 0.9 * delivered:
        print(f"FAIL: only {cov['flow_pairs']:.0f} send→delivery flow "
              f"pairs for {delivered:.0f} delivered frames (<90%)",
              file=sys.stderr)
        ok = False
    # waiting is not working: with compute/comm overlap and the
    # count-based finish barrier, drain_wait + barrier must stay a small
    # slice of the fleet's traced wall time (aggregated across ranks so
    # one rank's scheduling hiccup can't flake CI)
    from repro.obs.metrics import phase_attribution

    phases = phase_attribution(events)
    wall = sum(r["wall"] for r in phases.values())
    waiting = sum(r["drain_wait"] + r["barrier"] for r in phases.values())
    if wall and waiting > 0.25 * wall:
        print(f"FAIL: drain_wait + barrier = {waiting:.1f}s of "
              f"{wall:.1f}s traced wall ({waiting / wall:.0%} > 25%) — "
              f"the fleet is waiting, not working", file=sys.stderr)
        ok = False
    if ok:
        print(f"trace ok: {merged} — {len(events)} events, "
              f"{len(distill_ranks)} ranks with distill spans, "
              f"{cov['flow_pairs']:.0f}/{delivered:.0f} flow pairs, "
              f"drain_wait+barrier {waiting:.1f}s/{wall:.1f}s "
              f"({(waiting / wall if wall else 0.0):.0%})")
    return ok


def _warm_jit_cache(spec) -> None:
    """Compile the smoke's train/eval computations once in-process, into
    the shared persistent jit cache — every child of a subsequent launch
    (the socket smoke's 2, the churn smoke's two 3-process fleets) then
    loads instead of compiling, which is what keeps the smokes inside
    the CI budget."""
    import jax

    from repro.exp import Experiment, TransportSpec

    jax.config.update("jax_compilation_cache_dir",
                      os.environ["JAX_COMPILATION_CACHE_DIR"])
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    warm = dataclasses.replace(
        spec, name="churn_smoke_warm",
        transport=TransportSpec(kind="loopback"),
        # pin the LR schedule's total_steps to the real run's: it is a
        # compile-time constant, and a different value is a cache miss
        optimizer=dataclasses.replace(
            spec.optimizer,
            total_steps=(spec.train.steps
                         if spec.optimizer.total_steps is None
                         else spec.optimizer.total_steps)),
        train=dataclasses.replace(spec.train, steps=2, snapshot_dir=None,
                                  snapshot_every=0))
    t0 = time.monotonic()
    Experiment(warm).run()
    print(f"jit cache warmed in {time.monotonic() - t0:.1f}s")


def scoreboard_smoke(straggler: int = 2) -> int:
    """The out-of-order scheduling win over real processes: a 3-process
    ring where one rank is heavily throttled, gated by per-child
    `GossipPacer`s (``schedule.mode="scoreboard"``). Lock-step would
    drag every rank down to the straggler's wall clock; here the fast
    ranks must finish their step loops in < 0.5× the straggler's wall
    while the run-ahead credit (backpressure) keeps their teachers
    inside the staleness window — and lossless localhost delivery must
    still hold edge by edge."""
    from repro.exp import ExperimentSpec, ScheduleSpec, get_preset
    from repro.launch.gossip import (delivery_gaps, fleet_summary,
                                     launch_gossip)

    # the straggler's pace must dominate per-step compute even on a
    # 1-core CI box where all three children contend for the same CPU
    # (compute serializes; only *sleep* can be overlapped) — 2 s/step
    # makes the straggler's wall mostly pace, which the fast ranks are
    # free to overlap
    slow_pace_ms = 2000.0
    spec = get_preset("gossip_socket")
    spec = dataclasses.replace(
        spec,
        name="scoreboard_smoke",
        clients=ExperimentSpec.uniform_fleet(
            3, arch=spec.clients[0].arch, aux_heads=spec.clients[0].aux_heads,
            width=spec.clients[0].width),
        # runahead > the straggler's publish gap (pool_update_every=5) so
        # the gate releases on its first publish rather than deadlocking,
        # but < steps so it can engage mid-run
        schedule=ScheduleSpec(mode="scoreboard", runahead=12,
                              pace_ms=(0.0, 0.0, slow_pace_ms)),
        train=dataclasses.replace(spec.train, steps=16))
    spec.validate()
    os.environ.setdefault(
        "JAX_COMPILATION_CACHE_DIR",
        os.path.join(tempfile.gettempdir(), "repro_jit_cache"))
    # warm with a sync schedule: the jitted computations are identical,
    # and the warm run needs no pacer
    _warm_jit_cache(dataclasses.replace(spec, schedule=ScheduleSpec()))

    print(f"scoreboard smoke: 3 processes, rank {straggler} throttled to "
          f"{slow_pace_ms:.0f} ms/step, runahead {spec.schedule.runahead}")
    results = launch_gossip(spec, timeout=120.0)
    fleet = fleet_summary(results)
    for rank in sorted(results):
        r = results[rank]
        sched = r.get("sched") or {}
        print(f"  client {rank}: {r['steps']} steps in "
              f"{r['wall_seconds']:.2f}s, distilled on "
              f"{r['distill_steps']}/{r['steps']} steps, backpressure "
              f"{sched.get('backpressure_s', 0.0):.2f}s over "
              f"{sched.get('backpressure_events', 0):.0f} waits")

    fast_wall = max(r["wall_seconds"] for rank, r in results.items()
                    if rank != straggler)
    slow_wall = results[straggler]["wall_seconds"]
    ok = True
    if fast_wall >= 0.5 * slow_wall:
        print(f"FAIL: fast ranks took {fast_wall:.2f}s against the "
              f"straggler's {slow_wall:.2f}s — no better than the "
              f"lock-step bound", file=sys.stderr)
        ok = False
    # the run-ahead credit is timing-dependent on a loaded CI box (the
    # straggler's publish can land just before the fast ranks hit the
    # gate), so backpressure is reported, not asserted — the in-process
    # test_runahead_backpressure_gates_and_releases owns that invariant
    print(f"fleet backpressure: {fleet['backpressure_seconds']:.2f}s over "
          f"{fleet['backpressure_events']:.0f} waits")
    if fleet["distill_steps_min"] < 1:
        print("FAIL: a client never distilled from a neighbor",
              file=sys.stderr)
        ok = False
    if fleet["failed_sends"] == 0 and \
            not any(r.get("tombstoned_bytes", 0) for r in results.values()):
        gaps = delivery_gaps(results)
        if gaps:
            print("FAIL: delivered != offered on lossless localhost: "
                  + "; ".join(f"edge {e}: {d}/{o} B"
                              for e, (o, d) in sorted(gaps.items())),
                  file=sys.stderr)
            ok = False
    if ok:
        print(f"scoreboard ok: fast wall {fast_wall:.2f}s < 0.5 x "
              f"straggler {slow_wall:.2f}s, delivered == offered on "
              f"every edge")
    return 0 if ok else 1


def lm_smoke() -> int:
    """The heterogeneous-LM fleet over real processes: the ``lm_hetero``
    preset — an SSM, a dense transformer and a small MoE distilling each
    other's next-token predictions — run as 3 OS processes over TCP on
    the entropy-adaptive, delta-compressed wire. The smoke owns three
    invariants: every client distills from a neighbor, localhost
    delivery is lossless edge by edge, and the *measured* mean frame
    size stays inside the budget's shape-computed ceiling — the
    bytes/token ledger holds on the real wire."""
    from repro.exp import get_preset
    from repro.launch.gossip import (delivery_gaps, fleet_summary,
                                     launch_gossip)
    from repro.lm import adaptive_frame_max_nbytes, lm_wire_tokens

    spec = get_preset("lm_hetero")
    spec = dataclasses.replace(
        spec, name="lm_smoke",
        train=dataclasses.replace(spec.train, steps=12))
    spec.validate()
    os.environ.setdefault(
        "JAX_COMPILATION_CACHE_DIR",
        os.path.join(tempfile.gettempdir(), "repro_jit_cache"))
    _warm_jit_cache(spec)

    print(f"lm smoke: 3 processes "
          f"({'/'.join(c.arch for c in spec.clients)}), "
          f"{spec.train.steps} steps, budget "
          f"{spec.wire.budget_bytes_per_token} B/token, "
          f"compression {spec.wire.compression}")
    results = launch_gossip(spec, timeout=150.0)
    fleet = fleet_summary(results)
    for rank in sorted(results):
        r = results[rank]
        print(f"  client {rank} ({spec.clients[rank].arch}): "
              f"{r['steps']} steps in {r['wall_seconds']:.1f}s, "
              f"loss {r['final_loss']:.3f}, distilled on "
              f"{r['distill_steps']}/{r['steps']} steps, rx "
              f"{r['delivered_bytes']:,.0f} B / tx "
              f"{r['offered_bytes']:,.0f} B")

    ok = True
    if fleet["distill_steps_min"] < 1:
        print("FAIL: a client never distilled from a neighbor",
              file=sys.stderr)
        ok = False
    if fleet["failed_sends"] == 0 and \
            not any(r.get("tombstoned_bytes", 0) for r in results.values()):
        gaps = delivery_gaps(results)
        if gaps:
            print("FAIL: delivered != offered on lossless localhost: "
                  + "; ".join(f"edge {e}: {d}/{o} B"
                              for e, (o, d) in sorted(gaps.items())),
                  file=sys.stderr)
            ok = False
    # the budget ledger on the real wire: every published frame covers
    # horizon windows x lm_wire_tokens tokens, and its size is bounded
    # by the shape-computed ceiling (header + ids + k-map + lse lanes
    # plus budget_bytes_per_token for the value/index streams); the
    # delta compression wrapper only ever shrinks frames, so the raw
    # ceiling still bounds the compressed wire
    tokens = lm_wire_tokens(spec.train.public_batch_size,
                            spec.data.seq_len, spec.data.max_positions)
    ceiling = adaptive_frame_max_nbytes(
        window=spec.wire.horizon, seq_batch=spec.train.public_batch_size,
        tokens=tokens, num_heads=spec.clients[0].aux_heads + 1,
        budget_bytes_per_token=spec.wire.budget_bytes_per_token,
        emb_dim=0)
    n_msgs = fleet["offered_messages"]
    mean_frame = fleet["offered_bytes"] / max(n_msgs, 1)
    tokens_per_msg = spec.wire.horizon * tokens
    print(f"wire: {n_msgs:.0f} frames, mean {mean_frame:,.0f} B "
          f"({mean_frame / tokens_per_msg:.1f} B/token) vs ceiling "
          f"{ceiling:,d} B ({ceiling / tokens_per_msg:.1f} B/token)")
    if mean_frame > ceiling:
        print(f"FAIL: mean frame {mean_frame:,.0f} B exceeds the "
              f"budget ceiling {ceiling:,d} B", file=sys.stderr)
        ok = False
    if ok:
        print("lm smoke ok: all 3 archs distilled, delivery lossless, "
              "bytes/token within budget")
    return 0 if ok else 1


def churn_smoke(crash_rank: int = 1, crash_step: int = 5) -> int:
    """Kill-and-restore over real processes: crash one rank mid-run, then
    resume the whole fleet from its per-rank snapshots."""
    from repro.exp import ExperimentSpec, get_preset
    from repro.launch.gossip import fleet_summary, launch_gossip

    snap_dir = tempfile.mkdtemp(prefix="fleet_churn_smoke_")
    # jit cache shared by every child of both launches: the resumed fleet
    # (and ranks 1..2 of the first) skip compilation — what keeps two
    # full 3-process launches inside the CI budget
    os.environ.setdefault("JAX_COMPILATION_CACHE_DIR",
                          os.path.join(snap_dir, "jit_cache"))
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    spec = get_preset("gossip_socket")
    spec = dataclasses.replace(
        spec,
        name="churn_smoke",
        clients=ExperimentSpec.uniform_fleet(
            3, arch=spec.clients[0].arch, aux_heads=spec.clients[0].aux_heads,
            width=spec.clients[0].width),
        init_scheme="per_client",  # each child inits only its own model
        # a short horizon keeps the per-publish encode cheap (CI budget);
        # the restored mailbox's window still covers the resumed steps
        wire=dataclasses.replace(spec.wire, horizon=10),
        train=dataclasses.replace(spec.train, steps=8, batch_size=16,
                                  snapshot_dir=snap_dir, snapshot_every=3))
    spec.validate()
    try:
        print(f"churn smoke: 3 processes, crash rank {crash_rank} at local "
              f"step {crash_step}, snapshots every "
              f"{spec.train.snapshot_every} steps")
        _warm_jit_cache(spec)
        t0 = time.monotonic()
        try:
            launch_gossip(spec, timeout=50.0,
                          die_at={crash_rank: crash_step})
        except RuntimeError as e:
            elapsed = time.monotonic() - t0
            print(f"crash detected in {elapsed:.1f}s: {e}")
            if f"client {crash_rank}" not in str(e):
                print("FAIL: error does not name the crashed rank",
                      file=sys.stderr)
                return 1
            if elapsed > 40.0:
                print("FAIL: crash detection leaned on the hard timeout",
                      file=sys.stderr)
                return 1
        else:
            print("FAIL: the injected crash was not detected",
                  file=sys.stderr)
            return 1

        results = launch_gossip(spec, timeout=50.0, resume=True)
        fleet = fleet_summary(results)
        r = results[crash_rank]
        # note: fleet-wide delivered ≤ offered does NOT hold here — the
        # crashed rank's restored offered book rolled back to its last
        # snapshot while survivors' delivered books kept mail it sent
        # after that point (per-rank snapshots are uncoordinated cuts);
        # the invariant the smoke owns is "the restored client trains
        # and distills again"
        print(f"resumed: rank {crash_rank} restored at step "
              f"{r['start_step']}, distilled on {r['distill_steps']} "
              f"post-restore steps; fleet delivered "
              f"{fleet['delivered_bytes']:,.0f} / offered "
              f"{fleet['offered_bytes']:,.0f} B")
        ok = True
        if r["start_step"] < 1:
            print("FAIL: crashed rank did not restore from its snapshot",
                  file=sys.stderr)
            ok = False
        if r["distill_steps"] < 1:
            print("FAIL: restored client never distilled post-restore",
                  file=sys.stderr)
            ok = False
        return 0 if ok else 1
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
