#!/usr/bin/env python
"""Run a socket-transport gossip experiment as one OS process per client.

    PYTHONPATH=src python scripts/run_gossip_procs.py               # 4-proc ring
    PYTHONPATH=src python scripts/run_gossip_procs.py --preset gossip_socket \
        --steps 20 --throttle 3:50 --out gossip.json
    PYTHONPATH=src python scripts/run_gossip_procs.py --smoke       # CI: 2 procs

Each client is a real OS process with its own `SocketTransport` listener,
gossiping top-k prediction windows over localhost TCP (`launch/gossip.py`).
``--throttle RANK:MS`` sleeps MS milliseconds after each of that rank's
local steps — a genuine wall-clock straggler, not a simulated one.

``--smoke`` is the bounded CI configuration: 2 clients, 8 steps, hard
60-second internal timeout. The script exits non-zero if any client
finishes without ever distilling from a neighbor, or if the fleet's
delivered bytes exceed its offered bytes (the meter invariant).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def parse_throttle(items):
    out = {}
    for item in items or ():
        rank, _, ms = item.partition(":")
        out[int(rank)] = float(ms)
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--preset", default="gossip_socket")
    p.add_argument("--spec", help="ExperimentSpec JSON file (overrides "
                   "--preset; must use transport kind 'socket')")
    p.add_argument("--steps", type=int, help="override train.steps")
    p.add_argument("--clients", type=int,
                   help="override fleet size (uniform fleet)")
    p.add_argument("--throttle", action="append", metavar="RANK:MS",
                   help="sleep MS ms after each local step of RANK "
                        "(repeatable) — a real wall-clock straggler")
    p.add_argument("--timeout", type=float, default=300.0,
                   help="hard cap on the whole run (seconds)")
    p.add_argument("--smoke", action="store_true",
                   help="bounded CI config: 2 clients, 8 steps, 60s cap")
    p.add_argument("--out", metavar="PATH",
                   help="write per-rank results + fleet summary JSON")
    args = p.parse_args(argv)

    from repro.exp import ExperimentSpec, get_preset
    from repro.launch.gossip import fleet_summary, launch_gossip

    if args.spec:
        with open(args.spec) as f:
            spec = ExperimentSpec.from_json(f.read())
    else:
        spec = get_preset(args.preset)
    timeout = args.timeout
    if args.smoke:
        args.clients, args.steps, timeout = 2, 8, 55.0
    if args.clients:
        spec = dataclasses.replace(
            spec, clients=ExperimentSpec.uniform_fleet(
                args.clients, arch=spec.clients[0].arch,
                aux_heads=spec.clients[0].aux_heads,
                width=spec.clients[0].width))
    if args.steps:
        spec = dataclasses.replace(
            spec, train=dataclasses.replace(spec.train, steps=args.steps))

    K = spec.num_clients
    print(f"{spec.name}: {K} clients as {K} OS processes over TCP, "
          f"{spec.train.steps} local steps each (timeout {timeout:.0f}s)")
    results = launch_gossip(spec, timeout=timeout,
                            throttle_ms=parse_throttle(args.throttle))
    fleet = fleet_summary(results)

    for rank in sorted(results):
        r = results[rank]
        print(f"  client {rank}: {r['steps']} steps in "
              f"{r['wall_seconds']:.1f}s, loss {r['final_loss']:.3f}, "
              f"distilled on {r['distill_steps']}/{r['steps']} steps, "
              f"rx {r['delivered_bytes']:,.0f} B / tx "
              f"{r['offered_bytes']:,.0f} B")
    print(f"fleet: offered {fleet['offered_bytes']:,.0f} B, delivered "
          f"{fleet['delivered_bytes']:,.0f} B, "
          f"{fleet['distill_steps_total']:.0f} distillation steps, "
          f"{fleet['failed_sends']:.0f} failed sends")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"spec": spec.to_dict(),
                       "results": {str(k): v for k, v in results.items()},
                       "fleet": fleet}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    ok = True
    if fleet["delivered_bytes"] > fleet["offered_bytes"]:
        print("FAIL: delivered bytes exceed offered bytes", file=sys.stderr)
        ok = False
    if fleet["distill_steps_min"] < 1:
        print("FAIL: a client never distilled from a neighbor",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
