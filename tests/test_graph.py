"""Communication topology tests (paper §4.4, Fig. 5)."""
import numpy as np
import pytest

from repro.core.graph import (
    chain_graph,
    complete_graph,
    cycle_graph,
    graph_distance_matrix,
    islands_graph,
    isolated_graph,
    validate_adjacency,
)


def test_complete():
    adj = complete_graph(4)
    validate_adjacency(adj)
    assert all(len(n) == 3 for n in adj)


def test_cycle_distances():
    adj = cycle_graph(4)
    d = graph_distance_matrix(adj)
    # 0 -> 1 is 1 hop; 0 -> 3 is 3 hops (directed ring)
    assert d[0, 1] == 1 and d[0, 2] == 2 and d[0, 3] == 3


def test_islands_disconnected():
    adj = islands_graph(4, 2)
    d = graph_distance_matrix(adj)
    assert np.isinf(d[0, 2]) and np.isinf(d[0, 3])
    assert d[0, 1] == 1 and d[2, 3] == 1


def test_chain_endpoint():
    adj = chain_graph(3)
    assert adj[2] == ()
    d = graph_distance_matrix(adj)
    assert d[0, 2] == 2 and np.isinf(d[2, 0])


def test_isolated():
    adj = isolated_graph(3)
    assert all(n == () for n in adj)


def test_validate_rejects_self_edge():
    with pytest.raises(ValueError):
        validate_adjacency([(0,), ()])
