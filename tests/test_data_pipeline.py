"""Batch iterator + public pool determinism (the hash-identified public
batch of the paper's communication-efficiency argument)."""
import numpy as np
import pytest

from repro.data.pipeline import BatchIterator, PublicPool
from repro.data.synthetic import make_synthetic_text, make_synthetic_vision


def test_batch_iterator_covers_epoch():
    arrays = {"x": np.arange(10), "labels": np.arange(10)}
    it = BatchIterator(arrays, np.arange(10), batch_size=5, seed=0)
    seen = np.concatenate([it.next()["x"], it.next()["x"]])
    assert sorted(seen.tolist()) == list(range(10))


def test_batch_iterator_wraps():
    arrays = {"x": np.arange(4)}
    it = BatchIterator(arrays, np.arange(4), batch_size=3, seed=0)
    for _ in range(5):
        b = it.next()
        assert b["x"].shape == (3,)


def test_empty_indices_raise():
    with pytest.raises(ValueError):
        BatchIterator({"x": np.arange(4)}, np.array([], dtype=int), 2)


def test_public_pool_deterministic_and_unlabeled():
    arrays = {"x": np.arange(100), "labels": np.arange(100)}
    pool = PublicPool(arrays, np.arange(50), batch_size=8, seed=3)
    b1 = pool.sample(7)
    b2 = pool.sample(7)
    np.testing.assert_array_equal(b1["x"], b2["x"])  # same step, same batch
    assert "labels" not in b1  # D_* is unlabeled
    b3 = pool.sample(8)
    assert not np.array_equal(b1["x"], b3["x"])


def test_synthetic_vision_learnable_structure():
    ds = make_synthetic_vision(num_labels=4, samples_per_label=20, noise=0.2)
    # same-class samples are closer than cross-class on average
    intra, inter = [], []
    for i in range(40):
        for j in range(i + 1, 40):
            d = np.linalg.norm(ds.images[i] - ds.images[j])
            (intra if ds.labels[i] == ds.labels[j] else inter).append(d)
    assert np.mean(intra) < 0.5 * np.mean(inter)


def test_synthetic_text_shapes():
    ds = make_synthetic_text(num_domains=3, sequences_per_domain=4,
                             seq_len=16, vocab_size=32)
    assert ds.tokens.shape == (12, 16)
    assert ds.tokens.max() < 32 and ds.tokens.min() >= 0
