"""Tests for `repro.serve` — the continuous-batching inference front.

Acceptance (ISSUE 8):
  * decode determinism under slot admit/evict — a request's greedy token
    sequence through the continuous engine equals the solo (unbatched
    B=1) decode, for every request in a mixed-length stream, regardless
    of which other requests share the batch;
  * the fused full-prompt prefill is *bitwise* identical (logits and
    caches) to the token-by-token ``decode_step`` loop it replaced;
  * a teacher-cache hit returns predictions byte-identical to the
    recompute it replaced, with hit/miss/eviction ledger accounting;
  * a snapshot-loaded front serves exactly the params the trainer held;
  * serve→distill feedback: clients measurably distill from served
    traffic over the metered wire.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.exp import ExperimentSpec, ServeSpec, get_preset
from repro.models.zoo import build_bundle
from repro.serve import (
    CacheLedger,
    ContinuousBatchingEngine,
    Prefill,
    Router,
    ServeRequest,
    TeacherPredictionCache,
    TrafficLog,
    run_serve_scenario,
    solo_generate,
)

_ARCH = "minitron-4b"


def _tree_equal(a, b) -> bool:
    eq = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)
    return all(jax.tree.leaves(eq))


@pytest.fixture(scope="module")
def lm():
    cfg = get_reduced(_ARCH)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _gen_request(rid, vocab, rng, max_new):
    return ServeRequest(
        request_id=rid, kind="generate",
        prompt=rng.integers(0, vocab, size=int(rng.integers(3, 8)),
                            dtype=np.int32),
        max_new_tokens=max_new)


# ---------------------------------------------------------------------------
# fused prefill
# ---------------------------------------------------------------------------

def test_prefill_bitwise_matches_stepwise_loop(lm):
    """The single-dispatch scan prefill replaced a token-by-token python
    loop; the replacement must be bitwise — logits AND caches."""
    import jax.numpy as jnp

    cfg, bundle, params = lm
    B, T, cache_len = 2, 7, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)

    step = jax.jit(bundle.decode_step)
    loop_caches = bundle.init_cache(B, cache_len, jnp.float32)
    loop_logits = []
    for t in range(T):
        lg, loop_caches = step(params, tokens[:, t:t + 1], loop_caches)
        loop_logits.append(np.asarray(lg))

    fused_caches = bundle.init_cache(B, cache_len, jnp.float32)
    fused_caches, fused_logits = Prefill(bundle)(params, tokens,
                                                 fused_caches)

    fused_np = np.asarray(fused_logits)  # (T, B, 1, V)
    for t in range(T):
        assert fused_np[t].tobytes() == loop_logits[t].tobytes(), \
            f"prefill logits diverge at position {t}"
    assert _tree_equal(fused_caches, loop_caches), \
        "prefill caches diverge from the step-wise loop"


def test_prefill_rejects_non_lm():
    class NotLM:
        name = "resnet"
        is_lm = False

    with pytest.raises(ValueError, match="decode path"):
        Prefill(NotLM())


# ---------------------------------------------------------------------------
# continuous batching determinism
# ---------------------------------------------------------------------------

def test_continuous_equals_solo_under_admit_evict(lm):
    """Mixed-length requests through a 3-slot engine: lanes retire and
    re-admit constantly, and every request's greedy tokens must equal
    its solo unbatched decode."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(0)
    cache_len = 8 + 10
    engine = ContinuousBatchingEngine(bundle, params, num_slots=3,
                                      cache_len=cache_len)
    requests = [_gen_request(rid, cfg.vocab_size, rng,
                             max_new=int(rng.integers(1, 11)))
                for rid in range(6)]
    for r in requests:
        engine.submit(r)
    responses = {r.request_id: r for r in engine.run()}

    assert len(responses) == len(requests)
    assert engine.completed == len(requests)
    for req in requests:
        solo = solo_generate(bundle, params, req.prompt,
                             req.max_new_tokens, cache_len)
        got = responses[req.request_id].tokens
        assert got == solo, \
            f"request {req.request_id}: batched {got} != solo {solo}"
        assert len(got) == req.max_new_tokens


def test_cobatch_does_not_change_tokens(lm):
    """The same request decodes to the same tokens whatever shares the
    engine — here: alone vs alongside longer neighbours."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(1)
    probe = _gen_request(0, cfg.vocab_size, rng, max_new=6)

    alone = ContinuousBatchingEngine(bundle, params, num_slots=2,
                                     cache_len=18)
    alone.submit(probe)
    tokens_alone = alone.run()[0].tokens

    crowded = ContinuousBatchingEngine(bundle, params, num_slots=2,
                                       cache_len=18)
    crowded.submit(probe)
    for rid in range(1, 4):
        crowded.submit(_gen_request(rid, cfg.vocab_size, rng, max_new=9))
    tokens_crowded = {r.request_id: r.tokens for r in crowded.run()}[0]

    assert tokens_alone == tokens_crowded


def test_static_admission_drains_before_admitting(lm):
    """Static batching is the same engine with a gate: no admission into
    a partially-free batch. Tokens still match solo; the batch structure
    shows in the ticks (a later batch admits only after the earlier one
    fully finished)."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(2)
    engine = ContinuousBatchingEngine(bundle, params, num_slots=2,
                                      cache_len=18, admission="static")
    requests = [_gen_request(rid, cfg.vocab_size, rng,
                             max_new=(8 if rid % 2 == 0 else 2))
                for rid in range(4)]
    for r in requests:
        engine.submit(r)
    responses = sorted(engine.run(), key=lambda r: r.admit_tick)

    # two batches of two; the second admits no earlier than the first
    # batch's last retirement
    first_batch, second_batch = responses[:2], responses[2:]
    assert first_batch[0].admit_tick == first_batch[1].admit_tick
    assert second_batch[0].admit_tick == second_batch[1].admit_tick
    assert second_batch[0].admit_tick >= max(r.finish_tick
                                             for r in first_batch)
    for req in requests:
        got = {r.request_id: r.tokens for r in responses}[req.request_id]
        assert got == solo_generate(bundle, params, req.prompt,
                                    req.max_new_tokens, 18)


def test_continuous_occupancy_beats_static_on_mixed_lengths(lm):
    """The benchmark's claim as a correctness property: on mixed lengths
    the continuous engine needs fewer decode ticks and keeps lanes
    fuller than the static gate."""
    cfg, bundle, params = lm

    def run(admission):
        rng = np.random.default_rng(3)
        engine = ContinuousBatchingEngine(bundle, params, num_slots=2,
                                          cache_len=18,
                                          admission=admission)
        for rid in range(6):
            engine.submit(_gen_request(rid, cfg.vocab_size, rng,
                                       max_new=(10 if rid % 2 else 2)))
        engine.run()
        return engine

    cont, static = run("continuous"), run("static")
    assert cont.decode_ticks < static.decode_ticks
    assert cont.occupancy() > static.occupancy()


def test_engine_input_validation(lm):
    cfg, bundle, params = lm
    with pytest.raises(ValueError, match="admission"):
        ContinuousBatchingEngine(bundle, params, admission="greedy")
    with pytest.raises(ValueError, match="at least one slot"):
        ContinuousBatchingEngine(bundle, params, num_slots=0)
    engine = ContinuousBatchingEngine(bundle, params, num_slots=2,
                                      cache_len=12)
    with pytest.raises(ValueError, match="only decodes"):
        engine.submit(ServeRequest(request_id=0, kind="classify",
                                   image=np.zeros((8, 8, 3))))
    with pytest.raises(ValueError, match="cache"):
        engine.submit(ServeRequest(
            request_id=1, kind="generate",
            prompt=np.zeros(8, dtype=np.int32), max_new_tokens=8))


# ---------------------------------------------------------------------------
# teacher-prediction cache
# ---------------------------------------------------------------------------

def test_cache_hit_is_byte_identical_to_recompute():
    rng = np.random.default_rng(0)
    value = {"logits": rng.standard_normal((4, 8)).astype(np.float32),
             "sample_ids": np.arange(4, dtype=np.uint64)}
    calls = []

    def compute():
        calls.append(1)
        return {k: v.copy() for k, v in value.items()}

    cache = TeacherPredictionCache(capacity=2)
    miss, hit1 = cache.get_or_compute(3, (0, 1), compute)
    got, hit2 = cache.get_or_compute(3, (1, 0), compute)  # order-insensitive
    assert (hit1, hit2) == (False, True)
    assert len(calls) == 1, "hit must not recompute"
    for k in value:
        assert got[k].tobytes() == miss[k].tobytes()
    ledger = cache.ledger
    assert (ledger.hits, ledger.misses) == (1, 1)
    assert ledger.hit_bytes == ledger.miss_bytes > 0
    assert ledger.hit_rate() == 0.5


def test_cache_lru_eviction_and_ledger():
    cache = TeacherPredictionCache(capacity=2)
    mk = lambda w: (lambda: {"logits": np.full((2, 2), w, np.float32)})
    cache.get_or_compute(0, (0,), mk(0))
    cache.get_or_compute(1, (0,), mk(1))
    cache.get_or_compute(0, (0,), mk(0))  # touch 0: now 1 is LRU
    cache.get_or_compute(2, (0,), mk(2))  # evicts window 1
    assert cache.key(0, (0,)) in cache
    assert cache.key(1, (0,)) not in cache
    assert cache.key(2, (0,)) in cache
    assert cache.ledger.evictions == 1
    assert len(cache) == 2
    table = cache.ledger.format_table()
    assert "1 hits" in table and "evicted" in table
    with pytest.raises(ValueError, match="capacity"):
        TeacherPredictionCache(capacity=0)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def _affinity_router(policy="label_affinity"):
    # client 0 owns labels {0,1}, client 1 owns {2}, client 2 owns {1,2}
    affinity = np.array([[4.0, 2.0, 0.0],
                         [0.0, 0.0, 5.0],
                         [0.0, 3.0, 5.0]])
    return Router(3, affinity=affinity, policy=policy)


def test_router_label_affinity_and_pinning():
    r = _affinity_router()
    img = np.zeros((8, 8, 3))
    assert r.route(ServeRequest(0, image=img, label_hint=0)) == 0
    assert r.route(ServeRequest(1, image=img, label_hint=1)) == 2
    # argmax tie on label 2 (clients 1 and 2) resolves to the lowest id
    assert r.route(ServeRequest(2, image=img, label_hint=2)) == 1
    # an explicit pin beats the affinity map
    assert r.route(ServeRequest(3, image=img, label_hint=0,
                                client_id=2)) == 2
    # hintless requests fall back to round-robin
    assert [r.route(ServeRequest(4 + i, image=img)) for i in range(4)] \
        == [0, 1, 2, 0]
    s = r.summary()
    assert s["routed"] == 8.0 and s["c2"] == 3.0


def test_router_validation():
    with pytest.raises(ValueError, match="unknown routing policy"):
        Router(2, policy="sticky")
    with pytest.raises(ValueError, match="affinity map"):
        Router(2, policy="label_affinity")
    with pytest.raises(ValueError, match="does not cover"):
        Router(4, affinity=np.ones((2, 3)), policy="label_affinity")
    r = _affinity_router()
    with pytest.raises(ValueError, match="pins client"):
        r.route(ServeRequest(0, image=np.zeros((8, 8, 3)), client_id=7))


def test_router_round_robin_spreads_evenly():
    r = Router(3, policy="round_robin")
    img = np.zeros((8, 8, 3))
    got = [r.route(ServeRequest(i, image=img, label_hint=0))
           for i in range(6)]
    assert got == [0, 1, 2, 0, 1, 2]


# ---------------------------------------------------------------------------
# request validation + traffic log
# ---------------------------------------------------------------------------

def test_request_validation():
    with pytest.raises(ValueError, match="unknown request kind"):
        ServeRequest(0, kind="embed").validate()
    with pytest.raises(ValueError, match="no image"):
        ServeRequest(0, kind="classify").validate()
    with pytest.raises(ValueError, match="no window_id"):
        ServeRequest(0, kind="teacher").validate()
    with pytest.raises(ValueError, match="1-D token prompt"):
        ServeRequest(0, kind="generate",
                     prompt=np.zeros((2, 3), np.int32)).validate()
    with pytest.raises(ValueError, match="< 1 new token"):
        ServeRequest(0, kind="generate", prompt=np.zeros(3, np.int32),
                     max_new_tokens=0).validate()


def test_traffic_log():
    log = TrafficLog()
    with pytest.raises(ValueError, match="empty"):
        log.arrays()
    for _ in range(3):
        log.log(np.zeros((4, 4, 3), np.float32))
    assert len(log) == 3
    assert log.arrays()["images"].shape == (3, 4, 4, 3)


# ---------------------------------------------------------------------------
# ServeSpec
# ---------------------------------------------------------------------------

def test_serve_spec_json_round_trip():
    spec = get_preset("serve_loop")
    clone = ExperimentSpec.from_json(spec.to_json())
    assert clone == spec
    assert isinstance(clone.serve, ServeSpec)
    assert clone.serve.engine_arch == "minitron-4b"
    # the dict form carries the serve block
    assert json.loads(spec.to_json())["serve"]["requests"] == \
        spec.serve.requests


@pytest.mark.parametrize("patch, match", [
    (dict(requests=-1), "requests"),
    (dict(router="sticky"), "router"),
    (dict(num_slots=0), "num_slots"),
    (dict(max_new_tokens=0), "max_new_tokens"),
    (dict(cache_windows=0), "cache_windows"),
    (dict(teachers=(0, 9)), "teacher"),
    (dict(requests=0, feedback_steps=2), "feedback"),
])
def test_serve_spec_validation(patch, match):
    spec = get_preset("serve_loop")
    spec = dataclasses.replace(spec,
                               serve=dataclasses.replace(spec.serve,
                                                         **patch))
    with pytest.raises(ValueError, match=match):
        spec.validate()


def test_serve_feedback_needs_prediction_wire():
    spec = get_preset("serve_loop")
    spec = dataclasses.replace(
        spec, wire=dataclasses.replace(spec.wire, exchange="params"))
    with pytest.raises(ValueError, match="prediction"):
        spec.validate()


# ---------------------------------------------------------------------------
# end-to-end: snapshot serving + cache + feedback (slow tier)
# ---------------------------------------------------------------------------

pytest_slow = pytest.mark.slow


@pytest.fixture(scope="module")
def scenario(tmp_path_factory):
    """One tiny train→snapshot→serve→feedback run shared by the
    end-to-end assertions (training dominates; run it once)."""
    spec = get_preset("serve_loop")
    spec = dataclasses.replace(
        spec,
        train=dataclasses.replace(spec.train, steps=8),
        serve=dataclasses.replace(spec.serve, requests=9, num_slots=2,
                                  max_new_tokens=4, cache_windows=2,
                                  feedback_steps=1))
    workdir = str(tmp_path_factory.mktemp("serve_scenario"))
    return run_serve_scenario(spec, workdir)


@pytest_slow
def test_scenario_serves_every_request(scenario):
    m = scenario.metrics
    expected = 9 + max(2 * 2, 4)  # stream + generate burst
    assert len(scenario.responses) == expected
    assert sum(m[f"served/{k}"]
               for k in ("classify", "teacher", "generate")) == expected
    assert m["route/routed"] == m["served/classify"]
    assert m["engine/completed"] == m["served/generate"]
    assert m["serve/snapshot_step"] == 8.0
    assert all(r.tokens for r in scenario.responses
               if r.kind == "generate")


@pytest_slow
def test_scenario_cache_hits_on_hot_windows(scenario):
    m = scenario.metrics
    assert m["cache/hit_rate"] > 0
    assert m["cache/hits"] + m["cache/misses"] == m["served/teacher"]
    hits = [r for r in scenario.responses
            if r.kind == "teacher" and r.cache_hit]
    misses = {r.request_id: r for r in scenario.responses
              if r.kind == "teacher" and not r.cache_hit}
    assert hits and misses
    # a hit's predictions are byte-identical to the miss that filled the
    # entry (same window, whole-fleet teacher set)
    first_miss = min(misses.values(), key=lambda r: r.request_id)
    h = min(hits, key=lambda r: r.request_id)
    for k in ("logits", "sample_ids"):
        assert h.predictions[k].tobytes() == \
            first_miss.predictions[k].tobytes()


@pytest_slow
def test_snapshot_front_serves_trainer_params(scenario):
    """The router's loaded params must be exactly what the trained fleet
    snapshotted — reload from the same directory and compare against
    what the front serves (the trainer itself has since moved: the
    feedback steps kept training it)."""
    from repro.fleet import load_client_params

    front = scenario.front
    snap_dir = scenario.spec.train.snapshot_dir
    for cid, bundle in enumerate(front.bundles):
        like = bundle.init(jax.random.PRNGKey(99))
        loaded, step = load_client_params(snap_dir, cid, like)
        assert step == 8
        assert _tree_equal(loaded, front.params[cid])
        # ...and the post-feedback trainer params differ: the fleet
        # really trained on the served traffic after the snapshot
        trained = scenario.experiment.trainer.clients[cid].params
        assert not _tree_equal(trained, front.params[cid])


@pytest_slow
def test_scenario_feedback_distills_from_served_traffic(scenario):
    m = scenario.metrics
    assert m["feedback/steps"] == 1.0
    assert m["feedback/distill_steps"] >= 1.0
    assert m["feedback/wire_bytes"] > 0
    assert len(scenario.front.traffic) > 0
