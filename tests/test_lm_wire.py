"""Tests for the `repro.lm` wire half: the entropy-adaptive top-k codec
(budget allocation, bitwise anchors, ragged round-trips), the XOR-delta
bit-packed compression wrapper, and the positions-as-samples adapter's
seeded subsampling."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, NonFiniteError, make_codec
from repro.comm.wire import DenseCodec, TopKCodec
from repro.lm import (
    AdaptiveTopKCodec,
    CompressedCodec,
    adaptive_frame_max_nbytes,
    densify_adaptive,
    pack_bits,
    unpack_bits,
)


def _window_outs(W=2, B=4, E=8, C=10, m=2, seed=0, peaked=None):
    rng = np.random.default_rng(seed)
    outs = {
        "embedding": rng.normal(size=(W, B, E)).astype(np.float32),
        "logits": rng.normal(size=(W, B, C)).astype(np.float32),
        "aux_logits": rng.normal(size=(W, m, B, C)).astype(np.float32),
    }
    if peaked is not None:
        # make the first `peaked` tokens of each window near-deterministic
        outs["logits"][:, :peaked, 0] = 30.0
    return outs


def _ids(W, B):
    return (np.arange(W * B, dtype=np.uint64).reshape(W, B) * 977) + 3


# ---------------------------------------------------------------------------
# bitwise anchors
# ---------------------------------------------------------------------------

def test_unbounded_budget_is_topk_codec_bitwise():
    """budget_bytes_per_token=0 must produce byte-for-byte the fixed
    TopKCodec payload (codec_id 2 header included) — and the adaptive
    codec must decode/densify that frame itself."""
    outs = _window_outs()
    ids = _ids(2, 4)
    fixed = TopKCodec(k=4, emb_encoding="int8")
    adap = AdaptiveTopKCodec(k=4, budget_bytes_per_token=0,
                             emb_encoding="int8")
    pf = fixed.encode(1, 5, 5, ids, outs)
    assert adap.encode(1, 5, 5, ids, outs) == pf
    # device path too
    dev = {k: jnp.asarray(v) for k, v in outs.items()}
    assert adap.encode(1, 5, 5, ids, dev) == pf
    # and the adaptive codec densifies the fixed frame identically
    df = fixed.densify(fixed.decode(pf))
    da = adap.densify(adap.decode(pf))
    for key in df:
        np.testing.assert_array_equal(df[key], da[key])


def test_device_and_numpy_paths_byte_identical():
    """Budgeted frames from jax.Array outputs and numpy outputs must be
    byte-identical: all float math lives in one jitted graph shared by
    both paths."""
    outs = _window_outs(seed=3)
    ids = _ids(2, 4)
    codec = AdaptiveTopKCodec(k=6, budget_bytes_per_token=14,
                              emb_encoding="int8")
    p_np = codec.encode(2, 7, 7, ids, outs)
    p_dev = codec.encode(2, 7, 7, ids,
                         {k: jnp.asarray(v) for k, v in outs.items()})
    assert p_np == p_dev
    # serialization is deterministic
    assert codec.encode(2, 7, 7, ids, outs) == p_np


# ---------------------------------------------------------------------------
# budgeted round-trips
# ---------------------------------------------------------------------------

def test_adaptive_roundtrip_budget_and_entropy_allocation():
    """decode(encode(x)) is exact, the (val, idx) streams respect the
    byte budget, and low-entropy (peaked) tokens get fewer entries than
    uncertain ones."""
    W, B, C, m = 2, 6, 32, 2
    outs = _window_outs(W=W, B=B, C=C, m=m, seed=1, peaked=3)
    ids = _ids(W, B)
    budget = 16
    codec = AdaptiveTopKCodec(k=8, budget_bytes_per_token=budget,
                              emb_encoding="none")
    msg = codec.decode(codec.encode(4, 9, 9, ids, outs))
    assert (msg.src, msg.sent_step, msg.t0) == (4, 9, 9)
    np.testing.assert_array_equal(msg.arrays["sample_ids"], ids)
    kt = msg.arrays["k_per_token"]
    assert kt.dtype == np.uint16 and kt.shape == (W, B)
    H = m + 1
    N = W * B
    entry = 2 + 2  # f16 val + u16 idx
    T = int(kt.sum())
    assert msg.arrays["vals"].shape == (H, T)
    assert msg.arrays["idx"].shape == (H, T)
    # hard budget: stream bytes per token <= budget, by construction
    assert H * T * entry <= budget * N
    # entropy steering: the peaked tokens sit at the k_min floor while
    # the uncertain ones absorb the freed budget
    flat = kt.astype(int)
    assert flat[:, :3].max() <= flat[:, 3:].min()
    assert flat.min() >= 1  # never below top-1
    # retained entries carry the exact wire-cast top values, per token
    dense = codec.densify(msg)
    col = np.repeat(np.arange(N), kt.reshape(-1))
    lg = dense["logits"].reshape(N, C)
    np.testing.assert_array_equal(
        lg[col, msg.arrays["idx"][0].astype(np.int64)],
        msg.arrays["vals"][0].astype(np.float32))


def test_budget_exhaustion_floors_at_k_min():
    """A budget below the floor still ships k_min entries per token —
    the wire never sends less than the top-1 prediction."""
    outs = _window_outs(C=50)
    ids = _ids(2, 4)
    codec = AdaptiveTopKCodec(k=8, budget_bytes_per_token=1,
                              emb_encoding="none")
    msg = codec.decode(codec.encode(0, 0, 0, ids, outs))
    assert (msg.arrays["k_per_token"] == 1).all()
    dense = codec.densify(msg)
    # the survivor is the argmax
    top1 = dense["logits"].argmax(-1)
    np.testing.assert_array_equal(top1.reshape(-1),
                                  msg.arrays["idx"][0].astype(np.int64))


def test_k_edges_and_forced_u32_vocab():
    """k=1, k=vocab, and a >u16 vocab forcing u32 indices all round-trip
    exactly."""
    for k, C in ((1, 10), (10, 10)):
        outs = _window_outs(C=C)
        # budget comfortably above the full-k cost (H*k*entry = 120 B)
        codec = AdaptiveTopKCodec(k=k, budget_bytes_per_token=1000,
                                  emb_encoding="none")
        msg = codec.decode(codec.encode(0, 0, 0, _ids(2, 4), outs))
        assert msg.arrays["idx"].dtype == np.uint16
        dense = codec.densify(msg)
        if k == C:  # full-k: lossless reconstruction
            np.testing.assert_allclose(dense["logits"], outs["logits"],
                                       rtol=1e-3, atol=1e-3)
    C = 2 ** 16 + 7
    outs = _window_outs(W=1, B=2, C=C, m=1, seed=1)
    outs["logits"][..., C - 3] = 100.0  # winner beyond u16 range
    codec = AdaptiveTopKCodec(k=4, budget_bytes_per_token=12,
                              emb_encoding="none")
    msg = codec.decode(codec.encode(0, 0, 0, _ids(1, 2), outs))
    assert msg.arrays["idx"].dtype == np.uint32
    kt = msg.arrays["k_per_token"].reshape(-1).astype(np.int64)
    col0 = np.concatenate([[0], np.cumsum(kt)[:-1]])
    assert (msg.arrays["idx"][0][col0] == C - 3).all()


@pytest.mark.parametrize("poison", ["logits", "aux_logits"])
@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_adaptive_rejects_non_finite(poison, bad):
    outs = _window_outs()
    outs[poison].flat[outs[poison].size // 2] = bad
    codec = AdaptiveTopKCodec(k=4, budget_bytes_per_token=8,
                              emb_encoding="none")
    with pytest.raises(NonFiniteError, match="non-finite"):
        codec.encode(0, 0, 0, _ids(2, 4), outs)


def test_adaptive_rejects_f16_overflow():
    """Finite f32 beyond ±65504 overflows in the f16 wire cast — the
    rejection must fire on the wire dtype (same invariant as the fixed
    codecs)."""
    outs = _window_outs()
    outs["logits"][0, 0, 0] = 1e5
    codec = AdaptiveTopKCodec(k=4, budget_bytes_per_token=8,
                              val_dtype="float16", emb_encoding="none")
    with pytest.raises(NonFiniteError):
        codec.encode(0, 0, 0, _ids(2, 4), outs)
    # f32 wire dtype carries the value fine
    AdaptiveTopKCodec(k=4, budget_bytes_per_token=8, val_dtype="float32",
                      emb_encoding="none") \
        .encode(0, 0, 0, _ids(2, 4), outs)


def test_densify_adaptive_preserves_lse_and_confidence():
    """tail="uniform" per-token reconstruction keeps logsumexp and the
    top-1 probability exact, exactly as the fixed-k densify."""
    rng = np.random.default_rng(2)
    W, H, N, C = 1, 1, 6, 40
    logits = (rng.normal(size=(N, C)) * 3).astype(np.float32)
    kt = np.array([[1, 2, 3, 5, 8, 40]], np.uint16)
    vals_l, idx_l = [], []
    for i, k in enumerate(kt.reshape(-1)):
        v, ix = jax.lax.top_k(jnp.asarray(logits[i]), int(k))
        vals_l.append(np.asarray(v))
        idx_l.append(np.asarray(ix))
    vals = np.concatenate(vals_l)[None]
    idx = np.concatenate(idx_l)[None].astype(np.int64)
    lse = np.asarray(jax.nn.logsumexp(jnp.asarray(logits), -1)) \
        .reshape(W, H, N)
    recon = densify_adaptive(vals, idx, lse, kt, C).reshape(N, C)
    lse_r = np.asarray(jax.nn.logsumexp(jnp.asarray(recon), -1))
    np.testing.assert_allclose(lse_r, lse.reshape(N), rtol=1e-5)
    p = np.asarray(jax.nn.softmax(jnp.asarray(recon), -1))
    p_true = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
    np.testing.assert_allclose(p.max(-1), p_true.max(-1), rtol=1e-5)


def test_adaptive_frame_max_nbytes_is_a_tight_ceiling():
    """Measured payloads never exceed the shape-computed ceiling, and the
    ceiling is exact when the budget divides evenly."""
    W, B, C, m = 2, 8, 64, 2
    outs = _window_outs(W=W, B=B, C=C, m=m, E=16)
    ids = _ids(W, B)
    for budget in (4, 12, 24, 48):
        codec = AdaptiveTopKCodec(k=8, budget_bytes_per_token=budget,
                                  emb_encoding="int8")
        p = codec.encode(0, 0, 0, ids, outs)
        cap = adaptive_frame_max_nbytes(W, B, B, m + 1, budget, emb_dim=16)
        assert len(p) <= cap, (budget, len(p), cap)


# ---------------------------------------------------------------------------
# compression wrapper
# ---------------------------------------------------------------------------

def test_pack_bits_roundtrip():
    rng = np.random.default_rng(0)
    for width in (1, 3, 7, 11, 17, 32):
        v = rng.integers(0, 2 ** width, size=101, dtype=np.uint64)
        packed = pack_bits(v, width)
        assert packed.dtype == np.uint8
        assert len(packed) == (101 * width + 7) // 8
        np.testing.assert_array_equal(unpack_bits(packed, 101, width), v)


@pytest.mark.parametrize("inner", [
    lambda: AdaptiveTopKCodec(k=6, budget_bytes_per_token=14,
                              emb_encoding="int8"),
    lambda: AdaptiveTopKCodec(k=6, budget_bytes_per_token=0,
                              emb_encoding="int8"),  # fixed-format frame
    lambda: TopKCodec(k=6, emb_encoding="int8"),
], ids=["adaptive", "adaptive_unbounded", "fixed"])
def test_compressed_codec_is_decode_exact(inner):
    """CompressedCodec(inner) reproduces the inner codec's decoded arrays
    bit-for-bit — compression is transparent to every consumer."""
    outs = _window_outs(C=64, seed=5)
    ids = _ids(2, 4)
    raw = inner()
    comp = CompressedCodec(inner())
    m_raw = raw.decode(raw.encode(3, 11, 11, ids, outs))
    m_comp = comp.decode(comp.encode(3, 11, 11, ids, outs))
    assert set(m_raw.arrays) == set(m_comp.arrays)
    for key in m_raw.arrays:
        np.testing.assert_array_equal(m_raw.arrays[key],
                                      m_comp.arrays[key])
        assert m_raw.arrays[key].dtype == m_comp.arrays[key].dtype
    assert (m_comp.src, m_comp.sent_step, m_comp.t0, m_comp.num_classes) \
        == (3, 11, 11, 64)
    # densify delegates to the inner codec
    d_raw, d_comp = raw.densify(m_raw), comp.densify(m_comp)
    for key in d_raw:
        np.testing.assert_array_equal(d_raw[key], d_comp[key])


def test_compression_off_is_todays_frames():
    """compression="none" never constructs the wrapper: make_codec
    returns the bare codec and the payload is byte-identical to a direct
    encode."""
    cfg = CommConfig(topk=5, compression="none")
    codec = make_codec("prediction_topk", cfg)
    assert isinstance(codec, TopKCodec)
    outs = _window_outs()
    ids = _ids(2, 4)
    assert codec.encode(0, 0, 0, ids, outs) == \
        TopKCodec(k=5).encode(0, 0, 0, ids, outs)


def test_compressed_dense_frames_pass_through():
    """Frames with no index stream (DenseCodec) pass through unchanged —
    byte-identical payload, still decodable by the wrapper."""
    outs = _window_outs()
    ids = _ids(2, 4)
    inner = DenseCodec(logit_dtype="float32", emb_encoding="float32")
    comp = CompressedCodec(DenseCodec(logit_dtype="float32",
                                      emb_encoding="float32"))
    p_inner = inner.encode(0, 0, 0, ids, outs)
    p_comp = comp.encode(0, 0, 0, ids, outs)
    assert p_inner == p_comp
    m = comp.decode(p_comp)
    np.testing.assert_array_equal(m.arrays["heads"],
                                  inner.decode(p_inner).arrays["heads"])


def test_compressed_u32_index_stream():
    """Compression must be exact for u32 index streams (vocab > 65535)."""
    C = 2 ** 16 + 7
    outs = _window_outs(W=1, B=3, C=C, m=1, seed=2)
    ids = _ids(1, 3)
    raw = AdaptiveTopKCodec(k=4, budget_bytes_per_token=18,
                            emb_encoding="none")
    comp = CompressedCodec(AdaptiveTopKCodec(k=4, budget_bytes_per_token=18,
                                             emb_encoding="none"))
    m_raw = raw.decode(raw.encode(0, 0, 0, ids, outs))
    m_comp = comp.decode(comp.encode(0, 0, 0, ids, outs))
    assert m_comp.arrays["idx"].dtype == np.uint32
    np.testing.assert_array_equal(m_raw.arrays["idx"], m_comp.arrays["idx"])


def test_make_codec_dispatch_and_validation():
    cfg = CommConfig(topk=7, budget_bytes_per_token=20, compression="delta")
    codec = make_codec("prediction_adaptive", cfg)
    assert isinstance(codec, CompressedCodec)
    assert isinstance(codec.inner, AdaptiveTopKCodec)
    assert codec.inner.k == 7 and codec.inner.budget == 20
    with pytest.raises(ValueError, match="compression"):
        make_codec("prediction_topk", CommConfig(compression="gzip"))


# ---------------------------------------------------------------------------
# positions-as-samples adapter: seeded subsampling
# ---------------------------------------------------------------------------

def _fake_lm_bundle(B, T, D, V, m=1, seed=0):
    """A stand-in LM bundle: deterministic pseudo-outputs derived from the
    tokens, shaped like `models.zoo` LM bundles."""
    def apply(params, batch):
        tok = jnp.asarray(batch["tokens"], jnp.float32)
        base = tok[..., None]
        hidden = base * jnp.arange(1, D + 1, dtype=jnp.float32)
        logits = base * 0.01 * jnp.arange(1, V + 1, dtype=jnp.float32)
        aux = jnp.stack([logits * (h + 2) for h in range(m)])
        return {"hidden": hidden, "logits": logits, "aux_heads": aux,
                "aux_loss": jnp.float32(0.0)}

    return types.SimpleNamespace(apply=apply)


def test_lm_adapter_seeded_subsample_is_deterministic_and_shared():
    """The same position_seed must pick the same positions on every call
    and for every client (teachers and students must align row-by-row),
    and different seeds must pick different subsets."""
    from repro.core.lm_adapter import lm_mhd_outputs

    B, T, D, V = 4, 9, 6, 12
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, V, size=(B, T)).astype(np.int32)}
    bundle = _fake_lm_bundle(B, T, D, V)
    o1 = lm_mhd_outputs(bundle, None, batch, max_positions=10,
                        position_seed=7)
    o2 = lm_mhd_outputs(bundle, None, batch, max_positions=10,
                        position_seed=7)
    assert o1["logits"].shape[0] == 10
    for key in ("embedding", "logits", "labels", "sample_rows"):
        np.testing.assert_array_equal(np.asarray(o1[key]),
                                      np.asarray(o2[key]))
    o3 = lm_mhd_outputs(bundle, None, batch, max_positions=10,
                        position_seed=8)
    assert not np.array_equal(np.asarray(o1["sample_rows"]),
                              np.asarray(o3["sample_rows"])) or \
        not np.array_equal(np.asarray(o1["labels"]), np.asarray(o3["labels"]))
    # the seeded subset is NOT the biased prefix
    o_prefix = lm_mhd_outputs(bundle, None, batch, max_positions=10,
                              position_seed=None)
    np.testing.assert_array_equal(np.asarray(o_prefix["sample_rows"]),
                                  np.repeat(np.arange(2, dtype=np.int32),
                                            [8, 2]))
    assert not np.array_equal(np.asarray(o1["sample_rows"]),
                              np.asarray(o_prefix["sample_rows"]))


def test_lm_adapter_labels_and_rows_consistent():
    """labels[i] must be the next token at the position sample_rows[i]
    maps from — under both truncation modes."""
    from repro.core.lm_adapter import lm_mhd_outputs

    B, T, D, V = 3, 7, 4, 10
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, V, size=(B, T)).astype(np.int32)
    batch = {"tokens": tokens}
    bundle = _fake_lm_bundle(B, T, D, V)
    full_labels = tokens[:, 1:].reshape(-1)
    full_rows = np.repeat(np.arange(B), T - 1)
    for seed in (None, 5):
        o = lm_mhd_outputs(bundle, None, batch, max_positions=8,
                           position_seed=seed)
        lab = np.asarray(o["labels"])
        rows = np.asarray(o["sample_rows"])
        # every (row, label) pair exists in the full flattening
        pairs = set(zip(full_rows.tolist(), full_labels.tolist()))
        assert set(zip(rows.tolist(), lab.tolist())) <= pairs


def test_synthetic_text_table_seed_pins_domain_languages():
    from repro.data.synthetic import make_synthetic_text

    a = make_synthetic_text(num_domains=3, sequences_per_domain=4,
                            seq_len=10, vocab_size=16, seed=0, table_seed=5)
    b = make_synthetic_text(num_domains=3, sequences_per_domain=4,
                            seq_len=10, vocab_size=16, seed=0, table_seed=5)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    # different sample seeds, same languages: tokens differ
    c = make_synthetic_text(num_domains=3, sequences_per_domain=4,
                            seq_len=10, vocab_size=16, seed=1, table_seed=5)
    assert not np.array_equal(a.tokens, c.tokens)
    # table_seed=None keeps the historical single-stream draw: calling
    # twice is bitwise stable, and differs from the pinned-table stream
    d1 = make_synthetic_text(num_domains=3, sequences_per_domain=4,
                             seq_len=10, vocab_size=16, seed=0)
    d2 = make_synthetic_text(num_domains=3, sequences_per_domain=4,
                             seq_len=10, vocab_size=16, seed=0)
    np.testing.assert_array_equal(d1.tokens, d2.tokens)
    assert not np.array_equal(d1.tokens, a.tokens)


def test_lm_hetero_spec_roundtrip():
    """The preset validates, and its spec JSON round-trips exactly."""
    from repro.exp.presets import get_preset
    from repro.exp.spec import ExperimentSpec

    spec = get_preset("lm_hetero")
    assert spec.wire.exchange == "prediction_adaptive"
    assert spec.wire.compression == "delta"
    again = ExperimentSpec.from_json(spec.to_json()).validate()
    assert again == spec
    archs = [c.arch for c in spec.clients]
    assert archs == ["lm_ssm", "lm_transformer", "lm_moe"]


def test_spec_rejects_misconfigured_lm_wire():
    import dataclasses

    from repro.exp.presets import get_preset

    spec = get_preset("lm_hetero")
    with pytest.raises(ValueError, match="compression"):
        dataclasses.replace(
            spec, wire=dataclasses.replace(
                spec.wire, exchange="params", budget_bytes_per_token=0),
            transport=dataclasses.replace(spec.transport,
                                          kind="loopback")).validate()
    with pytest.raises(ValueError, match="budget_bytes_per_token"):
        dataclasses.replace(spec, wire=dataclasses.replace(
            spec.wire, exchange="prediction_topk",
            compression="none")).validate()
    with pytest.raises(ValueError, match="seq_len"):
        dataclasses.replace(spec, data=dataclasses.replace(
            spec.data, seq_len=1)).validate()
