"""Optimizers & schedules vs closed forms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import (
    OptimizerConfig,
    adamw,
    clip_by_global_norm,
    make_optimizer,
    sgd_momentum,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_decay_schedule,
    warmup_cosine_schedule,
)


def test_cosine_schedule_endpoints():
    s = cosine_decay_schedule(0.1, 100)
    np.testing.assert_allclose(float(s(0)), 0.1, rtol=1e-6)
    np.testing.assert_allclose(float(s(100)), 0.0, atol=1e-8)
    np.testing.assert_allclose(float(s(50)), 0.05, rtol=1e-6)


def test_warmup():
    s = warmup_cosine_schedule(0.1, 110, warmup_steps=10)
    np.testing.assert_allclose(float(s(5)), 0.05, rtol=1e-6)
    np.testing.assert_allclose(float(s(10)), 0.1, rtol=1e-6)


def test_sgd_momentum_matches_manual():
    opt = sgd_momentum(constant_schedule(0.1), momentum=0.9)
    params = {"w": jnp.array([1.0, 2.0])}
    state = opt.init(params)
    g = {"w": jnp.array([0.5, -1.0])}
    p1, s1 = opt.update(g, state, params, 0)
    np.testing.assert_allclose(np.asarray(p1["w"]), [1 - 0.05, 2 + 0.1],
                               rtol=1e-6)
    p2, s2 = opt.update(g, s1, p1, 1)
    # m2 = 0.9*0.5 + 0.5 = 0.95 -> step 0.095
    np.testing.assert_allclose(np.asarray(p2["w"])[0], 0.95 - 0.095,
                               rtol=1e-6)


def test_sgd_converges_quadratic():
    opt = sgd_momentum(constant_schedule(0.05), momentum=0.9)
    params = {"w": jnp.array([5.0])}
    state = opt.init(params)
    for t in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params, t)
    assert abs(float(params["w"][0])) < 1e-3


def test_adamw_decoupled_weight_decay():
    opt = adamw(constant_schedule(0.0), weight_decay=0.1, grad_clip_norm=None)
    # lr=0 -> weight decay also has no effect (decoupled via lr scaling)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    p1, _ = opt.update({"w": jnp.array([1.0])}, state, params, 0)
    np.testing.assert_allclose(np.asarray(p1["w"]), [1.0], rtol=1e-6)


def test_adamw_converges():
    opt = adamw(constant_schedule(0.05))
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for t in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, state = opt.update(g, state, params, t)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_grad_clip():
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5)


def test_make_optimizer_bf16_state():
    opt = make_optimizer(OptimizerConfig(state_dtype="bfloat16",
                                         total_steps=10))
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["momentum"]["w"].dtype == jnp.bfloat16
