"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as REF
from repro.kernels.dist_ce import dist_ce
from repro.kernels.emb_dist import emb_dist
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("B,V", [(8, 512), (37, 1000), (64, 2048), (3, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dist_ce_sweep(B, V, dtype):
    s = (jax.random.normal(jax.random.PRNGKey(0), (B, V)) * 3).astype(dtype)
    t = (jax.random.normal(jax.random.PRNGKey(1), (B, V)) * 3).astype(dtype)
    ce, tc, sc = dist_ce(s, t, interpret=True, block_rows=16, block_v=128)
    ce_r, tc_r, sc_r = REF.dist_ce_ref(s, t)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(ce, ce_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(tc, tc_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(sc, sc_r, rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,H,KV,d,causal,window", [
    (2, 64, 4, 2, 32, True, 0),
    (1, 100, 2, 2, 16, True, 24),
    (2, 32, 4, 4, 64, False, 0),
    (1, 256, 8, 2, 32, True, 64),
    (1, 48, 4, 1, 16, True, 0),
])
def test_flash_attention_sweep(B, T, H, KV, d, causal, window):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KV, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KV, d))
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_t=32, block_s=32, interpret=True)
    r = REF.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 2, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 32)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, block_t=32, block_s=32, interpret=True)
    r = REF.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=3e-2)


@pytest.mark.parametrize("Bt,T,H,P,N,chunk", [
    (2, 64, 4, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 32, 3, 8, 4, 8),
    (1, 64, 1, 64, 32, 64),
])
def test_ssd_scan_sweep(Bt, T, H, P, N, chunk):
    x = jax.random.normal(jax.random.PRNGKey(0), (Bt, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (Bt, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)))
    B = jax.random.normal(jax.random.PRNGKey(3), (Bt, T, N))
    C = jax.random.normal(jax.random.PRNGKey(4), (Bt, T, N))
    D = jnp.ones((H,))
    y, st = ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    y_r, st_r = REF.ssd_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B,E", [(16, 64), (37, 128), (5, 512)])
def test_emb_dist_sweep(B, E):
    s = jax.random.normal(jax.random.PRNGKey(0), (B, E))
    t = jax.random.normal(jax.random.PRNGKey(1), (B, E))
    o = emb_dist(s, t, interpret=True, block_rows=16)
    r = REF.emb_dist_ref(s, t)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-5, atol=1e-6)


def test_ops_dispatch_cpu_uses_ref():
    from repro.kernels import ops
    s = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    t = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    ce, tc, sc = ops.dist_ce(s, t)  # CPU -> ref path
    ce_r, _, _ = REF.dist_ce_ref(s, t)
    np.testing.assert_allclose(ce, ce_r, rtol=1e-6)
