"""Checkpoint I/O + the paper's rolling pool semantics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import CheckpointManager, load_pytree, save_pytree
from repro.checkpoint.pool import CheckpointPool, PoolEntry


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (3, 4)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, t)
    restored = load_pytree(path, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_mismatch_raises(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, _tree())
    with pytest.raises(ValueError):
        load_pytree(path, {"a": jnp.zeros((3, 4))})


def test_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "run"), max_to_keep=2)
    for s in (10, 20, 30):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [20, 30]
    restored = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(_tree(30)["a"]))


def test_pool_capacity_and_replacement():
    pool = CheckpointPool(capacity=3, update_every=10, seed=0)
    for i in range(5):
        pool.insert(PoolEntry(i, {"w": jnp.ones(1) * i}, step=i))
    assert len(pool) == 3


def test_pool_sampling_delta():
    pool = CheckpointPool(capacity=4, update_every=10, seed=0)
    for i in range(4):
        pool.insert(PoolEntry(i, None, step=0))
    got = pool.sample(2)
    assert len(got) == 2
    assert len({id(e) for e in got}) == 2  # distinct entries
    assert len(pool.sample(10)) == 4  # capped at pool size


def test_pool_update_cadence_and_staleness():
    pool = CheckpointPool(capacity=2, update_every=200)
    assert pool.should_update(0) and pool.should_update(400)
    assert not pool.should_update(150)
    pool.insert(PoolEntry(0, None, step=100))
    assert pool.staleness(300) == 200.0
