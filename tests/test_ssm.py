"""Mamba2 SSD: chunked vs sequential reference, decode-step consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MambaConfig
from repro.models.ssm import (
    init_mamba2,
    init_mamba2_cache,
    mamba2_apply,
    mamba2_decode,
    ssd_chunked,
    ssd_reference,
)


def _rand_ssd(Bt=2, T=64, H=4, P=16, N=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Bt, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bt, T, N))
    C = jax.random.normal(ks[4], (Bt, T, N))
    D = jnp.ones((H,))
    return x, dt, A, B, C, D


def test_chunked_matches_sequential():
    args = _rand_ssd()
    y_ref, h_ref = ssd_reference(*args)
    for chunk in (8, 16, 32, 64):
        y, h = ssd_chunked(*args, chunk_size=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   rtol=3e-4, atol=3e-4)


def test_chunked_gradients_finite():
    args = _rand_ssd(T=32)

    def loss(x):
        y, _ = ssd_chunked(x, *args[1:], chunk_size=8)
        return jnp.sum(jnp.square(y))

    g = jax.grad(loss)(args[0])
    assert np.all(np.isfinite(np.asarray(g)))


def test_mamba2_decode_matches_full_forward():
    cfg = MambaConfig(d_state=8, d_conv=4, expand=2, head_dim=8, chunk_size=8)
    D_model = 16
    params = init_mamba2(jax.random.PRNGKey(0), D_model, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, D_model))
    full = mamba2_apply(params, x, cfg, use_chunked=True)
    cache = init_mamba2_cache(2, D_model, cfg)
    outs = []
    for t in range(16):
        y, cache = mamba2_decode(params, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_state_decay_bounded():
    """With A<0 and bounded inputs the SSD state stays bounded (stability)."""
    x, dt, A, B, C, D = _rand_ssd(T=128)
    _, h = ssd_reference(x, dt, A, B, C, D)
    assert np.all(np.isfinite(np.asarray(h)))
    assert np.abs(np.asarray(h)).max() < 1e4
