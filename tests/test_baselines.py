"""FedAvg / FedMD / supervised baselines sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_mean
from repro.core.fedavg import train_fedavg
from repro.core.fedmd import train_fedmd
from repro.core.supervised import eval_per_label_accuracy, train_supervised
from repro.data import make_synthetic_vision, partition_dataset, PartitionConfig
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def _setup(labels=6, per=40, K=2, seed=0):
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=per,
                               image_size=8, noise=0.5, seed=seed)
    cfg = PartitionConfig(num_clients=K, num_labels=labels,
                          labels_per_client=labels // K, skew=100.0,
                          gamma_pub=0.15, seed=seed)
    part = partition_dataset(ds.labels, cfg)
    arrays = {"images": ds.images, "labels": ds.labels}
    return ds, part, arrays


def test_tree_mean():
    t1 = {"w": jnp.array([1.0, 2.0])}
    t2 = {"w": jnp.array([3.0, 4.0])}
    m = tree_mean([t1, t2])
    np.testing.assert_allclose(np.asarray(m["w"]), [2.0, 3.0])


def test_supervised_learns():
    ds, part, arrays = _setup()
    bundle = build_bundle(resnet_tiny(6))
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=60))
    all_private = np.concatenate(part.client_indices)
    params = train_supervised(bundle, opt, arrays, all_private, steps=60,
                              batch_size=32, seed=0)
    test = make_synthetic_vision(num_labels=6, samples_per_label=10,
                                 image_size=8, noise=0.5, seed=77,
                                 prototype_seed=0)
    acc, present = eval_per_label_accuracy(
        bundle, params, {"images": test.images, "labels": test.labels}, 6)
    assert acc[present].mean() > 0.5  # well above 1/6 chance


def test_fedavg_runs_and_averages():
    ds, part, arrays = _setup()
    bundle = build_bundle(resnet_tiny(6))
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=30))
    params = train_fedavg(bundle, opt, arrays, part.client_indices,
                          steps=30, batch_size=16, average_every=10, seed=0)
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(params))


def test_fedmd_runs():
    ds, part, arrays = _setup()
    bundles = [build_bundle(resnet_tiny(6)) for _ in range(2)]
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=20))
    params = train_fedmd(bundles, opt, arrays, part.client_indices,
                         part.public_indices, steps=20, batch_size=16,
                         public_batch_size=16, seed=0)
    assert len(params) == 2
    for p in params:
        assert all(np.all(np.isfinite(np.asarray(x)))
                   for x in jax.tree.leaves(p))
