"""Tests for `repro.fleet` — the elastic fleet runtime.

Acceptance (ISSUE 5):
  * kill-and-restore determinism: in a 4-client ring under a lossless
    in-process transport, killing one client at step T and restoring it
    from its snapshot yields final per-client params bitwise-equal to
    the uninterrupted run, with delivered ≤ offered on every edge;
  * mid-run save→restore bitwise resume across all four trainers (MHD
    sync + async scheduler clocks, FedMD, FedAvg, supervised);
  * init_scheme="per_client": a process inits only its own clients
    (counted init draws), while the legacy scheme's stream is untouched.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.comm import CommConfig, CommMeter, LoopbackTransport, \
    PredictionBus
from repro.core.graph import complete_graph, cycle_graph
from repro.fleet import (
    ChurnDriver,
    Join,
    Kill,
    Membership,
    Restart,
    Rewire,
    events_from_spec,
    restore_clients,
    restore_fleet,
    save_fleet,
    snapshot_steps,
)

from test_comm import _make_trainer


def _tree_equal(a, b) -> bool:
    eq = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)
    return all(jax.tree.leaves(eq))


def _clients_equal(clients_a, clients_b) -> bool:
    return all(_tree_equal(ca.params, cb.params)
               for ca, cb in zip(clients_a, clients_b))


_PRED_KW = dict(K=4, steps=8, delta=1, m=1, s_p=2, graph=cycle_graph(4),
                comm=CommConfig(topk=8, val_dtype="float32",
                                emb_encoding="float32", horizon=12))


# ---------------------------------------------------------------------------
# membership
# ---------------------------------------------------------------------------

def test_membership_liveness_timeline():
    mem = Membership(cycle_graph(4), 4, [
        Kill(1, step=5), Restart(1, step=9), Join(3, step=3)])
    assert mem.is_alive(0, 0) and mem.is_alive(1, 0)
    assert not mem.is_alive(3, 0) and not mem.is_alive(3, 2)
    assert mem.is_alive(3, 3)
    assert mem.is_alive(1, 4) and not mem.is_alive(1, 5)
    assert not mem.is_alive(1, 8) and mem.is_alive(1, 9)
    assert mem.alive(0) == frozenset({0, 1, 2})
    assert mem.alive(6) == frozenset({0, 2, 3})
    assert mem.alive(20) == frozenset({0, 1, 2, 3})


def test_membership_epochs_are_monotone():
    mem = Membership(cycle_graph(3), 3, [Kill(0, 4), Restart(0, 8)])
    assert [mem.epoch(t) for t in (0, 3, 4, 7, 8, 100)] == \
        [0, 0, 1, 1, 2, 2]


def test_membership_graph_view_filters_dead_sources_keeps_dead_dsts():
    """A dead client publishes nothing (its out-edges vanish as teacher
    links), but mail can still be *addressed* to it — the tombstone
    path."""
    mem = Membership(cycle_graph(4), 4, [Kill(1, step=5)])
    # cycle: adj[i] = (i+1,). client 0 receives from 1; client 1 from 2.
    assert mem.graph_view(4) == [(1,), (2,), (3,), (0,)]
    view = mem.graph_view(5)
    assert view[0] == ()  # dead source filtered: 0 no longer pulls from 1
    assert view[1] == (2,)  # dead DESTINATION keeps its in-edges


def test_membership_rewire_switches_edges():
    two_hop = [(1, 2), (2, 3), (3, 0), (0, 1)]
    mem = Membership(cycle_graph(4), 4, [Rewire(step=6, edges=tuple(
        tuple(r) for r in two_hop))])
    assert mem.graph_view(5) == [(1,), (2,), (3,), (0,)]
    assert mem.graph_view(6) == [tuple(r) for r in two_hop]


def test_membership_rejects_incoherent_scripts():
    with pytest.raises(ValueError, match="already-dead"):
        Membership(cycle_graph(3), 3, [Kill(0, 2), Kill(0, 4)])
    with pytest.raises(ValueError, match="alive"):
        Membership(cycle_graph(3), 3, [Restart(0, 2)])
    with pytest.raises(ValueError, match="joins twice"):
        Membership(cycle_graph(3), 3, [Join(0, 1), Join(0, 5)])
    with pytest.raises(ValueError, match="outside"):
        Membership(cycle_graph(3), 3, [Kill(7, 2)])
    with pytest.raises(ValueError, match="rows"):
        Membership(cycle_graph(3), 3, [Rewire(1, ((1,), (2,)))])


def test_bus_tombstones_mail_to_dead_clients():
    mem = Membership(complete_graph(2), 2, [Kill(1, step=3)])
    meter = CommMeter()
    bus = PredictionBus(LoopbackTransport(), complete_graph(2), 2,
                        meter=meter, membership=mem)
    bus.publish(0, b"live", 2)
    assert bus.deliver(2) == 1
    bus.publish(0, b"dead", 3)
    assert bus.deliver(3) == 0  # dropped, not delivered
    assert bus.mailbox(1)[0].payload == b"live"
    assert meter.tombstoned_messages == 1
    assert meter.tombstoned_bytes == 4
    assert meter.delivered_bytes < meter.total_bytes


# ---------------------------------------------------------------------------
# spec blocks (repro.exp wiring)
# ---------------------------------------------------------------------------

def test_churn_spec_json_roundtrip():
    from repro.exp import ExperimentSpec, get_preset

    spec = get_preset("churn_ring")
    assert spec.churn.events  # the preset actually scripts churn
    spec2 = ExperimentSpec.from_json(spec.to_json()).validate()
    assert spec2 == spec
    events = events_from_spec(spec2.churn)
    assert any(isinstance(e, Join) for e in events)
    assert any(isinstance(e, Rewire) for e in events)


def test_churn_spec_validation():
    from repro.exp import (ChurnEventSpec, ChurnSpec, ExperimentSpec,
                           TrainSpec)

    with pytest.raises(ValueError, match="client id"):
        ExperimentSpec(churn=ChurnSpec(events=(
            ChurnEventSpec(kind="kill", step=3, client=99),))).validate()
    with pytest.raises(ValueError, match="snapshot_dir"):
        ExperimentSpec(churn=ChurnSpec(events=(
            ChurnEventSpec(kind="kill", step=1, client=0),
            ChurnEventSpec(kind="restart", step=3, client=0),))).validate()
    with pytest.raises(ValueError, match="adjacency"):
        ExperimentSpec(churn=ChurnSpec(events=(
            ChurnEventSpec(kind="rewire", step=3),))).validate()
    with pytest.raises(ValueError, match="init_scheme"):
        ExperimentSpec(init_scheme="bogus").validate()
    with pytest.raises(ValueError, match="snapshot_dir"):
        ExperimentSpec(train=TrainSpec(snapshot_every=5)).validate()


def test_runner_rejects_churn_for_inelastic_algorithms():
    from repro.exp import (AlgorithmSpec, ChurnEventSpec, ChurnSpec,
                           Experiment, ExperimentSpec)

    spec = ExperimentSpec(
        algorithm=AlgorithmSpec("fedavg", {"average_every": 5}),
        churn=ChurnSpec(events=(
            ChurnEventSpec(kind="kill", step=3, client=0),)))
    with pytest.raises(ValueError, match="not elastic"):
        Experiment(spec).run()


# ---------------------------------------------------------------------------
# snapshots: bitwise resume (all four trainers)
# ---------------------------------------------------------------------------

def test_snapshot_resume_bitwise_mhd_sync(tmp_path):
    """Step to T, snapshot, step to 2T; restore a FRESH trainer at T and
    step to 2T: params and step metrics identical (prediction wire)."""
    T, N = 4, 8
    tr_a = _make_trainer("prediction_topk", **_PRED_KW)
    metrics_a = [tr_a.step(t) for t in range(N)]
    tr_b = _make_trainer("prediction_topk", **_PRED_KW)
    for t in range(T):
        tr_b.step(t)
    save_fleet(str(tmp_path), T, tr_b)
    tr_c = _make_trainer("prediction_topk", **_PRED_KW)
    assert restore_fleet(str(tmp_path), tr_c) == T
    metrics_c = [tr_c.step(t) for t in range(T, N)]
    assert _clients_equal(tr_a.clients, tr_c.clients)
    assert metrics_a[T:] == metrics_c
    assert tr_a.meter.total_bytes == tr_c.meter.total_bytes
    assert tr_a.meter.delivered_bytes == tr_c.meter.delivered_bytes


def test_snapshot_resume_bitwise_mhd_params_mode(tmp_path):
    T, N = 3, 6
    kw = dict(K=3, steps=N, delta=2, m=1, s_p=2)
    tr_a = _make_trainer("params", **kw)
    for t in range(N):
        tr_a.step(t)
    tr_b = _make_trainer("params", **kw)
    for t in range(T):
        tr_b.step(t)
    save_fleet(str(tmp_path), T, tr_b)
    tr_c = _make_trainer("params", **kw)
    assert restore_fleet(str(tmp_path), tr_c) == T
    for t in range(T, N):
        tr_c.step(t)
    assert _clients_equal(tr_a.clients, tr_c.clients)


def test_snapshot_resume_bitwise_mhd_async_clocks(tmp_path):
    """Async resume restores the scheduler's wall tick and per-client
    local step counts — a 2× straggler keeps its cadence and its LR
    schedule position."""
    from repro.core import AsyncScheduler, ScheduleConfig

    kw = dict(K=3, steps=12, delta=1, m=1, s_p=2,
              comm=CommConfig(topk=8, val_dtype="float32",
                              emb_encoding="float32", horizon=20))
    rates = (1, 1, 2)
    tr_a = _make_trainer("prediction_topk", **kw)
    sched_a = AsyncScheduler(tr_a, ScheduleConfig(rates))
    for _ in range(12):
        sched_a.tick()
    tr_b = _make_trainer("prediction_topk", **kw)
    sched_b = AsyncScheduler(tr_b, ScheduleConfig(rates))
    for _ in range(6):
        sched_b.tick()
    save_fleet(str(tmp_path), 6, tr_b, scheduler=sched_b)
    tr_c = _make_trainer("prediction_topk", **kw)
    sched_c = AsyncScheduler(tr_c, ScheduleConfig(rates))
    assert restore_fleet(str(tmp_path), tr_c, scheduler=sched_c) == 6
    assert sched_c.wall == 6
    assert sched_c.local_steps == sched_b.local_steps
    for _ in range(6):
        sched_c.tick()
    assert _clients_equal(tr_a.clients, tr_c.clients)
    assert sched_c.local_steps == sched_a.local_steps


@pytest.mark.parametrize("mode", ["lockstep", "scoreboard"])
def test_snapshot_resume_bitwise_mid_cadence_4x_skew(tmp_path, mode):
    """Scoreboard satellite: a snapshot cut mid-pool-cadence under 4×
    rate skew (straggler cadence 8 wall ticks, cut at tick 6 — between
    its boundaries) resumes bitwise-equal to the uninterrupted run, for
    both the lockstep and the out-of-order policy."""
    from repro.core import (AsyncScheduler, ScheduleConfig,
                            ScoreboardScheduler)

    cls = AsyncScheduler if mode == "lockstep" else ScoreboardScheduler
    kw = dict(K=3, steps=12, delta=1, m=1, s_p=2,
              comm=CommConfig(topk=8, val_dtype="float32",
                              emb_encoding="float32", horizon=20))
    rates = (1, 1, 4)
    tr_a = _make_trainer("prediction_topk", **kw)
    sched_a = cls(tr_a, ScheduleConfig(rates))
    for _ in range(12):
        sched_a.tick()
    tr_b = _make_trainer("prediction_topk", **kw)
    sched_b = cls(tr_b, ScheduleConfig(rates))
    for _ in range(6):
        sched_b.tick()
    save_fleet(str(tmp_path), 6, tr_b, scheduler=sched_b)
    tr_c = _make_trainer("prediction_topk", **kw)
    sched_c = cls(tr_c, ScheduleConfig(rates))
    assert restore_fleet(str(tmp_path), tr_c, scheduler=sched_c) == 6
    assert sched_c.wall == 6
    assert sched_c.local_steps == sched_b.local_steps == [6, 6, 2]
    for _ in range(6):
        sched_c.tick()
    assert _clients_equal(tr_a.clients, tr_c.clients)
    assert sched_c.local_steps == sched_a.local_steps == [12, 12, 3]


def _baseline_trainer(kind: str):
    from repro.core.fedavg import FedAvgTrainer
    from repro.core.fedmd import FedMDTrainer
    from repro.core.supervised import SupervisedTrainer
    from repro.data import (PartitionConfig, make_synthetic_vision,
                            partition_dataset)
    from repro.models.resnet import resnet_tiny
    from repro.models.zoo import build_bundle
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    K, labels = 3, 8
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=30,
                               image_size=8, noise=0.5, seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=2, skew=100.0,
        gamma_pub=0.2, seed=0))
    arrays = {"images": ds.images, "labels": ds.labels}
    bundles = [build_bundle(resnet_tiny(labels)) for _ in range(K)]
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=6,
                                         grad_clip_norm=1.0))
    if kind == "fedmd":
        return FedMDTrainer(bundles, opt, arrays, part.client_indices,
                            part.public_indices, labels, batch_size=8,
                            public_batch_size=8)
    if kind == "fedavg":
        return FedAvgTrainer(bundles[0], opt, arrays, part.client_indices,
                             labels, batch_size=8, average_every=2)
    if kind == "supervised":
        return SupervisedTrainer(bundles, opt, arrays, part.client_indices,
                                 labels, batch_size=8, scope="separate")
    raise ValueError(kind)


@pytest.mark.parametrize("kind", ["fedmd", "fedavg", "supervised"])
def test_snapshot_resume_bitwise_baselines(kind, tmp_path):
    """The same save→restore bitwise-resume contract for the baseline
    trainers: identical params AND identical step metrics."""
    T, N = 3, 6
    tr_a = _baseline_trainer(kind)
    metrics_a = [tr_a.step(t) for t in range(N)]
    tr_b = _baseline_trainer(kind)
    for t in range(T):
        tr_b.step(t)
    save_fleet(str(tmp_path), T, tr_b)
    tr_c = _baseline_trainer(kind)
    assert restore_fleet(str(tmp_path), tr_c) == T
    metrics_c = [tr_c.step(t) for t in range(T, N)]
    assert metrics_a[T:] == metrics_c
    params_a = (tr_a.client_params if kind == "fedavg" else tr_a.params)
    params_c = (tr_c.client_params if kind == "fedavg" else tr_c.params)
    for pa, pc in zip(params_a, params_c):
        assert _tree_equal(pa, pc)


def test_snapshot_version_gate(tmp_path):
    from repro.fleet import snapshot as snap

    tr = _baseline_trainer("supervised")
    save_fleet(str(tmp_path), 2, tr)
    # corrupt the version of the process file
    path = str(tmp_path / "step_0000000002" / "proc_all.npz")
    state = snap._load_state(path)
    state["version"] = 999
    snap._save_state(path, state)
    with pytest.raises(ValueError, match="version"):
        restore_fleet(str(tmp_path), _baseline_trainer("supervised"))


# ---------------------------------------------------------------------------
# kill-and-restore (the headline acceptance)
# ---------------------------------------------------------------------------

def test_kill_and_restore_bitwise_in_ring(tmp_path):
    """ISSUE 5 acceptance: 4-client ring, lossless in-process transport;
    kill client 2 at step T (its params, pool, mailbox and pending pulls
    wiped), restore it from the step-T snapshot, finish — bitwise-equal
    to the uninterrupted run, delivered ≤ offered on every edge."""
    T, N, victim = 4, 8, 2
    tr_a = _make_trainer("prediction_topk", **_PRED_KW)
    for t in range(N):
        tr_a.step(t)

    tr_b = _make_trainer("prediction_topk", **_PRED_KW)
    for t in range(T):
        tr_b.step(t)
    save_fleet(str(tmp_path), T, tr_b)

    # the crash: state wiped, client out of the stepping set
    tr_b.deactivate_client(victim)
    c = tr_b.clients[victim]
    c.params = jax.tree.map(lambda x: np.zeros_like(x), c.params)
    c.opt_state = jax.tree.map(lambda x: np.zeros_like(x), c.opt_state)
    assert victim not in tr_b.active_ids
    assert len(tr_b.bus.mailbox(victim)) == 0

    # the restore: its snapshot slice, nothing else touched
    assert restore_clients(str(tmp_path), tr_b, [victim],
                           step=T) == {victim: T}
    tr_b.activate_client(victim)
    for t in range(T, N):
        tr_b.step(t)

    assert _clients_equal(tr_a.clients, tr_b.clients)
    meter = tr_b.meter
    assert meter.by_edge, "no traffic metered"
    for edge, offered in meter.by_edge.items():
        assert meter.by_edge_delivered.get(edge, 0) <= offered, edge
    # lossless wire + zero-length outage: the books agree exactly
    assert meter.delivered_bytes == meter.total_bytes


def test_kill_period_tombstones_then_fresh_restart(tmp_path):
    """A client dead for a while: its in-mail is tombstoned (metered
    offered-not-delivered), nobody crashes, and a fresh restart trains
    and distills again."""
    K, steps = 4, 12
    kw = dict(_PRED_KW, K=K, steps=steps)
    events = [Kill(1, step=4), Restart(1, step=8, from_snapshot=False)]
    mem = Membership(cycle_graph(K), K, events)
    tr = _make_trainer("prediction_topk",
                       **dict(kw, graph=mem.graph_view, membership=mem))
    driver = ChurnDriver(tr, events)
    post_restart_distill = 0
    for t in range(steps):
        driver.before_step(t)
        m = tr.step(t)
        if 4 <= t < 8:
            assert "c1/loss" not in m  # dead client does not step
        if t >= 8:
            assert "c1/loss" in m
            post_restart_distill += int(m.get("c1/distill_active", 0.0))
    assert len(driver.applied) == 2
    meter = tr.meter
    assert meter.tombstoned_messages > 0
    for edge, offered in meter.by_edge.items():
        assert meter.by_edge_delivered.get(edge, 0) <= offered, edge
    assert meter.delivered_bytes + meter.tombstoned_bytes == \
        meter.total_bytes  # lossless wire: every offered byte accounted
    assert post_restart_distill > 0


def test_join_late_client_starts_dead(tmp_path):
    """A scripted joiner neither steps nor publishes before its join
    step (its neighbors fall back to supervised-only), then joins."""
    K, steps = 3, 6
    events = [Join(2, step=3)]
    mem = Membership(cycle_graph(K), K, events)
    kw = dict(K=K, steps=steps, delta=1, m=1, s_p=2,
              comm=CommConfig(topk=8, val_dtype="float32",
                              emb_encoding="float32", horizon=10))
    tr = _make_trainer("prediction_topk",
                       **dict(kw, graph=mem.graph_view, membership=mem))
    assert tr.active_ids == [0, 1]
    driver = ChurnDriver(tr, events)
    for t in range(steps):
        driver.before_step(t)
        m = tr.step(t)
        assert ("c2/loss" in m) == (t >= 3)
    assert tr.active_ids == [0, 1, 2]


# ---------------------------------------------------------------------------
# init schemes
# ---------------------------------------------------------------------------

def _counting_bundles(K=3, labels=8, m=1):
    from repro.models.resnet import resnet_tiny
    from repro.models.zoo import build_bundle

    counts = []

    def wrap(bundle, i):
        orig = bundle.init

        def init(key):
            counts.append(i)
            return orig(key)

        return dataclasses.replace(bundle, init=init)

    bundles = [wrap(build_bundle(resnet_tiny(labels, num_aux_heads=m)), i)
               for i in range(K)]
    return bundles, counts


def _trainer_with_bundles(bundles, **kw):
    from repro.core import MHDConfig, DecentralizedTrainer, RunConfig
    from repro.data import (PartitionConfig, make_synthetic_vision,
                            partition_dataset)
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    K, labels = len(bundles), 8
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=30,
                               image_size=8, noise=0.5, seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=2, skew=100.0,
        gamma_pub=0.2, seed=0))
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=4,
                                         grad_clip_norm=1.0))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=1, delta=1,
                    pool_size=2, pool_update_every=2)
    kw.setdefault("exchange", "prediction_topk")
    return DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=4, batch_size=8, public_batch_size=8, seed=0),
        {"images": ds.images, "labels": ds.labels},
        part.client_indices, part.public_indices, complete_graph(K),
        labels, comm=CommConfig(topk=8, horizon=4), **kw)


def test_per_client_init_draws_only_local_models():
    """The O(K) startup claim, asserted by counting init draws: a process
    driving one client runs model init exactly once under per_client —
    and K times under legacy (every process replays the full stream)."""
    bundles, counts = _counting_bundles(K=3)
    tr = _trainer_with_bundles(bundles, local_clients=[1],
                               init_scheme="per_client")
    assert counts == [1]
    assert tr.initialized_clients == [1]
    assert tr.clients[0].params is None and tr.clients[2].params is None

    bundles, counts = _counting_bundles(K=3)
    tr = _trainer_with_bundles(bundles, local_clients=[1],
                               init_scheme="legacy")
    assert counts == [0, 1, 2]
    assert tr.initialized_clients == [0, 1, 2]


def test_per_client_init_is_deterministic_across_processes():
    """fold_in(seed, i): client i's params agree no matter which process
    materializes them — the rendezvous-free property gossip needs."""
    bundles, _ = _counting_bundles(K=3)
    tr_a = _trainer_with_bundles(bundles, local_clients=[0, 1],
                                 init_scheme="per_client")
    bundles, _ = _counting_bundles(K=3)
    tr_b = _trainer_with_bundles(bundles, local_clients=[1, 2],
                                 init_scheme="per_client")
    assert _tree_equal(tr_a.clients[1].params, tr_b.clients[1].params)


def test_legacy_scheme_stream_is_unchanged():
    """The legacy split chain is pinned: same params whether or not the
    fleet machinery is in play (bitwise vs a hand-rolled split chain)."""
    from repro.models.resnet import resnet_tiny
    from repro.models.zoo import build_bundle

    bundles, _ = _counting_bundles(K=3)
    tr = _trainer_with_bundles(bundles, init_scheme="legacy")
    key = jax.random.PRNGKey(0)
    ref = build_bundle(resnet_tiny(8, num_aux_heads=1))
    for i in range(3):
        key, sub = jax.random.split(key)
        assert _tree_equal(tr.clients[i].params, ref.init(sub)), i


def test_per_client_rejects_params_exchange():
    bundles, _ = _counting_bundles(K=3)
    with pytest.raises(ValueError, match="per_client"):
        _trainer_with_bundles(bundles, init_scheme="per_client",
                              exchange="params")


def test_spec_rejects_per_client_with_params_exchange():
    from repro.exp import ExperimentSpec

    with pytest.raises(ValueError, match="per_client"):
        ExperimentSpec(init_scheme="per_client").validate()


# ---------------------------------------------------------------------------
# runner wiring
# ---------------------------------------------------------------------------

def test_runner_snapshot_cadence_and_churn(tmp_path):
    """`Experiment.run()` with snapshot_every writes restorable fleet
    snapshots, and a spec-driven churn run completes with tombstone
    accounting in the exported metrics."""
    from repro.exp import (ChurnEventSpec, ChurnSpec, Experiment,
                           get_preset)

    spec = get_preset("churn_ring")
    spec = dataclasses.replace(
        spec,
        data=dataclasses.replace(spec.data, samples_per_label=30),
        train=dataclasses.replace(spec.train, steps=12,
                                  snapshot_dir=str(tmp_path),
                                  snapshot_every=4),
        churn=ChurnSpec(events=(
            ChurnEventSpec(kind="kill", step=5, client=1),
            ChurnEventSpec(kind="restart", step=9, client=1,
                           from_snapshot=True),)))
    res = Experiment(spec).run()
    assert snapshot_steps(str(tmp_path)) == [4, 8, 12]
    assert res.metrics["comm/tombstoned_bytes"] > 0
    assert res.metrics["comm/delivered_bytes"] <= \
        res.metrics["comm/total_bytes"]
    # the restarted client is back in the final eval
    assert any(k.startswith("c1/") for k in res.metrics)


def test_churn_spec_exchange_mismatch_is_rejected(tmp_path):
    tr = _make_trainer("prediction_topk", **_PRED_KW)
    save_fleet(str(tmp_path), 2, tr)
    tr2 = _make_trainer("params", K=4, steps=4, delta=1, m=1, s_p=2,
                        graph=cycle_graph(4))
    with pytest.raises(ValueError, match="exchange"):
        restore_clients(str(tmp_path), tr2, [0])
