"""Paper §3.3 data-partition protocol: unit + property tests (the
property test skips itself via pytest.importorskip without hypothesis)."""
import numpy as np
import pytest

from repro.data.partition import (
    PartitionConfig,
    assign_primary_labels,
    partition_dataset,
    shared_test_split,
)


def _labels(n_labels=10, per=30, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_labels, size=n_labels * per)


def test_public_private_disjoint_and_complete():
    labels = _labels()
    cfg = PartitionConfig(num_clients=4, num_labels=10, labels_per_client=3,
                          gamma_pub=0.2, seed=0)
    part = partition_dataset(labels, cfg)
    all_idx = np.concatenate([part.public_indices] + part.client_indices)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)  # no repetition (paper)
    assert len(part.public_indices) == round(0.2 * len(labels))


def test_skew_zero_is_uniform():
    labels = _labels(per=200)
    cfg = PartitionConfig(num_clients=4, num_labels=10, labels_per_client=3,
                          skew=0.0, gamma_pub=0.0, seed=1)
    part = partition_dataset(labels, cfg)
    sizes = [len(ci) for ci in part.client_indices]
    assert max(sizes) - min(sizes) < 0.25 * np.mean(sizes)


def test_high_skew_concentrates_on_primary():
    labels = _labels(per=100)
    cfg = PartitionConfig(num_clients=4, num_labels=10, labels_per_client=3,
                          skew=1000.0, gamma_pub=0.0, seed=2)
    part = partition_dataset(labels, cfg)
    for i, idx in enumerate(part.client_indices):
        mask = part.primary_mask(i)
        labs = labels[idx]
        # labels that are primary for nobody are spread uniformly, so only
        # check: of this client's samples whose label has ANY primary owner,
        # the overwhelming majority are primary for this client.
        any_primary = np.zeros(10, dtype=bool)
        for j in range(4):
            any_primary |= part.primary_mask(j)
        relevant = any_primary[labs]
        if relevant.sum() == 0:
            continue
        frac = mask[labs[relevant]].mean()
        assert frac > 0.9, f"client {i}: {frac}"


def test_even_assignment_multiplicity():
    cfg = PartitionConfig(num_clients=6, num_labels=12, labels_per_client=4,
                          assignment="even", even_multiplicity=2, seed=0)
    rng = np.random.default_rng(0)
    primary = assign_primary_labels(cfg, rng)
    counts = np.zeros(12, dtype=int)
    for labs in primary:
        counts[labs] += 1
    assert (counts == 2).all()


def test_shared_test_split_uniform():
    labels = _labels(n_labels=5, per=50)
    idx = shared_test_split(labels, per_label=10, num_labels=5)
    hist = np.bincount(labels[idx], minlength=5)
    assert (hist == 10).all()


def test_partition_invariants():
    """Property: disjoint cover, public fraction, primary sets within range."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        K=st.integers(2, 8),
        L=st.integers(4, 20),
        skew=st.sampled_from([0.0, 1.0, 100.0]),
        gamma=st.sampled_from([0.0, 0.1, 0.3]),
        seed=st.integers(0, 100),
    )
    def check(K, L, skew, gamma, seed):
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, L, size=L * 20)
        cfg = PartitionConfig(num_clients=K, num_labels=L,
                              labels_per_client=max(L // K, 1), skew=skew,
                              gamma_pub=gamma, seed=seed)
        part = partition_dataset(labels, cfg)
        all_idx = np.concatenate([part.public_indices] + part.client_indices)
        assert len(np.unique(all_idx)) == len(labels) == len(all_idx)
        for labs in part.primary_labels:
            assert len(labs) <= max(L // K, 1)
            assert (labs >= 0).all() and (labs < L).all()

    check()
