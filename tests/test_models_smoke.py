"""Per-architecture smoke tests: reduced variant of each assigned arch runs
one forward + one train step on CPU; output shapes + no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get_reduced

pytestmark = pytest.mark.slow  # one jit per assigned arch — minutes on CPU
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def _batch_for(cfg, B=2, T=32, seed=0):
    rng = np.random.default_rng(seed)
    if getattr(cfg, "audio", None) is not None:
        return {
            "tokens": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (B, cfg.audio.decoder_len), dtype=np.int32)),
            "audio_frames": jnp.asarray(
                rng.standard_normal((B, T, cfg.audio.frame_dim)), jnp.float32),
        }
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (B, T), dtype=np.int32))}
    if getattr(cfg, "vision", None) is not None:
        batch["vision_embeds"] = jnp.asarray(rng.standard_normal(
            (B, cfg.vision.num_patches, cfg.vision.embed_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", arch_ids())
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    B = batch["tokens"].shape[0]
    T = batch["tokens"].shape[1]

    out = jax.jit(bundle.apply)(params, batch)
    assert out["logits"].shape == (B, T, cfg.vocab_size)
    assert out["hidden"].shape == (B, T, cfg.d_model)
    assert out["aux_heads"].shape == (cfg.num_aux_heads, B, T, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(out["logits"], dtype=np.float32)))

    opt = make_optimizer(OptimizerConfig(init_lr=0.01, total_steps=10))

    @jax.jit
    def step(p, s, b):
        (loss, _), g = jax.value_and_grad(bundle.loss, has_aux=True)(p, b)
        p2, s2 = opt.update(g, s, p, 0)
        return p2, s2, loss

    p2, _, loss = step(params, opt.init(params), batch)
    assert np.isfinite(float(loss))
    # params actually changed
    d = sum(float(jnp.sum(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert d > 0


@pytest.mark.parametrize("arch", ["gemma3-12b", "qwen2.5-32b", "mamba2-370m",
                                  "deepseek-v3-671b", "zamba2-7b",
                                  "arctic-480b"])
def test_decode_step_shapes(arch):
    cfg = get_reduced(arch)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    caches = bundle.init_cache(2, 16, jnp.float32)
    logits, caches2 = jax.jit(bundle.decode_step)(
        params, jnp.ones((2, 1), jnp.int32), caches)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert int(caches2["index"]) == 1
    assert not np.any(np.isnan(np.asarray(logits, dtype=np.float32)))


def test_resnet_interface():
    from repro.models.resnet import resnet_tiny
    cfg = resnet_tiny(10, num_aux_heads=3)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = {"images": jnp.ones((4, 8, 8, 3)), "labels": jnp.zeros((4,), jnp.int32)}
    out = jax.jit(bundle.apply)(params, batch)
    assert out["logits"].shape == (4, 10)
    assert out["embedding"].shape == (4, cfg.embed_dim)
    assert out["aux_logits"].shape == (3, 4, 10)
    loss, metrics = bundle.loss(params, batch)
    assert np.isfinite(float(loss))
