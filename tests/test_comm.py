"""Tests for the `repro.comm` prediction-exchange subsystem: codec
round-trips, transports, bus fanout, metering accounting, and the
param-pool ⇔ prediction-pool equivalence of the runtime."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    CommConfig,
    CommMeter,
    DenseCodec,
    EdgeSpec,
    LoopbackTransport,
    PredictionBus,
    SimulatedNetwork,
    TopKCodec,
    densify_topk,
    topk_frame_nbytes,
)
from repro.comm.wire import (
    dense_xent_and_conf,
    quantize_emb_int8,
    dequantize_emb_int8,
    sparse_xent_and_conf,
)


def _window_outs(W=2, B=4, E=8, C=10, m=2, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embedding": rng.normal(size=(W, B, E)).astype(np.float32),
        "logits": rng.normal(size=(W, B, C)).astype(np.float32),
        "aux_logits": rng.normal(size=(W, m, B, C)).astype(np.float32),
    }


# ---------------------------------------------------------------------------
# wire codecs
# ---------------------------------------------------------------------------

def test_topk_codec_roundtrip_byte_exact():
    """decode(encode(x)) reproduces every wire array bit-for-bit."""
    outs = _window_outs()
    ids = np.arange(8, dtype=np.uint64).reshape(2, 4) * 17
    codec = TopKCodec(k=4, val_dtype="float32", emb_encoding="float32")
    payload = codec.encode(src=1, sent_step=5, t0=5, sample_ids=ids,
                           outs=outs)
    msg = codec.decode(payload)
    assert (msg.src, msg.sent_step, msg.t0) == (1, 5, 5)
    assert msg.num_classes == 10 and msg.window == 2
    np.testing.assert_array_equal(msg.arrays["sample_ids"], ids)
    # re-encoding the decoded arrays is byte-identical
    W, H, B, k = msg.arrays["vals"].shape
    assert (H, k) == (3, 4)
    vals, idx = jax.lax.top_k(jnp.asarray(outs["logits"]), 4)
    np.testing.assert_array_equal(msg.arrays["vals"][:, 0], np.asarray(vals))
    np.testing.assert_array_equal(msg.arrays["idx"][:, 0],
                                  np.asarray(idx).astype(np.uint16))
    lse = np.asarray(jax.nn.logsumexp(jnp.asarray(outs["logits"]), -1))
    np.testing.assert_allclose(msg.arrays["lse"][:, 0], lse, rtol=1e-6)
    # encoding is deterministic: same inputs -> identical bytes
    assert codec.encode(1, 5, 5, ids, outs) == payload


def test_dense_codec_roundtrip_and_densify():
    outs = _window_outs()
    ids = np.zeros((2, 4), np.uint64)
    codec = DenseCodec(logit_dtype="float32", emb_encoding="float32")
    msg = codec.decode(codec.encode(0, 0, 0, ids, outs))
    dec = codec.densify(msg)
    for key in ("embedding", "logits", "aux_logits"):
        np.testing.assert_array_equal(dec[key], outs[key])


def test_topk_full_k_densify_is_exact():
    """k == num_classes: the packed format is a lossless permutation."""
    outs = _window_outs(C=7)
    codec = TopKCodec(k=7, val_dtype="float32", emb_encoding="none")
    msg = codec.decode(codec.encode(0, 0, 0, np.zeros((2, 4), np.uint64),
                                    outs))
    dec = codec.densify(msg)
    np.testing.assert_allclose(dec["logits"], outs["logits"], rtol=1e-6)
    np.testing.assert_allclose(dec["aux_logits"], outs["aux_logits"],
                               rtol=1e-6)
    assert "embedding" not in dec


def test_densify_preserves_lse_and_confidence():
    """tail="uniform" reconstruction keeps logsumexp and top-1 prob exact
    even when k < C truncates the distribution."""
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(6, 40)).astype(np.float32) * 3
    vals, idx = jax.lax.top_k(jnp.asarray(logits), 5)
    lse = np.asarray(jax.nn.logsumexp(jnp.asarray(logits), -1))
    recon = densify_topk(np.asarray(vals), np.asarray(idx), lse, 40)
    lse_r = np.asarray(jax.nn.logsumexp(jnp.asarray(recon), -1))
    np.testing.assert_allclose(lse_r, lse, rtol=1e-5)
    p = np.asarray(jax.nn.softmax(jnp.asarray(recon), -1))
    p_true = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
    np.testing.assert_allclose(p.max(-1), p_true.max(-1), rtol=1e-5)


def test_sparse_xent_matches_densified_ce():
    """CE against the lse-preserving dense reconstruction ≈ the sparse CE
    of the wire format (they treat tail mass differently; for a peaked
    teacher both approach the dense CE)."""
    V, k = 30, 8
    t = np.zeros((4, V), np.float32)
    t[:, 3], t[:, 7] = 10.0, 8.0
    s = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (4, V)))
    vals, idx = jax.lax.top_k(jnp.asarray(t), k)
    packed = {"vals": vals, "idx": idx,
              "lse": jax.nn.logsumexp(jnp.asarray(t), -1)}
    sp_ce, sp_conf = sparse_xent_and_conf(jnp.asarray(s), packed)
    recon = densify_topk(np.asarray(vals), np.asarray(idx),
                         np.asarray(packed["lse"]), V)
    de_ce, de_conf = dense_xent_and_conf(jnp.asarray(s), jnp.asarray(recon))
    np.testing.assert_allclose(np.asarray(sp_conf), np.asarray(de_conf),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sp_ce), np.asarray(de_ce),
                               rtol=2e-2)


def test_int8_embedding_quantization():
    emb = np.random.default_rng(0).normal(size=(3, 5, 16)).astype(np.float32)
    q, scale = quantize_emb_int8(emb)
    assert q.dtype == np.int8 and scale.shape == (3, 5)
    deq = dequantize_emb_int8(q, scale)
    np.testing.assert_allclose(deq, emb, atol=np.abs(emb).max() / 127 + 1e-6)
    # round-trip through the codec is byte-exact on the quantized arrays
    outs = _window_outs(E=16)
    codec = TopKCodec(k=3, emb_encoding="int8")
    msg = codec.decode(codec.encode(0, 0, 0, np.zeros((2, 4), np.uint64),
                                    outs))
    q2, s2 = quantize_emb_int8(outs["embedding"])
    np.testing.assert_array_equal(msg.arrays["emb_q"], q2)
    np.testing.assert_array_equal(msg.arrays["emb_scale"], s2)


# ---------------------------------------------------------------------------
# adversarial codec round-trips (ISSUE 2 satellite): huge vocabs forcing
# u32 indices, k = vocab, single-class heads, non-finite rejection — for
# all three wire layouts (dense, top-k packed, int8 embeddings)
# ---------------------------------------------------------------------------

_CODECS = {
    "dense": lambda: DenseCodec(logit_dtype="float32",
                                emb_encoding="float32"),
    "topk": lambda: TopKCodec(k=4, val_dtype="float32",
                              emb_encoding="float32"),
    "topk_int8emb": lambda: TopKCodec(k=4, val_dtype="float32",
                                      emb_encoding="int8"),
}


@pytest.mark.parametrize("make", _CODECS.values(), ids=_CODECS.keys())
@pytest.mark.parametrize("shape", [
    dict(W=1, B=2, C=2 ** 16, m=1),  # vocab ≥ 2**16: u16 idx insufficient
    dict(W=2, B=3, C=1, m=1),        # single-class head
    dict(W=1, B=2, C=13, m=2),       # k ≥ vocab (full-k packing)
], ids=["vocab64k", "single_class", "k_ge_vocab"])
def test_codec_roundtrip_adversarial_shapes(make, shape, seed=0):
    """decode(encode(x)) is exact for every codec over shapes that stress
    the index dtype choice and the top-k truncation edge cases."""
    outs = _window_outs(seed=seed, **shape)
    codec = make()
    W, B = shape["W"], shape["B"]
    ids = (np.arange(W * B, dtype=np.uint64).reshape(W, B) * 977) + 3
    payload = codec.encode(src=2, sent_step=7, t0=7, sample_ids=ids,
                           outs=outs)
    msg = codec.decode(payload)
    assert (msg.src, msg.sent_step, msg.t0) == (2, 7, 7)
    assert msg.num_classes == shape["C"] and msg.window == W
    np.testing.assert_array_equal(msg.arrays["sample_ids"], ids)
    if "idx" in msg.arrays:  # top-k codecs: index width tracks the vocab
        expect_dt = np.uint16 if shape["C"] <= 0xFFFF else np.uint32
        assert msg.arrays["idx"].dtype == expect_dt
        assert int(msg.arrays["idx"].max(initial=0)) < shape["C"]
    dec = codec.densify(msg)
    k_eff = min(getattr(codec, "k", shape["C"]), shape["C"])
    if k_eff >= shape["C"]:  # dense, or full-k pack: exact reconstruction
        np.testing.assert_allclose(dec["logits"], outs["logits"], rtol=1e-6)
        np.testing.assert_allclose(dec["aux_logits"], outs["aux_logits"],
                                   rtol=1e-6)
    else:  # truncated: retained ids carry the exact original logits
        vals, idx = jax.lax.top_k(jnp.asarray(outs["logits"]), k_eff)
        got = np.take_along_axis(dec["logits"], np.asarray(idx), axis=-1)
        np.testing.assert_allclose(got, np.asarray(vals), rtol=1e-5)
    # serialization is deterministic
    assert codec.encode(2, 7, 7, ids, outs) == payload


@pytest.mark.parametrize("make", _CODECS.values(), ids=_CODECS.keys())
@pytest.mark.parametrize("poison", ["logits", "aux_logits", "embedding"])
@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_codec_rejects_non_finite(make, poison, bad):
    """NaN/±inf anywhere in the outputs must be refused at encode time —
    a diverged teacher may not poison its students."""
    outs = _window_outs()
    arr = outs[poison].copy()
    arr.flat[arr.size // 2] = bad
    outs[poison] = arr
    with pytest.raises(ValueError, match="non-finite"):
        make().encode(0, 0, 0, np.zeros((2, 4), np.uint64), outs)


def test_codec_rejects_f16_overflow():
    """Finite f32 logits beyond ±65504 overflow to inf in the f16 wire
    cast — the non-finite check must fire on the *wire* dtype, not just
    the input (else the rejection invariant is defeated)."""
    from repro.comm import NonFiniteError

    outs = _window_outs()
    outs["logits"][0, 0, 0] = 1e5  # finite in f32, inf in f16
    ids = np.zeros((2, 4), np.uint64)
    with pytest.raises(NonFiniteError, match="f16 wire cast"):
        TopKCodec(k=4, val_dtype="float16", emb_encoding="none") \
            .encode(0, 0, 0, ids, outs)
    with pytest.raises(NonFiniteError, match="f16 wire cast"):
        DenseCodec(logit_dtype="float16", emb_encoding="none") \
            .encode(0, 0, 0, ids, outs)
    # f32 wire dtypes carry the same value fine
    TopKCodec(k=4, val_dtype="float32", emb_encoding="none") \
        .encode(0, 0, 0, ids, outs)


def test_u32_indices_roundtrip_values_beyond_u16():
    """With vocab > 65535 the winning indices themselves can exceed u16
    range; the wire must carry them losslessly."""
    C = 2 ** 16 + 7
    outs = _window_outs(W=1, B=2, C=C, m=1, seed=1)
    # force the top-1 winner into the > u16 index range
    outs["logits"][..., C - 3] = 100.0
    codec = TopKCodec(k=2, val_dtype="float32", emb_encoding="none")
    msg = codec.decode(codec.encode(0, 0, 0, np.zeros((1, 2), np.uint64),
                                    outs))
    assert msg.arrays["idx"].dtype == np.uint32
    assert (msg.arrays["idx"][:, 0, :, 0] == C - 3).all()


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

def test_loopback_delivers_same_step():
    tr = LoopbackTransport()
    tr.send(0, 1, b"hello", step=3)
    assert tr.poll(1, 3)[0].payload == b"hello"
    assert tr.poll(1, 3) == []  # drained


def test_simulated_network_latency_and_order():
    net = SimulatedNetwork(latency=2)
    net.send(0, 1, b"a", step=0)
    net.send(0, 1, b"b", step=1)
    assert net.poll(1, 1) == []
    got = net.poll(1, 3)
    assert [d.payload for d in got] == [b"a", b"b"]
    assert [d.sent_step for d in got] == [0, 1]
    assert all(d.recv_step == 3 for d in got)


def test_simulated_network_bandwidth_serializes_edge():
    """A 10-byte/step edge takes ceil(len/bw) steps per message, FIFO."""
    net = SimulatedNetwork(latency=0, bandwidth=10)
    net.send(0, 1, b"x" * 25, step=0)  # tx 3 steps -> arrives step 3
    net.send(0, 1, b"y" * 5, step=0)  # queued behind -> arrives step 4
    assert net.poll(1, 2) == []
    assert [d.payload[:1] for d in net.poll(1, 3)] == [b"x"]
    assert [d.payload[:1] for d in net.poll(1, 4)] == [b"y"]


def test_simulated_network_seeded_drops_are_deterministic():
    """Same seed ⇒ the same messages survive and arrive at the same steps
    (ISSUE 2 satellite) — reruns of a lossy experiment are replayable."""
    def deliveries(seed):
        net = SimulatedNetwork(latency=1, drop_prob=0.5, seed=seed)
        for t in range(30):
            net.send(0, 1, f"m{t}".encode(), step=t)
            net.send(2, 1, f"n{t}".encode(), step=t)
        got = net.poll(1, 100)
        return [(d.src, d.payload, d.sent_step, d.recv_step) for d in got], \
            net.dropped_count
    a, dropped_a = deliveries(seed=9)
    b, dropped_b = deliveries(seed=9)
    assert a == b and dropped_a == dropped_b
    assert 0 < dropped_a < 60  # the coin actually flipped both ways


def test_simulated_network_client_rates_slow_the_uplink():
    """client_rates models a slow client as a slow sender: the same payload
    on the same 10-byte/step edge takes rate× as many wall ticks."""
    fast = SimulatedNetwork(bandwidth=10)
    slow = SimulatedNetwork(bandwidth=10, client_rates={0: 4})
    fast.send(0, 1, b"x" * 20, step=0)  # ceil(20/10) = 2 ticks
    slow.send(0, 1, b"x" * 20, step=0)  # ceil(20*4/10) = 8 ticks
    assert [d.payload for d in fast.poll(1, 2)] and not slow.poll(1, 7)
    assert [d.payload for d in slow.poll(1, 8)]
    # propagation latency is a link property: NOT scaled by the rate
    lat = SimulatedNetwork(latency=3, client_rates={0: 4})
    lat.send(0, 1, b"y", step=0)
    assert not lat.poll(1, 2) and lat.poll(1, 3)


def test_simulated_network_drops():
    net = SimulatedNetwork(drop_prob=1.0, seed=0)
    net.send(0, 1, b"gone", step=0)
    assert net.poll(1, 100) == []
    assert net.dropped_count == 1
    keep = SimulatedNetwork(per_edge={(0, 1): EdgeSpec(drop_prob=0.0)},
                            drop_prob=1.0)
    keep.send(0, 1, b"kept", step=0)
    assert len(keep.poll(1, 0)) == 1


# ---------------------------------------------------------------------------
# bus + metering
# ---------------------------------------------------------------------------

def test_bus_fanout_follows_graph():
    from repro.core.graph import cycle_graph

    meter = CommMeter()
    bus = PredictionBus(LoopbackTransport(), cycle_graph(4), 4, meter=meter)
    bus.publish(1, b"msg-from-1", step=0)  # adj[0] = (1,): only 0 receives
    bus.deliver(0)
    assert set(bus.mailbox(0)) == {1}
    assert all(not bus.mailbox(d) for d in (1, 2, 3))
    assert meter.total_bytes == len(b"msg-from-1")
    assert meter.by_edge == {(1, 0): len(b"msg-from-1")}
    assert bus.mailbox(0)[1].staleness(7) == 7


def test_bus_keeps_latest_message_per_sender():
    bus = PredictionBus(LoopbackTransport(), [(1,), (0,)], 2)
    bus.publish(1, b"old", step=0)
    bus.publish(1, b"new", step=5)
    bus.deliver(5)
    assert bus.mailbox(0)[1].payload == b"new"
    assert bus.mailbox(0)[1].sent_step == 5


# ---------------------------------------------------------------------------
# runtime integration
# ---------------------------------------------------------------------------

def _make_trainer(exchange, K=3, labels=8, steps=10, delta=1, m=1,
                  pool_size=2, s_p=2, nu_emb=1.0, graph=None, **kw):
    from repro.core import MHDConfig, DecentralizedTrainer, RunConfig
    from repro.core.graph import complete_graph
    from repro.data import (PartitionConfig, make_synthetic_vision,
                            partition_dataset)
    from repro.models.resnet import resnet_tiny
    from repro.models.zoo import build_bundle
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    ds = make_synthetic_vision(num_labels=labels, samples_per_label=30,
                               image_size=8, noise=0.5, seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=2, skew=100.0,
        gamma_pub=0.2, seed=0))
    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=m))
               for _ in range(K)]
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=steps,
                                         grad_clip_norm=1.0))
    mhd = MHDConfig(nu_emb=nu_emb, nu_aux=1.0, num_aux_heads=m, delta=delta,
                    pool_size=pool_size, pool_update_every=s_p)
    return DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=steps, batch_size=8, public_batch_size=16,
                  eval_every=0, seed=0),
        {"images": ds.images, "labels": ds.labels},
        part.client_indices, part.public_indices,
        graph if graph is not None else complete_graph(K), labels,
        exchange=exchange, **kw)


@pytest.mark.slow
def test_prediction_pool_matches_param_pool_when_lossless():
    """Acceptance: exchange="prediction_topk" under a lossless zero-latency
    transport reproduces the param-pool run — same rng streams, full-k f32
    codec, horizon covering the pool's staleness range ⇒ identical loss
    trajectories (and params never leave a client)."""
    steps = 10
    t_params = _make_trainer("params", steps=steps, delta=2, m=2, s_p=4)
    t_pred = _make_trainer(
        "prediction_topk", steps=steps, delta=2, m=2, s_p=4,
        comm=CommConfig(topk=8, val_dtype="float32",
                        emb_encoding="float32", horizon=steps + 4))
    for t in range(steps):
        m1, m2 = t_params.step(t), t_pred.step(t)
        for key in m1:
            if key in m2:
                assert abs(m1[key] - m2[key]) < 1e-5, (t, key, m1[key],
                                                       m2[key])
    assert t_pred.meter.total_bytes > 0


def test_prediction_mode_metering_matches_accounting():
    """Per-client-step inbound bytes land within 2× of the shared §3.2
    accounting (`_mhd_bytes_per_step` on the run's real wire shape)."""
    from benchmarks.comm_efficiency import _mhd_bytes_per_step

    steps, s_p, K, B, k = 6, 2, 3, 16, 5
    tr = _make_trainer("prediction_topk", K=K, steps=steps, m=1, s_p=s_p,
                       nu_emb=0.0,
                       comm=CommConfig(topk=k, val_dtype="float16",
                                       emb_encoding="none", horizon=s_p))
    for t in range(steps):
        tr.step(t)
    rounds = 1 + steps // s_p  # seed round + one per S_P boundary
    per_client_step = tr.meter.total_bytes / rounds / K / s_p
    # paper accounting: Δ = in-degree teachers' top-k + hash per sample
    formula = _mhd_bytes_per_step(batch=B, topk=k, delta=K - 1)
    assert formula <= per_client_step <= 2 * formula, (per_client_step,
                                                       formula)
    # the exact byte model (H=2 heads, f16 vals, u16 idx, f32 lse) is
    # within the header/framing overhead of the measured payload
    payload = tr.meter.total_bytes / tr.meter.num_messages
    frame = topk_frame_nbytes(B, k, num_heads=2, val_bytes=2, idx_bytes=2,
                              lse_bytes=4)
    assert s_p * frame <= payload <= s_p * frame * 1.15


def test_chain_graph_trains_end_to_end():
    """Satellite: the chain's last client has no in-neighbors — it must
    fall back to supervised-only steps instead of crashing."""
    from repro.core.graph import chain_graph

    tr = _make_trainer("params", K=3, steps=4, graph=chain_graph(3))
    for t in range(4):
        m = tr.step(t)
    assert np.isfinite(m["c2/loss"])
    assert "c2/aux_dist_total" not in m  # supervised-only path
    assert "c0/aux_dist_total" in m  # connected clients still distill


def test_isolated_graph_trains_supervised_only():
    from repro.core.graph import isolated_graph

    tr = _make_trainer("params", K=2, steps=2, graph=isolated_graph(2))
    m = tr.step(0)
    loss_keys = {k for k in m if k.endswith("/ce") or k.endswith("/loss")}
    assert loss_keys == {"c0/ce", "c0/loss", "c1/ce", "c1/loss"}
    # the gate metrics report: nothing sampled, nothing skipped, no distill
    assert m["c0/stale_skipped"] == 0.0 and m["c0/distill_active"] == 0.0


def test_teacher_padding_cycles_sampled_entries():
    """Satellite: Δ > pool entries pads by cycling over the sampled
    entries (the old code repeated entry 0 forever)."""
    tr = _make_trainer("params", K=3, steps=2, delta=5, pool_size=2)
    c = tr.clients[0]
    assert len(c.pool) == 2
    entries = c.pool.sample(5)
    padded = [entries[i % len(entries)] for i in range(5)]
    assert [e.client_id for e in padded[:2]] * 2 + \
        [padded[0].client_id] == [e.client_id for e in padded]
    public = {k: jnp.asarray(v) for k, v in tr.public.sample(0).items()}
    teachers, _ = tr._stack_teachers(c, public, 0)
    assert teachers["logits"].shape[0] == 5
    # both pool clients appear among the padded teacher outputs
    t0 = np.asarray(teachers["logits"][0])
    assert any(not np.array_equal(t0, np.asarray(teachers["logits"][i]))
               for i in range(1, 5))


def test_prediction_mode_survives_total_loss():
    """100% drops ⇒ empty mailboxes ⇒ every client supervised-only, and
    the run still completes."""
    tr = _make_trainer("prediction_topk", K=2, steps=4, s_p=2,
                       comm=CommConfig(topk=4, horizon=2),
                       transport=SimulatedNetwork(drop_prob=1.0, seed=0))
    for t in range(4):
        m = tr.step(t)
    assert np.isfinite(m["c0/loss"]) and np.isfinite(m["c1/loss"])
    assert tr.meter.total_bytes > 0  # sends were metered even though lost


# ---------------------------------------------------------------------------
# meter books: format_table and snapshot round-trip
# ---------------------------------------------------------------------------

def _booked_meter() -> CommMeter:
    """A meter with all three books populated, gate stats included."""
    m = CommMeter()
    m.record(0, 0, 1, 100)
    m.record(0, 1, 0, 80)
    m.record(2, 0, 1, 100)
    m.record_delivery(1, 0, 1, 100)
    m.record_delivery(1, 1, 0, 80)
    m.record_tombstone(3, 0, 2, 64)  # dead dst: edge exists in no other book
    m.record_gate(0, fresh=3, stale=1)
    m.record_gate(1, fresh=2, stale=0)
    m.rejected_publishes = 1
    return m


def test_format_table_shows_all_three_books():
    """format_table lists offered, delivered AND tombstoned bytes — the
    tombstone-only edge (dst died mid-run) must get a row, and the totals
    line must carry the tombstoned aggregate."""
    table = _booked_meter().format_table()
    header, *rows = table.splitlines()
    assert "tombstoned" in header
    edge_rows = {r.split()[0] + r.split()[2]: r for r in rows[:-1]}
    # the tombstone-only edge 0->2 appears, with its bytes in column 3
    assert "02" in edge_rows
    assert edge_rows["02"].split()[-1] == "64"
    # offered/delivered columns survive alongside
    assert edge_rows["01"].split()[-3:] == ["200", "100", "0"]
    total = rows[-1]
    assert "64" in total and "1 tombstoned" in total


def test_meter_state_dict_roundtrip_all_books():
    """state_dict -> load_state_dict reproduces every book (offered,
    delivered, tombstoned incl. the per-edge book) and the gate stats."""
    m = _booked_meter()
    m2 = CommMeter()
    m2.load_state_dict(m.state_dict())
    assert m2.total_bytes == m.total_bytes == 280
    assert m2.delivered_bytes == m.delivered_bytes == 180
    assert m2.tombstoned_bytes == m.tombstoned_bytes == 64
    assert m2.tombstoned_messages == 1
    assert dict(m2.by_edge) == {(0, 1): 200, (1, 0): 80}
    assert dict(m2.by_edge_delivered) == {(0, 1): 100, (1, 0): 80}
    assert dict(m2.by_edge_tombstoned) == {(0, 2): 64}
    assert dict(m2.by_dst_tombstoned) == {2: 64}
    assert dict(m2.gate_fresh) == {0: 3, 1: 2}
    assert dict(m2.gate_stale) == {0: 1, 1: 0}
    assert m2.rejected_publishes == 1
    assert m2.stale_fraction(0) == 0.25
    # restored meter keeps accounting: books stay independent
    m2.record_tombstone(4, 1, 2, 10)
    assert m2.by_edge_tombstoned[(1, 2)] == 10 and m.tombstoned_bytes == 64
    assert m2.format_table() != ""


def test_meter_load_state_dict_accepts_pre_obs_snapshot():
    """SNAPSHOT_VERSION=1 fleet snapshots predate by_edge_tombstoned —
    loading one must not KeyError and must leave the book empty."""
    m = _booked_meter()
    state = m.state_dict()
    del state["by_edge_tombstoned"]
    m2 = CommMeter()
    m2.load_state_dict(state)
    assert dict(m2.by_edge_tombstoned) == {}
    assert m2.tombstoned_bytes == 64  # scalar counters still restored
