"""MoE dispatch properties (unit + hypothesis; the hypothesis test skips
itself via pytest.importorskip when the dev-only dep is absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import MoEConfig
from repro.models.moe import init_moe, moe_apply, load_balance_loss, router_topk


def _setup(E=4, K=2, D=16, F=32, cf=2.0, scoring="softmax", seed=0):
    cfg = MoEConfig(num_experts=E, top_k=K, d_ff_expert=F,
                    capacity_factor=cf)
    params = init_moe(jax.random.PRNGKey(seed), D, cfg)
    return cfg, params


def test_moe_output_shape_and_finite():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = jax.jit(lambda p, x: moe_apply(p, x, cfg))(params, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.isfinite(float(aux))


def test_moe_matches_dense_computation_at_full_capacity():
    """With capacity_factor high enough that nothing drops, the scatter
    dispatch must equal the direct per-token expert evaluation."""
    cfg, params = _setup(E=4, K=2, cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 16))
    y, _ = moe_apply(params, x, cfg)

    xf = x.reshape(-1, 16)
    logits = xf @ params["router"]
    w, ids, _ = router_topk(logits, 2)
    expected = np.zeros_like(np.asarray(xf))
    for n in range(xf.shape[0]):
        for j in range(2):
            e = int(ids[n, j])
            h = jax.nn.silu(xf[n] @ params["w_gate"][e]) * (xf[n] @ params["w_up"][e])
            expected[n] += float(w[n, j]) * np.asarray(h @ params["w_down"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 16)), expected,
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """With tiny capacity, overflowing pairs contribute nothing (not NaNs)."""
    cfg, params = _setup(E=2, K=1, cf=0.25)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    y, _ = moe_apply(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))
    # some rows must be exactly zero (dropped)
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms < 1e-6).any()


def test_router_sigmoid_weights_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(0), (10, 8)) * 2
    w, ids, probs = router_topk(logits, 3, scoring="sigmoid")
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss = 1 (E · Σ (1/E)·(1/E) · E)."""
    E = 8
    N = 800
    probs = jnp.full((N, E), 1.0 / E)
    ids = jnp.stack([jnp.arange(N) % E, (jnp.arange(N) + 1) % E], -1)
    lb = load_balance_loss(probs, ids, E)
    np.testing.assert_allclose(float(lb), 1.0, rtol=1e-5)


def test_moe_dispatch_invariants():
    """Property: outputs finite; aux in [0, weight·E]; shape preserved;
    dropping monotone in capacity (fewer drops with more capacity)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        E=st.sampled_from([2, 4, 8]),
        K=st.integers(1, 2),
        T=st.integers(2, 24),
        seed=st.integers(0, 5),
    )
    def check(E, K, T, seed):
        cfg = MoEConfig(num_experts=E, top_k=min(K, E), d_ff_expert=8,
                        capacity_factor=1.0, router_aux_weight=0.01)
        params = init_moe(jax.random.PRNGKey(seed), 8, cfg)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, T, 8))
        y, aux = moe_apply(params, x, cfg)
        assert y.shape == x.shape
        assert np.all(np.isfinite(np.asarray(y)))
        assert 0.0 <= float(aux) <= 0.01 * E * cfg.top_k * 4

    check()


def test_shared_expert_added():
    cfg = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                    num_shared_experts=1, capacity_factor=4.0)
    params = init_moe(jax.random.PRNGKey(0), 8, cfg)
    assert "shared" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 8))
    y_with, _ = moe_apply(params, x, cfg)
    p2 = dict(params)
    p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    y_zero_shared, _ = moe_apply(p2, x, cfg)
    assert float(jnp.sum(jnp.abs(y_with - y_zero_shared))) > 1e-4
