"""Sharding rules + a small-mesh dry-run executed in a subprocess (the
device-count env var must be set before jax initializes)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (4, 2)


def test_param_rules_divisibility():
    from repro.launch.shardings import param_pspec

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    class KP:
        def __init__(self, key):
            self.key = key

    mesh = _FakeMesh()
    # embed (V, D): vocab on model(2), d on data(4)
    spec = param_pspec((KP("embed"),), Leaf((512, 64)), mesh)
    assert spec == P("model", "data")
    # non-divisible vocab -> replicated on that dim
    spec = param_pspec((KP("embed"),), Leaf((511, 64)), mesh)
    assert spec == P(None, "data")
    # stacked stage param gets a leading None
    spec = param_pspec((KP("stage0"), KP("layer0"), KP("attn"), KP("wq")),
                       Leaf((8, 64, 32)), mesh)
    assert spec == P(None, "data", "model")
    # norm scales replicate
    spec = param_pspec((KP("final_norm"), KP("scale")), Leaf((64,)), mesh)
    assert spec == P()
    # moe expert weights: 3D base
    spec = param_pspec((KP("stage1"), KP("layer0"), KP("ffn"), KP("w_gate")),
                       Leaf((8, 4, 64, 128)), mesh)
    assert spec == P(None, "model", "data", None)


def test_small_mesh_dryrun_subprocess():
    """Lower+compile a reduced arch on a 2x2 mesh with 8 forced host devices
    — validates the whole shardings/steps/dryrun pipeline shape."""
    if not hasattr(jax.sharding, "AxisType"):
        pytest.skip("jax.sharding.AxisType (and jax.set_mesh) not in this "
                    "jax version; the subprocess script needs them")
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_reduced
        from repro.launch.shardings import params_shardings, batch_shardings
        from repro.launch.steps import make_train_step, train_state_shapes
        from repro.models.zoo import build_bundle
        from repro.optim.optimizers import OptimizerConfig, make_optimizer

        cfg = get_reduced("qwen2.5-32b")
        bundle = build_bundle(cfg)
        opt = make_optimizer(OptimizerConfig(total_steps=10))
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            state_shapes = train_state_shapes(bundle, opt)
            state_spec = {
                "params": params_shardings(state_shapes["params"], mesh),
                "opt": {"momentum": params_shardings(
                    state_shapes["opt"]["momentum"], mesh)},
                "step": P(),
            }
            specs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
            batch_spec = batch_shardings(specs, mesh)
            step = make_train_step(bundle, opt)
            def fn(state, batch):
                s, m = step(state, batch)
                return s, m["loss"]
            lowered = jax.jit(fn, in_shardings=(state_spec, batch_spec),
                              out_shardings=(state_spec, P())).lower(
                state_shapes, specs)
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            print(json.dumps({"ok": True,
                              "temp": ma.temp_size_in_bytes}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"]


def test_cache_shardings_rules():
    from repro.launch.shardings import cache_shardings

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    mesh = _FakeMesh()
    tree = {"stage0": {"layer0": {"attn": {
        "k": Leaf((2, 8, 64, 4, 16)),  # stacked (R,B,S,KV,hd)
        "v": Leaf((2, 8, 64, 4, 16)),
        "index": Leaf(()),
    }}}}
    specs = cache_shardings(tree, mesh)
    k_spec = specs["stage0"]["layer0"]["attn"]["k"]
    assert k_spec[0] is None          # stacked dim replicated
    assert k_spec[1] == "data"        # batch 8 % 4 == 0
    assert k_spec[3] == "model"       # kv 4 % 2 == 0
    assert specs["stage0"]["layer0"]["attn"]["index"] == P()
