"""Unit tests for core neural layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def test_rmsnorm_unit_variance():
    p = L.init_norm(64, "rmsnorm")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 10
    y = L.norm_apply(p, x, "rmsnorm")
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layernorm_moments():
    p = L.init_norm(64, "layernorm")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 5 + 3
    y = np.asarray(L.norm_apply(p, x, "layernorm"))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, rtol=1e-2)


def test_rope_preserves_norm_and_relative_property():
    hd = 32
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, hd))
    pos = jnp.arange(8)[None]
    y = L.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))
    def dot_at(p1, p2):
        rq = L.apply_rope(q, jnp.array([[p1]]))
        rv = L.apply_rope(v, jnp.array([[p2]]))
        return float(jnp.sum(rq * rv))
    assert abs(dot_at(3, 5) - dot_at(10, 12)) < 1e-4


def test_attention_causality():
    dims = L.AttnDims(d_model=32, num_heads=4, num_kv_heads=2, head_dim=8)
    p = L.init_attention(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 32))
    y1 = L.attention_apply(p, dims, x)
    x2 = x.at[:, 5:].set(0.0)
    y2 = L.attention_apply(p, dims, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :5]), np.asarray(y2[:, :5]),
                               atol=1e-5)


def test_sliding_window_matches_flash_ref():
    from repro.kernels.ref import flash_attention_ref

    dims = L.AttnDims(d_model=32, num_heads=4, num_kv_heads=4, head_dim=8)
    p = L.init_attention(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    # internal path vs reference mask construction
    y = L.attention_apply(p, dims, x, mask_kind="swa", window=4,
                          rope_theta=None)
    q, k, v = L._project_qkv(p, dims, x, x, jnp.arange(16)[None],
                             jnp.arange(16)[None], None)
    ref = flash_attention_ref(q, k, v, causal=True, window=4)
    out_ref = ref.reshape(2, 16, 32) @ p["wo"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(out_ref), atol=1e-4)


def test_blockwise_attention_equals_dense():
    dims = L.AttnDims(d_model=32, num_heads=4, num_kv_heads=2, head_dim=8)
    p = L.init_attention(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    q, k, v = L._project_qkv(p, dims, x, x, jnp.arange(64)[None],
                             jnp.arange(64)[None], 10_000.0)
    dense = L.attention_scores(q, k, v, L.make_mask(64, 64, "causal"))
    block = L._blockwise_attention(q, k, v, "causal", 0, None, block_q=16)
    np.testing.assert_allclose(np.asarray(block), np.asarray(dense),
                               atol=2e-3, rtol=1e-3)
    # sliding window too
    dense_w = L.attention_scores(q, k, v, L.make_mask(64, 64, "swa", window=8))
    block_w = L._blockwise_attention(q, k, v, "swa", 8, None, block_q=16)
    np.testing.assert_allclose(np.asarray(block_w), np.asarray(dense_w),
                               atol=2e-3, rtol=1e-3)


def test_decode_ring_buffer_matches_full():
    """Sliding-window decode with a ring cache == full attention w/ window."""
    dims = L.AttnDims(d_model=16, num_heads=2, num_kv_heads=2, head_dim=8)
    p = L.init_attention(jax.random.PRNGKey(0), dims)
    T, W = 12, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (1, T, 16))
    full = L.attention_apply(p, dims, x, mask_kind="swa", window=W)
    cache = L.init_kv_cache(1, W, 2, 8, jnp.float32)
    outs = []
    for t in range(T):
        y, cache = L.attention_decode(p, dims, x[:, t:t + 1], cache, window=W)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


def test_causal_conv_step_matches_full():
    p = L.init_causal_conv1d(jax.random.PRNGKey(0), 6, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 6))
    full = L.causal_conv1d_apply(p, x)
    state = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(10):
        y, state = L.causal_conv1d_step(p, x[:, t], state)
        outs.append(y)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), atol=1e-5)


@pytest.mark.parametrize("act", ["silu", "gelu", "relu2"])
def test_mlp_acts(act):
    p = L.init_mlp(jax.random.PRNGKey(0), 16, 32, act)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16))
    y = L.mlp_apply(p, x, act)
    assert y.shape == (3, 16)
    assert not np.any(np.isnan(np.asarray(y)))
