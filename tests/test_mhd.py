"""Unit tests for the paper's core losses (Eqs. 2, 4, 5) and gating rules.

The property-based test imports hypothesis lazily (pytest.importorskip)
so the example-based tests stay runnable without the dev-only dependency
(see requirements-dev.txt)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mhd import (
    MHDConfig,
    embedding_distillation_loss,
    multi_head_distillation_loss,
    mhd_total_loss,
    normalized,
)


def _outs(B=6, C=5, m=2, seed=0, conf_boost=None):
    """Random client outputs; conf_boost makes one candidate very confident."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    out = {
        "embedding": jax.random.normal(ks[0], (B, 8)),
        "logits": jax.random.normal(ks[1], (B, C)),
        "aux_logits": jax.random.normal(ks[2], (m, B, C)),
    }
    return out


def _teachers(delta=2, B=6, C=5, m=2, seed=10):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "embedding": jax.random.normal(ks[0], (delta, B, 8)),
        "logits": jax.random.normal(ks[1], (delta, B, C)),
        "aux_logits": jax.random.normal(ks[2], (delta, m, B, C)),
    }


def test_normalized_unit_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 37
    n = np.linalg.norm(np.asarray(normalized(x)), axis=-1)
    np.testing.assert_allclose(n, 1.0, rtol=1e-5)


def test_embedding_loss_zero_for_identical():
    e = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    loss = embedding_distillation_loss(e, jnp.stack([e * 3.0]), nu_emb=1.0)
    # scaled teacher has the same direction -> zero distance after norm
    assert float(loss) < 1e-8


def test_embedding_loss_positive_and_scales_with_nu():
    e1 = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    e2 = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 8))
    l1 = float(embedding_distillation_loss(e1, e2, 1.0))
    l3 = float(embedding_distillation_loss(e1, e2, 3.0))
    assert l1 > 0
    np.testing.assert_allclose(l3, 3 * l1, rtol=1e-6)


def test_most_confident_candidate_wins():
    """Eq. 4: if a teacher is overwhelmingly confident, the distillation
    target equals (nearly) its one-hot prediction."""
    B, C, m = 4, 5, 1
    student = _outs(B, C, m)
    teachers = _teachers(1, B, C, m)
    # make teacher main head extremely confident on class 3
    teachers["logits"] = jnp.zeros((1, B, C)).at[..., 3].set(50.0)
    cfg = MHDConfig(nu_aux=1.0, num_aux_heads=m, delta=1)
    loss, metrics = multi_head_distillation_loss(student, teachers, cfg)
    # loss should equal CE(student aux1, one-hot class 3)
    logp = jax.nn.log_softmax(student["aux_logits"][0], -1)
    expected = float(jnp.mean(-logp[:, 3]))
    np.testing.assert_allclose(float(loss), expected, rtol=1e-3)
    assert metrics["aux1_teacher_frac"] == 1.0


def test_chain_structure_levels():
    """Eq. 5: aux_k must distill from level k-1 — verify by making the
    teacher's aux1 confident; only the student's aux2 should chase it."""
    B, C, m = 4, 6, 2
    student = _outs(B, C, m)
    teachers = _teachers(1, B, C, m)
    teachers["aux_logits"] = teachers["aux_logits"].at[:, 0].set(
        jnp.zeros((1, B, C)).at[..., 2].set(60.0))
    # teacher main low-confidence everywhere; student heads low-confidence
    cfg = MHDConfig(nu_aux=1.0, num_aux_heads=m, delta=1)
    _, metrics = multi_head_distillation_loss(student, teachers, cfg)
    # for head 2 the teacher aux1 (level-1 source) is the confident one
    assert metrics["aux2_teacher_frac"] == 1.0


def test_self_target_skips_samples():
    """SF (App. B.1): when the distilled head itself is the most confident
    candidate, the sample is skipped."""
    B, C, m = 4, 5, 1
    student = _outs(B, C, m)
    student["aux_logits"] = jnp.zeros((m, B, C)).at[..., 1].set(80.0)
    teachers = _teachers(1, B, C, m)
    cfg = MHDConfig(nu_aux=1.0, num_aux_heads=m, delta=1, use_self=True)
    loss, metrics = multi_head_distillation_loss(student, teachers, cfg)
    assert metrics["aux1_keep_frac"] == 0.0
    assert float(loss) == 0.0


def test_random_confidence_needs_rng_and_differs():
    student = _outs()
    teachers = _teachers()
    cfg = MHDConfig(num_aux_heads=2, confidence="random")
    with pytest.raises(AssertionError):
        multi_head_distillation_loss(student, teachers, cfg, rng=None)
    l1, _ = multi_head_distillation_loss(student, teachers, cfg,
                                         rng=jax.random.PRNGKey(0))
    l2, _ = multi_head_distillation_loss(student, teachers, cfg,
                                         rng=jax.random.PRNGKey(1))
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))


def test_total_loss_composition():
    B, C, m = 6, 5, 2
    priv = _outs(B, C, m, seed=1)
    pub = _outs(B, C, m, seed=2)
    teachers = _teachers(2, B, C, m)
    labels = jnp.zeros((B,), jnp.int32)
    cfg = MHDConfig(nu_emb=1.0, nu_aux=3.0, num_aux_heads=m, delta=2)
    loss, metrics = mhd_total_loss(priv, labels, pub, teachers, cfg)
    recomposed = metrics["ce"] + metrics["emb_dist"] + metrics["aux_dist_total"]
    np.testing.assert_allclose(float(loss), float(recomposed), rtol=1e-6)


def test_gradients_do_not_flow_to_teachers():
    """Teachers are stop-gradiented: d loss / d teacher == 0."""
    B, C, m = 4, 5, 1
    student = _outs(B, C, m)
    teachers = _teachers(1, B, C, m)
    cfg = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=m)

    def f(tl):
        t = dict(teachers)
        t["logits"] = tl
        loss, _ = multi_head_distillation_loss(student, t, cfg)
        return loss

    g = jax.grad(f)(teachers["logits"])
    assert float(jnp.sum(jnp.abs(g))) == 0.0


def test_mhd_loss_invariants():
    """Property: loss finite & >= 0; keep fractions in [0,1]; one metric
    triple per head."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 4),
        delta=st.integers(1, 3),
        sl=st.booleans(),
        sf=st.booleans(),
    )
    def check(m, delta, sl, sf):
        B, C = 5, 7
        student = _outs(B, C, m, seed=3)
        teachers = _teachers(delta, B, C, m, seed=4)
        cfg = MHDConfig(nu_aux=2.0, num_aux_heads=m, delta=delta,
                        use_same_level=sl, use_self=sf)
        loss, metrics = multi_head_distillation_loss(student, teachers, cfg)
        assert np.isfinite(float(loss)) and float(loss) >= 0.0
        for k in range(1, m + 1):
            assert 0.0 <= float(metrics[f"aux{k}_keep_frac"]) <= 1.0
            assert 0.0 <= float(metrics[f"aux{k}_teacher_frac"]) <= 1.0

    check()
