"""Multi-process gossip over real TCP (ISSUE 4 acceptance).

A 4-client ring runs as 4 OS processes via ``TransportSpec(kind=
"socket")`` and the `launch/gossip.py` launcher: every client completes
its preset run, distills from its neighbor at least once (the exchange
actually crossed process boundaries), and the fleet-level meter books
satisfy delivered ≤ offered. Marked slow: spawning 4 jax processes
dominates the cost; the fast tier covers the same path with the
2-process smoke in scripts/check.sh.
"""
import dataclasses

import numpy as np
import pytest

from repro.exp import ExperimentSpec, get_preset
from repro.launch.gossip import fleet_summary, launch_gossip


@pytest.mark.slow
def test_four_process_ring_end_to_end():
    spec = get_preset("gossip_socket")
    results = launch_gossip(spec, timeout=280.0)
    assert set(results) == {0, 1, 2, 3}
    for rank, r in results.items():
        assert r["steps"] == spec.train.steps
        assert np.isfinite(r["final_loss"])
        # nonzero distillation on every client: mail really crossed the
        # process boundary and fed the distillation loss
        assert r["distill_steps"] >= 1, rank
        assert r["fresh_teachers"] >= 1, rank
        # every client evaluated its own model
        assert f"c{rank}/main/beta_sh" in r["eval"]
    fleet = fleet_summary(results)
    assert 0 < fleet["delivered_bytes"] <= fleet["offered_bytes"]
    assert fleet["delivered_messages"] <= fleet["offered_messages"]


@pytest.mark.slow
def test_two_process_throttled_straggler():
    """A real wall-clock straggler (rank 1 sleeps per step) finishes its
    own run without stalling rank 0 — nobody waits for anybody."""
    spec = get_preset("gossip_socket")
    spec = dataclasses.replace(
        spec,
        clients=ExperimentSpec.uniform_fleet(
            2, aux_heads=spec.clients[0].aux_heads),
        train=dataclasses.replace(spec.train, steps=10))
    results = launch_gossip(spec, timeout=150.0,
                            throttle_ms={1: 100.0})
    assert results[1]["wall_seconds"] >= 1.0  # 10 steps x 100ms floor
    assert results[0]["distill_steps"] >= 1
    assert results[1]["distill_steps"] >= 1
    fleet = fleet_summary(results)
    assert fleet["delivered_bytes"] <= fleet["offered_bytes"]


@pytest.mark.slow
def test_crash_is_reaped_promptly_and_fleet_resumes(tmp_path):
    """ISSUE 5: a child crashing mid-run fails the launch immediately
    with its rank + exit status (not the hard-timeout backstop), and a
    ``resume=True`` relaunch restores every rank from its own fleet
    snapshot — the crashed rank restarts from its last save and distills
    again post-restore."""
    import time

    spec = get_preset("gossip_socket")
    spec = dataclasses.replace(
        spec,
        clients=ExperimentSpec.uniform_fleet(
            3, aux_heads=spec.clients[0].aux_heads),
        init_scheme="per_client",
        train=dataclasses.replace(spec.train, steps=8,
                                  snapshot_dir=str(tmp_path),
                                  snapshot_every=3))
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="client 1 died"):
        launch_gossip(spec, timeout=240.0, die_at={1: 5})
    assert time.monotonic() - t0 < 120.0  # reaped, not timed out

    results = launch_gossip(spec, timeout=240.0, resume=True)
    assert results[1]["start_step"] >= 3  # really restored, not fresh
    assert results[1]["distill_steps"] >= 1  # distills post-restore
    for rank, r in results.items():
        assert np.isfinite(r["final_loss"]), rank


def test_launch_rejects_non_socket_spec():
    spec = get_preset("gossip")  # simulated transport
    with pytest.raises(ValueError, match="socket"):
        launch_gossip(spec)


def test_launch_rejects_async_schedule():
    """Multi-process step rates are real wall-clock differences; a spec
    asking for simulated ScheduleSpec rates must fail loudly instead of
    being silently reinterpreted."""
    from repro.exp import ScheduleSpec

    spec = get_preset("gossip_socket")
    spec = dataclasses.replace(
        spec, schedule=ScheduleSpec(mode="async", rates=(1, 1, 1, 4)))
    with pytest.raises(ValueError, match="wall-clock"):
        launch_gossip(spec)
