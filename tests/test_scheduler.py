"""Tests for the scoreboard fleet scheduler (`core/scheduler.py`):
lockstep and out-of-order policies' bitwise equivalence with the
synchronous trainer, bounded run-ahead backpressure, snapshot/resume
under rate skew, bounded-staleness gating (stale mail → supervised
fallback, never a crash), per-client bus clocks, and the empty-mailbox
staleness sentinel."""
import jax
import numpy as np
import pytest

from repro.comm import CommConfig, LoopbackTransport, PredictionBus, \
    SimulatedNetwork
from repro.core import AsyncScheduler, ScheduleConfig, \
    ScoreboardScheduler, run_async
from repro.core.graph import chain_graph, cycle_graph, isolated_graph

from test_comm import _make_trainer


def _params_bitwise_equal(clients_a, clients_b) -> bool:
    for ca, cb in zip(clients_a, clients_b):
        eq = jax.tree.map(
            lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
            ca.params, cb.params)
        if not all(jax.tree.leaves(eq)):
            return False
    return True


# ---------------------------------------------------------------------------
# schedule config
# ---------------------------------------------------------------------------

def test_schedule_config_validation():
    with pytest.raises(ValueError):
        ScheduleConfig(rates=())
    with pytest.raises(ValueError):
        ScheduleConfig(rates=(1, 0))
    with pytest.raises(ValueError):
        ScheduleConfig(rates=(1, 1.5))
    assert ScheduleConfig.uniform(3).rates == (1, 1, 1)
    assert ScheduleConfig.skewed(4, slow_rate=4).rates == (1, 1, 1, 4)
    assert ScheduleConfig.skewed(4, 4, num_slow=2).max_rate == 4


def test_scheduler_rejects_rate_count_mismatch():
    tr = _make_trainer("params", K=3, steps=2)
    with pytest.raises(ValueError):
        AsyncScheduler(tr, ScheduleConfig(rates=(1, 1)))


# ---------------------------------------------------------------------------
# lockstep equivalence (acceptance)
# ---------------------------------------------------------------------------

def test_async_equals_sync_params_mode_bitwise():
    """Equal rates ⇒ every tick replays the synchronous step exactly:
    identical metrics and bitwise-identical final params."""
    steps = 6
    t_sync = _make_trainer("params", steps=steps, delta=2, m=1, s_p=2)
    t_async = _make_trainer("params", steps=steps, delta=2, m=1, s_p=2)
    sched = AsyncScheduler(t_async)
    for t in range(steps):
        m_sync, m_async = t_sync.step(t), sched.tick()
        for key, v in m_sync.items():
            assert m_async[key] == v, (t, key)
    assert _params_bitwise_equal(t_sync.clients, t_async.clients)


def test_async_equals_sync_prediction_mode_bitwise():
    """Acceptance: async scheduler with equal rates + lossless zero-latency
    transport + unbounded staleness is bitwise-equal to the synchronous
    prediction-exchange trainer."""
    steps = 6
    kw = dict(steps=steps, delta=1, m=1, s_p=2,
              comm=CommConfig(topk=8, val_dtype="float32",
                              emb_encoding="float32", horizon=steps + 4))
    t_sync = _make_trainer("prediction_topk", **kw)
    t_async = _make_trainer("prediction_topk", **kw)
    sched = AsyncScheduler(t_async, ScheduleConfig.uniform(3))
    for t in range(steps):
        m_sync, m_async = t_sync.step(t), sched.tick()
        for key, v in m_sync.items():
            assert m_async[key] == v, (t, key)
    assert _params_bitwise_equal(t_sync.clients, t_async.clients)
    assert t_sync.meter.total_bytes == t_async.meter.total_bytes


def test_scoreboard_equals_sync_prediction_mode_bitwise():
    """The non-negotiable anchor: the out-of-order policy with equal
    rates + lossless zero-latency transport + unbounded staleness and
    run-ahead issues in exact key order — bitwise-equal to the
    synchronous ``step()`` loop, metrics and params."""
    steps = 6
    kw = dict(steps=steps, delta=1, m=1, s_p=2,
              comm=CommConfig(topk=8, val_dtype="float32",
                              emb_encoding="float32", horizon=steps + 4))
    t_sync = _make_trainer("prediction_topk", **kw)
    t_sb = _make_trainer("prediction_topk", **kw)
    sched = ScoreboardScheduler(t_sb, ScheduleConfig.uniform(3))
    for t in range(steps):
        m_sync, m_sb = t_sync.step(t), sched.tick()
        for key, v in m_sync.items():
            assert m_sb[key] == v, (t, key)
    assert _params_bitwise_equal(t_sync.clients, t_sb.clients)
    assert t_sync.meter.total_bytes == t_sb.meter.total_bytes


def test_scoreboard_equals_lockstep_under_rate_skew_bitwise():
    """Without gates, out-of-order issue picks the lowest-keyed ready op
    — the same total order the lockstep policy walks. Rate skew included:
    both policies must produce identical params and step counts."""
    ticks = 12
    kw = dict(K=3, steps=ticks, s_p=2,
              comm=CommConfig(topk=4, horizon=8))
    t_lock = _make_trainer("prediction_topk", **kw)
    t_sb = _make_trainer("prediction_topk", **kw)
    lock = AsyncScheduler(t_lock, ScheduleConfig(rates=(1, 1, 4)))
    sb = ScoreboardScheduler(t_sb, ScheduleConfig(rates=(1, 1, 4)))
    for _ in range(ticks):
        m_lock, m_sb = lock.tick(), sb.tick()
        assert m_lock == m_sb
    assert lock.local_steps == sb.local_steps == [12, 12, 3]
    assert _params_bitwise_equal(t_lock.clients, t_sb.clients)


# ---------------------------------------------------------------------------
# heterogeneous rates
# ---------------------------------------------------------------------------

def test_rate_skew_steps_clients_at_their_own_cadence():
    """A 4× client takes a quarter of the local steps and reports
    `local_step`; fast clients are unaffected by its presence."""
    tr = _make_trainer("params", K=3, steps=8)
    sched = AsyncScheduler(tr, ScheduleConfig(rates=(1, 1, 4)))
    seen_c2 = 0
    for w in range(8):
        m = sched.tick()
        assert ("c2/loss" in m) == (w % 4 == 0)
        seen_c2 += int("c2/loss" in m)
        assert "c0/loss" in m and "c1/loss" in m
    assert sched.local_steps == [8, 8, 2]
    assert seen_c2 == 2


def test_runahead_backpressure_gates_and_releases():
    """Deterministic bounded run-ahead: freeze a straggler at 2 local
    steps (run_until_steps target) — fast clients issue ahead until the
    credit window closes at wall ``2 + runahead`` and then stall (no
    busy-looping on future comm rounds). Raising the straggler's target
    reopens the window and the gated clients issue again, booked as
    backpressure."""
    tr = _make_trainer("prediction_topk", K=3, steps=10, s_p=2,
                       comm=CommConfig(topk=4, horizon=12))
    sched = ScoreboardScheduler(tr, ScheduleConfig.uniform(3, runahead=4))
    sched.run_until_steps((100, 100, 2))
    # steps at walls 0..(2+4) issue; wall 7 exceeds the window
    assert sched.local_steps == [7, 7, 2]
    sched.run_until_steps((10, 10, 10))
    assert sched.local_steps == [10, 10, 10]
    assert sched.stats["backpressure_events"] > 0


def test_paced_straggler_is_overtaken_not_waited_on():
    """The lockstep barrier this refactor removes: with a real-time paced
    straggler, ready ops of *other* clients issue past its gated ones
    instead of queueing behind the pace deadline."""
    tr = _make_trainer("prediction_topk", K=3, steps=8, s_p=2,
                       comm=CommConfig(topk=4, horizon=12))
    sched = ScoreboardScheduler(
        tr, ScheduleConfig.uniform(3, pace_s=(0.0, 0.0, 0.25)))
    sched.run_until_steps((6, 6, 2))
    assert sched.local_steps == [6, 6, 2]
    assert sched.stats["overtakes"] > 0  # ready ops passed the paced one
    # every client's completion wall-clock is stamped (benchmarks read it)
    assert all(ts > 0.0 for ts in sched.resolved_at)


def test_scheduler_state_dict_roundtrip_and_legacy():
    """`state_dict` captures wall, step counts, issue cursors and the
    pump; `load_state_dict` restores them exactly — and still accepts the
    pre-scoreboard clock-only snapshot format, deriving the cursors."""
    rates = (1, 1, 4)
    kw = dict(K=3, steps=8, s_p=2, comm=CommConfig(topk=4, horizon=12))
    tr = _make_trainer("prediction_topk", **kw)
    sched = AsyncScheduler(tr, ScheduleConfig(rates))
    for _ in range(6):
        sched.tick()
    state = sched.state_dict()
    assert state["mode"] == "lockstep" and state["wall"] == 6
    sched2 = AsyncScheduler(_make_trainer("prediction_topk", **kw),
                            ScheduleConfig(rates))
    sched2.load_state_dict(state)
    assert sched2.state_dict() == state
    # legacy clock-only snapshot: cursors reconstructed from the clocks
    sched3 = AsyncScheduler(_make_trainer("prediction_topk", **kw),
                            ScheduleConfig(rates))
    sched3.load_state_dict({"wall": 6, "local_steps": [6, 6, 2]})
    assert sched3.state_dict() == state


def test_rate_skewed_lossy_run_completes_with_metrics():
    """Acceptance: a rate-skewed lossy run completes without error while
    reporting per-client staleness/skip metrics."""
    net = SimulatedNetwork(latency=1, bandwidth=32 * 1024, drop_prob=0.25,
                           seed=3, client_rates={2: 4})
    tr = _make_trainer("prediction_topk", K=3, steps=16, s_p=2,
                       graph=cycle_graph(3),
                       comm=CommConfig(topk=4, horizon=4), transport=net)
    tr.run_cfg.max_staleness = 5
    # horizon 4 < the straggler's 8-tick publish gap: the scheduler warns
    # about the coverage hole instead of failing silently
    with pytest.warns(UserWarning, match="publish gap"):
        sched = AsyncScheduler(tr, ScheduleConfig(rates=(1, 1, 4)))
    for _ in range(16):
        m = sched.tick()
        for key in ("loss", "stale_skipped", "mail_staleness"):
            assert f"c0/{key}" in m
        assert np.isfinite(m["c0/loss"])
    # the staleness gate actually fired somewhere in this lossy run
    assert sum(tr.meter.gate_stale.values()) > 0
    report = sched.freshness_report()
    assert report[2]["clock"] == 12.0  # slow client last stepped at tick 12
    assert report[0]["clock"] == 15.0
    assert all(r["fresh"] <= r["mailbox"] for r in report.values())


# ---------------------------------------------------------------------------
# bounded-staleness gating: supervised fallback, never a crash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph_fn", [chain_graph, cycle_graph,
                                      isolated_graph])
def test_stale_mail_falls_back_to_supervised(graph_fn):
    """max_staleness=0 with a 2-tick-latency transport means no mail is
    ever fresh enough: every client must run supervised-only steps on any
    topology, with no exception raised."""
    tr = _make_trainer("prediction_topk", K=3, steps=6, s_p=2,
                       graph=graph_fn(3),
                       comm=CommConfig(topk=4, horizon=8),
                       transport=SimulatedNetwork(latency=2, seed=0))
    tr.run_cfg.max_staleness = 0
    sched = AsyncScheduler(tr)
    for _ in range(6):
        m = sched.tick()
        for cid in range(3):
            assert m[f"c{cid}/distill_active"] == 0.0
            assert np.isfinite(m[f"c{cid}/loss"])


@pytest.mark.parametrize("graph_fn", [chain_graph, cycle_graph,
                                      isolated_graph])
def test_unbounded_staleness_never_crashes(graph_fn):
    """The same topologies with the gate wide open and a lossy link also
    complete; connected clients eventually distill."""
    tr = _make_trainer("prediction_topk", K=3, steps=6, s_p=2,
                       graph=graph_fn(3),
                       comm=CommConfig(topk=4, horizon=8),
                       transport=SimulatedNetwork(drop_prob=0.5, seed=1))
    sched = run_async(tr, 6)
    assert sched.wall == 6


def test_params_mode_staleness_gate():
    """The gate also applies to legacy param pools: entries older than the
    bound are skipped and counted in `stale_skipped`."""
    tr = _make_trainer("params", K=3, steps=8, s_p=100)  # pools never refresh
    tr.run_cfg.max_staleness = 2
    sched = AsyncScheduler(tr)
    m = None
    for _ in range(6):
        m = sched.tick()
    # seed entries are from step 0; at t=5 they exceed max_staleness=2
    assert all(m[f"c{cid}/distill_active"] == 0.0 for cid in range(3))
    assert sum(m[f"c{cid}/stale_skipped"] for cid in range(3)) > 0


# ---------------------------------------------------------------------------
# bus clocks + staleness sentinel
# ---------------------------------------------------------------------------

def test_freshness_report_explicit_none_requests_unbounded_view():
    """Regression (ISSUE 4 satellite): an explicit ``max_staleness=None``
    must mean *unbounded*, not silently fall back to the trainer's
    configured bound. Only a missing argument uses ``run_cfg``."""
    tr = _make_trainer("prediction_topk", K=2, steps=4, s_p=2,
                       comm=CommConfig(topk=4, horizon=8),
                       transport=SimulatedNetwork(latency=1, seed=0))
    tr.run_cfg.max_staleness = 0  # 1-tick latency: no mail is ever fresh
    sched = AsyncScheduler(tr)
    for _ in range(4):
        sched.tick()
    bounded = sched.freshness_report()  # default: the configured bound
    unbounded = sched.freshness_report(None)  # explicit: the whole mailbox
    for cid in range(2):
        assert bounded[cid]["mailbox"] > 0  # mail exists...
        assert bounded[cid]["fresh"] == 0.0  # ...but none passes bound 0
        assert unbounded[cid]["fresh"] == unbounded[cid]["mailbox"]


def test_bus_clock_advance_is_monotone():
    bus = PredictionBus(LoopbackTransport(), [(1,), (0,)], 2)
    assert bus.clock(0) == 0
    bus.advance(0, 5)
    bus.advance(0, 3)  # stale advance: no-op
    assert bus.clock(0) == 5


def test_bus_poll_fresh_filters_by_client_clock():
    bus = PredictionBus(LoopbackTransport(), [(1,), (0,)], 2)
    bus.publish(1, b"m", step=2)
    bus.deliver(2)
    bus.advance(0, 10)
    assert set(bus.poll_fresh(0, None)) == {1}  # unbounded
    assert set(bus.poll_fresh(0, 8)) == {1}  # age 8 <= 8
    assert bus.poll_fresh(0, 7) == {}  # age 8 > 7
    assert bus.poll_fresh(1, 0) == {}  # empty mailbox


def test_bus_staleness_empty_mailbox_sentinel():
    """Regression (ISSUE 2 satellite): `bus.staleness()` on a mailbox that
    has never received mail returns the documented -1.0 sentinel instead
    of a value indistinguishable from perfectly fresh mail."""
    bus = PredictionBus(LoopbackTransport(), [(1,), (0,)], 2)
    assert bus.staleness(0, 0) == bus.EMPTY_STALENESS == -1.0
    bus.publish(1, b"m", step=0)
    bus.deliver(0)
    assert bus.staleness(0, 3) == 3.0  # real mail: real staleness
    assert bus.staleness(1, 3) == -1.0  # client 1 still has no mail


def test_runtime_reports_sentinel_for_mailless_client():
    """A chain's sink client never receives mail — its `mail_staleness`
    metric must be the sentinel from the very first step, not garbage."""
    tr = _make_trainer("prediction_topk", K=3, steps=2, s_p=2,
                       graph=chain_graph(3),
                       comm=CommConfig(topk=4, horizon=4))
    m = tr.step(0)
    assert m["c2/mail_staleness"] == -1.0
    assert m["c0/mail_staleness"] >= 0.0  # c0 has mail from c1
