"""Transport conformance suite + socket transport specifics.

Every `Transport` (loopback, simulated, socket) must honor the same
contract the runtime's drain points rely on:

  * FIFO per directed edge,
  * no delivery before the caller's tick (``sent_step <= step``),
  * poll is a drain: a second poll at the same step returns nothing,
  * polling an unknown/unhosted destination returns [].

Plus the acceptance test of the socket transport: a 2-client gossip run
over real TCP (in-process, deterministic drain) reproduces the loopback
run's teacher schedule *bitwise*, and the delivered-vs-offered meter
split (ISSUE 4 satellites) books drops on the sender only.
"""
import time

import jax
import numpy as np
import pytest

from repro.comm import (
    CommConfig,
    CommMeter,
    LoopbackTransport,
    PredictionBus,
    SimulatedNetwork,
    SocketTransport,
    allocate_ports,
)

from test_comm import _make_trainer


@pytest.fixture(params=["loopback", "simulated", "socket"])
def transport(request):
    """A lossless, effectively-zero-latency instance of each kind."""
    if request.param == "loopback":
        yield LoopbackTransport()
    elif request.param == "simulated":
        yield SimulatedNetwork()
    else:
        t = SocketTransport(num_clients=4)
        yield t
        t.close()


# ---------------------------------------------------------------------------
# the shared contract
# ---------------------------------------------------------------------------

def test_fifo_per_edge(transport):
    for i in range(5):
        transport.send(0, 1, f"m{i}".encode(), step=i)
    got = transport.poll(1, 10)
    assert [d.payload for d in got] == [f"m{i}".encode() for i in range(5)]
    assert [d.sent_step for d in got] == list(range(5))


def test_no_delivery_before_sent_step(transport):
    transport.send(0, 1, b"future", step=5)
    assert transport.poll(1, 3) == []
    got = transport.poll(1, 5)
    assert [d.payload for d in got] == [b"future"]
    assert got[0].recv_step == 5


def test_poll_is_a_drain(transport):
    transport.send(0, 1, b"once", step=0)
    assert len(transport.poll(1, 0)) == 1
    assert transport.poll(1, 0) == []
    assert transport.poll(1, 100) == []


def test_multiple_senders_all_arrive(transport):
    transport.send(0, 1, b"from0", step=0)
    transport.send(2, 1, b"from2", step=0)
    transport.send(3, 1, b"from3", step=1)
    got = transport.poll(1, 2)
    assert {(d.src, d.payload) for d in got} == {
        (0, b"from0"), (2, b"from2"), (3, b"from3")}


def test_unknown_destination_returns_empty(transport):
    assert transport.poll(9, 0) == []


# ---------------------------------------------------------------------------
# socket transport specifics
# ---------------------------------------------------------------------------

def test_socket_cross_instance_over_tcp():
    """Two transport instances (the multi-process shape, minus the
    processes): a frame sent by one arrives at the other over real TCP,
    carrying src and sent_step through the frame header."""
    with SocketTransport(2, clients=[1], wait_inflight=False) as b, \
            SocketTransport(2, clients=[0], ports={1: b.ports[1]},
                            wait_inflight=False) as a:
        a.send(0, 1, b"x" * 70000, step=3)  # bigger than one recv() chunk
        deadline = time.monotonic() + 10
        got = []
        while not got and time.monotonic() < deadline:
            got = b.poll(1, 10)
        assert [(d.src, d.sent_step) for d in got] == [(0, 3)]
        assert got[0].payload == b"x" * 70000
        assert a.sent_bytes == b.recv_bytes == 70000


def test_socket_set_ports_and_connect_edges():
    """The two-phase rendezvous: hosts bind port 0, learn peers' ports
    later, and eagerly open the graph's edges."""
    with SocketTransport(2, clients=[0], wait_inflight=False) as a, \
            SocketTransport(2, clients=[1], wait_inflight=False) as b:
        ports = {0: a.ports[0], 1: b.ports[1]}
        a.set_ports(ports)
        b.set_ports(ports)
        a.connect_edges([(1,), (0,)])  # ring: 0 sends to 1
        assert (0, 1) in a._out
        with pytest.raises(ValueError):
            a.set_ports({0: a.ports[0] + 1})  # hosted port can't move


def test_spec_validation_rejects_sim_knobs_on_socket():
    """Per-kind validation rides on the TRANSPORTS registry entry: socket
    specs carrying simulated-network knobs fail loudly at validate()."""
    import dataclasses

    from repro.exp import ExperimentSpec, TransportSpec, WireSpec

    spec = ExperimentSpec(
        transport=TransportSpec(kind="socket", drop_prob=0.1),
        wire=WireSpec(exchange="prediction_topk"))
    with pytest.raises(ValueError, match="real wire"):
        spec.validate()
    ok = dataclasses.replace(spec, transport=TransportSpec(kind="socket"))
    ok.validate()
    with pytest.raises(ValueError, match="unknown transport kind"):
        dataclasses.replace(
            spec, transport=TransportSpec(kind="carrier_pigeon")).validate()
    # and symmetrically: socket-only fields on an in-process transport
    with pytest.raises(ValueError, match="silently ignore"):
        dataclasses.replace(spec, transport=TransportSpec(
            kind="simulated", base_port=9000)).validate()


def test_socket_rejects_unknown_peer_port():
    with SocketTransport(3, clients=[0], wait_inflight=False) as t:
        with pytest.raises(ValueError, match="no port known"):
            t.send(0, 2, b"?", step=0)


def test_socket_inprocess_big_frame_no_deadlock():
    """Single-threaded in-process mode writes and reads the same socket
    pair: a frame larger than the kernel's socket buffers must not
    deadlock sendall (the send path drains the local destination while
    writing)."""
    with SocketTransport(2) as t:
        big = bytes(range(256)) * (16 * 1024)  # 4 MiB
        t.send(0, 1, big, step=0)
        got = t.poll(1, 0)
        assert len(got) == 1
        assert got[0].payload == big


def test_socket_drops_corrupt_connection_not_the_run():
    """A stray localhost connection writing non-protocol bytes (port
    scanner, recycled ephemeral port) is dropped; the receiver's loop
    never sees an exception and real peers keep working."""
    import socket as pysocket

    with SocketTransport(2, clients=[1], wait_inflight=False) as t:
        stray = pysocket.create_connection(("127.0.0.1", t.ports[1]))
        stray.sendall(b"GET / HTTP/1.1\r\n" + b"\x00" * 64)
        deadline = time.monotonic() + 5
        while t.corrupt_connections == 0 and time.monotonic() < deadline:
            assert t.poll(1, 0) == []  # garbage never becomes a delivery
        assert t.corrupt_connections == 1
        stray.close()
        # a real peer on a fresh connection still gets through
        with SocketTransport(2, clients=[0], ports={1: t.ports[1]},
                             wait_inflight=False) as a:
            a.send(0, 1, b"still-works", step=0)
            got = []
            while not got and time.monotonic() < deadline:
                got = t.poll(1, 0)
            assert [d.payload for d in got] == [b"still-works"]


def test_allocate_ports_are_distinct_and_bindable():
    ports = allocate_ports(4)
    assert len(set(ports.values())) == 4
    with SocketTransport(4, clients=[2], ports={2: ports[2]}) as t:
        assert t.ports[2] == ports[2]


def test_socket_send_to_dead_peer_is_lost_not_fatal():
    """A peer process that exited mid-run looks like a dropped message,
    never a sender crash (real networks lose packets; so do we)."""
    b = SocketTransport(2, clients=[1], wait_inflight=False)
    a = SocketTransport(2, clients=[0], ports={1: b.ports[1]},
                        wait_inflight=False)
    a.send(0, 1, b"first", step=0)
    b.close()
    time.sleep(0.2)  # let the peer's RST reach the sender
    # the kernel may accept a few frames into dead buffers before
    # surfacing ECONNRESET; what matters is that send never raises
    for i in range(50):
        a.send(0, 1, b"x" * 4096, step=i)
    assert a.failed_sends > 0
    a.close()


# ---------------------------------------------------------------------------
# acceptance: socket == loopback teacher schedule (2-client gossip)
# ---------------------------------------------------------------------------

def test_socket_matches_loopback_teacher_schedule():
    """A 2-client prediction-exchange run over real TCP (in-process,
    deterministic drain) is bitwise-equal to the loopback run: same
    step metrics, same final params, same meter books."""
    steps = 6
    kw = dict(steps=steps, K=2, delta=1, m=1, s_p=2,
              comm=CommConfig(topk=8, val_dtype="float32",
                              emb_encoding="float32", horizon=steps + 4))
    t_loop = _make_trainer("prediction_topk", **kw)
    sock = SocketTransport(2)
    try:
        t_sock = _make_trainer("prediction_topk", transport=sock, **kw)
        for t in range(steps):
            m_loop, m_sock = t_loop.step(t), t_sock.step(t)
            for key, v in m_loop.items():
                assert m_sock[key] == v, (t, key)
        for ca, cb in zip(t_loop.clients, t_sock.clients):
            eq = jax.tree.map(
                lambda a, b: bool(np.array_equal(np.asarray(a),
                                                 np.asarray(b))),
                ca.params, cb.params)
            assert all(jax.tree.leaves(eq))
        assert t_loop.meter.total_bytes == t_sock.meter.total_bytes
        assert t_loop.meter.delivered_bytes == t_sock.meter.delivered_bytes
        assert t_sock.meter.delivered_bytes == t_sock.meter.total_bytes
    finally:
        sock.close()


# ---------------------------------------------------------------------------
# delivered-vs-offered metering (satellite)
# ---------------------------------------------------------------------------

def test_meter_books_drops_as_offered_not_delivered():
    """bus.publish meters the send (sender-side cost); bus.deliver meters
    the arrival. A 100%-drop link therefore shows offered > 0 but zero
    delivered traffic — and `received_per_client_step` excludes drops."""
    meter = CommMeter()
    bus = PredictionBus(SimulatedNetwork(drop_prob=1.0, seed=0),
                        [(1,), (0,)], 2, meter=meter)
    bus.publish(1, b"lost-message", step=0)
    bus.deliver(0)
    assert meter.total_bytes == len(b"lost-message")  # offered
    assert meter.delivered_bytes == 0
    assert meter.by_dst[0] == len(b"lost-message")  # sender-side book
    assert meter.received_per_client_step(10) == {}  # no student paid
    assert bus.mailbox(0) == {}


def test_meter_lossless_books_agree():
    meter = CommMeter()
    bus = PredictionBus(LoopbackTransport(), [(1,), (0,)], 2, meter=meter)
    bus.publish(1, b"abcdef", step=0)
    bus.publish(0, b"xy", step=0)
    bus.deliver(0)
    assert meter.delivered_bytes == meter.total_bytes == 8
    assert meter.by_dst_delivered == {0: 6, 1: 2}
    assert meter.received_per_client_step(2) == {0: 3.0, 1: 1.0}
    s = meter.summary()
    assert s["delivered_bytes"] == s["total_bytes"] == 8.0


def test_meter_partial_drops_delivered_below_offered():
    """A lossy run keeps delivered strictly between 0 and offered, and
    the per-student figure reads the delivered book."""
    meter = CommMeter()
    net = SimulatedNetwork(drop_prob=0.5, seed=3)
    bus = PredictionBus(net, [(1,), (0,)], 2, meter=meter)
    for t in range(40):
        bus.publish(0, b"p" * 10, step=t)
        bus.publish(1, b"q" * 10, step=t)
        bus.deliver(t)
    assert 0 < meter.delivered_bytes < meter.total_bytes
    assert meter.delivered_bytes == meter.total_bytes - 10 * net.dropped_count
    per_student = meter.received_per_client_step(40)
    assert per_student[1] == meter.by_dst_delivered[1] / 40


# ---------------------------------------------------------------------------
# per-tick delivered == offered on a lossless wire (all transports)
# ---------------------------------------------------------------------------

def test_per_tick_delivered_equals_offered(transport):
    """The headline delivery invariant: on a lossless localhost wire the
    meter's per-edge delivered book must equal the offered book after
    EVERY tick's publish + deliver — for all three transports (the socket
    transport in in-process deterministic mode). A frame stranded in a
    queue or kernel buffer across a tick boundary shows up here as a
    per-edge gap."""
    meter = CommMeter()
    ring = [(3,), (0,), (1,), (2,)]  # adj[dst] = in-neighbors
    bus = PredictionBus(transport, ring, 4, meter=meter)
    for t in range(5):
        for src in range(4):
            bus.publish(src, f"tick{t}-from{src}".encode(), step=t)
        bus.deliver(t)
        assert meter.by_edge == meter.by_edge_delivered, f"gap at tick {t}"
    assert meter.delivered_bytes == meter.total_bytes > 0


# ---------------------------------------------------------------------------
# finish-barrier stranding + drain-stall retry (regression)
# ---------------------------------------------------------------------------

_DRAIN_ALL = 1 << 60  # the finish barrier's release-everything poll step


def test_finish_barrier_strands_no_frames():
    """Regression for the delivery-loss bug: a frame in flight at exit —
    arrived on the wire but held back by poll's no-delivery-before-tick
    rule — must be fully drainable: ``quiesce`` pulls it out of the
    kernel/parse buffers, the drain-all poll releases it, and the sender/
    receiver frame counts (the finish barrier's reconciliation data)
    agree. Before the count-based barrier, exactly this frame was counted
    offered-but-never-delivered."""
    with SocketTransport(2, clients=[1], wait_inflight=False) as b, \
            SocketTransport(2, clients=[0], ports={1: b.ports[1]},
                            wait_inflight=False) as a:
        a.send(0, 1, b"held-back", step=99)  # sent for a future tick
        deadline = time.monotonic() + 10
        while b.recv_count < 1 and time.monotonic() < deadline:
            b.quiesce(settle=0.01, timeout=1.0)
        assert dict(a.sent_to) == {1: 1}
        assert b.recv_count == 1          # arrived and parsed...
        assert b.poll(1, 0) == []         # ...but held back at tick 0
        assert b.undrained_bytes == 0     # nothing left half-parsed
        got = b.poll(1, _DRAIN_ALL)       # the finish barrier's release
        assert [(d.src, d.payload) for d in got] == [(0, b"held-back")]


def test_drain_stall_retries_instead_of_dropping():
    """A receiver that stops reading long enough to fill the kernel
    buffers (e.g. stuck in a 20s+ jit compile) must NOT cost frames: the
    sender's bounded-retry loop meters ``drain_stalls`` and keeps the
    frame in flight until the receiver catches up — only the launcher's
    hard timeout is fatal."""
    import threading

    with SocketTransport(2, clients=[1], wait_inflight=False) as b, \
            SocketTransport(2, clients=[0], ports={1: b.ports[1]},
                            wait_inflight=False, drain_timeout=0.05,
                            send_hard_timeout=30.0) as a:
        big = b"z" * (32 * 1024 * 1024)  # far beyond the kernel buffers

        def drain_later():
            time.sleep(0.5)  # let the sender hit at least one stall
            deadline = time.monotonic() + 20
            while b.recv_count < 1 and time.monotonic() < deadline:
                b.quiesce(settle=0.01, timeout=1.0)

        th = threading.Thread(target=drain_later)
        th.start()
        a.send(0, 1, big, step=0)  # blocks past drain_timeout, retries
        th.join()
        assert a.failed_sends == 0
        assert a.drain_stalls >= 1
        got = b.poll(1, _DRAIN_ALL)
        assert [d.payload == big for d in got] == [True]


# ---------------------------------------------------------------------------
# dropped sends still occupy the uplink (satellite)
# ---------------------------------------------------------------------------

def _seed_with_drop_then_keep(p=0.5):
    """A seed whose first rng draw drops and second keeps."""
    for seed in range(1000):
        r = np.random.default_rng(seed)
        if r.random() < p <= r.random():
            return seed
    raise AssertionError("no such seed in range")


def test_dropped_message_still_occupies_uplink():
    """Regression: a dropped message's transmit time still serializes the
    edge — the sender spends the bytes whether or not the wire delivers
    them, so the next message is delayed behind the drop."""
    seed = _seed_with_drop_then_keep()
    net = SimulatedNetwork(bandwidth=10, drop_prob=0.5, seed=seed)
    net.send(0, 1, b"x" * 30, step=0)  # dropped; tx 3 steps holds the edge
    net.send(0, 1, b"y" * 10, step=0)  # kept; starts at 3, arrives at 4
    assert net.dropped_count == 1
    assert net.poll(1, 3) == []
    got = net.poll(1, 4)
    assert [d.payload for d in got] == [b"y" * 10]
    # determinism: the same seed replays the same schedule
    net2 = SimulatedNetwork(bandwidth=10, drop_prob=0.5, seed=seed)
    net2.send(0, 1, b"x" * 30, step=0)
    net2.send(0, 1, b"y" * 10, step=0)
    assert [d.payload for d in net2.poll(1, 4)] == [b"y" * 10]
