"""End-to-end behaviour tests for the paper's system (integration).

The heavier qualitative reproductions (MHD vs Separate vs FedAvg orderings,
topology effects, head-count sweeps) live in benchmarks/; here we verify the
decentralized runtime *mechanically works end-to-end* and that distillation
measurably transfers knowledge in a small controlled run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end training runs

from repro.core import (
    MHDConfig,
    DecentralizedTrainer,
    RunConfig,
    complete_graph,
    cycle_graph,
)
from repro.data import PartitionConfig, make_synthetic_vision, partition_dataset
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def _setup(K=2, labels=8, skew=1000.0, steps=40, aux_heads=2, seed=0,
           noise=0.5):
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=40,
                               image_size=8, noise=noise, seed=seed)
    test = make_synthetic_vision(num_labels=labels, samples_per_label=10,
                                 image_size=8, noise=noise, seed=seed + 99,
                                 prototype_seed=seed)
    pcfg = PartitionConfig(num_clients=K, num_labels=labels,
                           labels_per_client=labels // K, skew=skew,
                           gamma_pub=0.15, seed=seed)
    part = partition_dataset(ds.labels, pcfg)
    arrays = {"images": ds.images, "labels": ds.labels}
    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=aux_heads))
               for _ in range(K)]
    # calibrated regime (benchmarks/common.py): nu_aux=1 + clipping — the
    # paper's nu_aux=3 is tuned for 1000-way CE and destabilizes at 8-way
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=steps,
                                         grad_clip_norm=1.0))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=aux_heads,
                    delta=1, pool_size=K, pool_update_every=10)
    trainer = DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=steps, batch_size=16, public_batch_size=16,
                  eval_every=0, seed=seed),
        arrays, part.client_indices, part.public_indices,
        complete_graph(K), labels)
    return trainer, test, steps


def test_mhd_end_to_end_losses_decrease():
    trainer, test, steps = _setup()
    first = trainer.step(0)
    for t in range(1, steps):
        last = trainer.step(t)
    f = np.mean([v for k, v in first.items() if k.endswith("/ce")])
    l = np.mean([v for k, v in last.items() if k.endswith("/ce")])
    assert l < f, f"private CE did not decrease: {f} -> {l}"
    ev = trainer.evaluate({"images": test.images, "labels": test.labels})
    # private accuracy well above chance on an 8-class problem
    assert ev["mean/main/beta_priv"] > 0.3


def test_aux_head_learns_other_clients_classes():
    """The point of the paper: with fully skewed data the MAIN head knows
    only private classes, while the AUX head picks up the rest via
    distillation — so aux β_sh must beat main β_sh."""
    trainer, test, steps = _setup(K=2, labels=8, skew=10_000.0, steps=80,
                                  noise=0.3)
    for t in range(steps):
        trainer.step(t)
    ev = trainer.evaluate({"images": test.images, "labels": test.labels})
    assert ev["mean/aux2/beta_sh"] > ev["mean/main/beta_sh"] - 0.02, ev


def test_pool_staleness_respected():
    trainer, _, _ = _setup(steps=5)
    c = trainer.clients[0]
    assert len(c.pool) > 0
    for t in range(5):
        trainer.step(t)
    # entries carry the step at which they were inserted
    assert all(e.step <= 5 for e in c.pool.entries)


def test_heterogeneous_architectures_interop():
    """ResNet-18-like and ResNet-34-like clients distilling to each other
    (paper §4.5) — mechanically: mixed-arch pools must not retrace/crash."""
    from repro.models.resnet import resnet_tiny34

    labels = 6
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=30,
                               image_size=8, noise=0.5, seed=0)
    pcfg = PartitionConfig(num_clients=2, num_labels=labels,
                           labels_per_client=3, skew=100.0, gamma_pub=0.2,
                           seed=0)
    part = partition_dataset(ds.labels, pcfg)
    arrays = {"images": ds.images, "labels": ds.labels}
    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=2)),
               build_bundle(resnet_tiny34(labels, num_aux_heads=2))]
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=10))
    mhd = MHDConfig(num_aux_heads=2, pool_size=2, pool_update_every=5)
    trainer = DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=10, batch_size=8, public_batch_size=8, seed=0),
        arrays, part.client_indices, part.public_indices,
        complete_graph(2), labels)
    for t in range(6):
        m = trainer.step(t)
    assert np.isfinite(m["c0/loss"]) and np.isfinite(m["c1/loss"])


def test_lm_clients_mhd_loss():
    """MHD applied to LM clients (reduced assigned archs) via the adapter."""
    from repro.configs import get_reduced
    from repro.core.lm_adapter import lm_mhd_loss, lm_mhd_outputs

    cfg = get_reduced("minitron-4b")
    bundle = build_bundle(cfg)
    p_student = bundle.init(jax.random.PRNGKey(0))
    p_teacher = bundle.init(jax.random.PRNGKey(1))
    B, T = 2, 16
    priv = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                         cfg.vocab_size)}
    pub = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                                        cfg.vocab_size)}
    t_out = lm_mhd_outputs(bundle, p_teacher, pub)
    teachers = jax.tree.map(lambda x: x[None],
                            {"embedding": t_out["embedding"],
                             "logits": t_out["logits"],
                             "aux_logits": t_out["aux_logits"]})
    mhd = MHDConfig(nu_emb=1.0, nu_aux=3.0, num_aux_heads=cfg.num_aux_heads)
    loss, metrics = lm_mhd_loss(bundle, p_student, priv, pub, teachers, mhd)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: lm_mhd_loss(bundle, p, priv, pub, teachers,
                                       mhd)[0])(p_student)
    assert all(np.all(np.isfinite(np.asarray(x, np.float32)))
               for x in jax.tree.leaves(g))
