"""The declarative Experiment API: spec round-trip, registry wiring,
runner equivalence with direct trainer construction, metrics parity for
the baselines, and the unified private-batch rng streams."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    DecentralizedTrainer,
    MHDConfig,
    RunConfig,
    complete_graph,
)
from repro.data import (
    PartitionConfig,
    client_stream_seed,
    make_synthetic_vision,
    partition_dataset,
)
from repro.exp import (
    ALGORITHMS,
    AlgorithmSpec,
    ClientSpec,
    DataSpec,
    Experiment,
    ExperimentSpec,
    OptimizerSpec,
    PartitionSpec,
    ScheduleSpec,
    TopologySpec,
    TrainSpec,
    TransportSpec,
    WireSpec,
    get_preset,
    preset_names,
)
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def tiny_spec(algo="mhd", params=None, clients=None, *, steps=4,
              eval_every=0, schedule=None, **train_kw):
    return ExperimentSpec(
        name="tiny",
        algorithm=AlgorithmSpec(algo, params or {}),
        data=DataSpec(num_labels=6, samples_per_label=30),
        partition=PartitionSpec(labels_per_client=3, gamma_pub=0.15),
        clients=clients or ExperimentSpec.uniform_fleet(2),
        schedule=schedule or ScheduleSpec(),
        optimizer=OptimizerSpec(init_lr=0.05, total_steps=steps),
        train=TrainSpec(steps=steps, batch_size=16, public_batch_size=16,
                        eval_every=eval_every, **train_kw))


# -- spec serialization ------------------------------------------------------


def test_spec_json_roundtrip_heterogeneous():
    spec = ExperimentSpec(
        name="rt",
        algorithm=AlgorithmSpec("mhd", {"nu_aux": 2.0, "pool_size": 3}),
        data=DataSpec(num_labels=10, samples_per_label=50, noise=1.5),
        partition=PartitionSpec(labels_per_client=2, assignment="even",
                                skew=10.0, seed=7),
        clients=(ClientSpec("resnet_tiny", aux_heads=2),
                 ClientSpec("resnet_tiny34", aux_heads=2, width=16),
                 ClientSpec("resnet_tiny", aux_heads=2)),
        topology=TopologySpec("cycle", hops=2),
        schedule=ScheduleSpec(mode="async", rates=(1, 4, 2)),
        transport=TransportSpec(kind="simulated", latency=2,
                                bandwidth=4096, drop_prob=0.25, seed=3,
                                client_rates={1: 4, 2: 2}),
        wire=WireSpec(exchange="prediction_topk", topk=5, horizon=20),
        optimizer=OptimizerSpec(init_lr=0.1, grad_clip_norm=1.0),
        train=TrainSpec(steps=40, eval_every=10, max_staleness=30, seed=5))
    text = spec.to_json()
    json.loads(text)  # valid JSON
    restored = ExperimentSpec.from_json(text)
    assert restored == spec
    # types survive JSON (not just equality under coercion)
    assert isinstance(restored.clients, tuple)
    assert isinstance(restored.schedule.rates, tuple)
    assert all(isinstance(k, int)
               for k in restored.transport.client_rates)


def test_spec_roundtrip_all_presets():
    for name in preset_names():
        spec = get_preset(name)
        assert ExperimentSpec.from_json(spec.to_json()) == spec, name


def test_spec_rejects_unknown_fields_and_values():
    spec = tiny_spec()
    d = json.loads(spec.to_json())
    d["train"]["warp_factor"] = 9
    with pytest.raises(ValueError, match="warp_factor"):
        ExperimentSpec.from_dict(d)
    with pytest.raises(ValueError, match="unknown client arch"):
        tiny_spec(clients=(ClientSpec("resnet_huge"),)).validate()
    with pytest.raises(ValueError, match="rates"):
        tiny_spec(schedule=ScheduleSpec(mode="async",
                                        rates=(1, 1, 1))).validate()


def test_spec_rejects_short_horizon_for_skewed_prediction_exchange():
    """Satellite (ISSUE 9): the horizon-vs-publish-gap coverage hole is
    rejected at spec time for prediction exchanges — a 4× straggler only
    publishes every ``max_rate * pool_update_every`` wall ticks, so
    shorter-lived mailboxes expire before its neighbors read them.
    Direct `AsyncScheduler` construction keeps the softer runtime
    warning (tests/test_scheduler.py)."""
    import dataclasses

    def spec(horizon):
        base = tiny_spec("mhd", {"pool_update_every": 4},
                         schedule=ScheduleSpec(mode="async", rates=(1, 4)))
        return dataclasses.replace(
            base,
            transport=TransportSpec(kind="simulated"),
            wire=WireSpec(exchange="prediction_topk", topk=4,
                          horizon=horizon))

    with pytest.raises(ValueError, match="publish gap"):
        spec(horizon=8).validate()  # < 4 * 4
    spec(horizon=16).validate()  # exactly covers the straggler's gap
    # wire.horizon=0 means auto (= S_P), which a 4x straggler outruns
    with pytest.raises(ValueError, match="publish gap"):
        spec(horizon=0).validate()


def test_schedule_spec_scoreboard_knobs_validate():
    sb = ScheduleSpec(mode="scoreboard", rates=(1, 4), runahead=8,
                      pace_ms=(0.0, 40.0))
    tiny_spec(schedule=sb).validate()
    with pytest.raises(ValueError, match="pace_ms"):
        tiny_spec(schedule=ScheduleSpec(
            mode="scoreboard", pace_ms=(1.0,))).validate()
    with pytest.raises(ValueError, match="runahead"):
        tiny_spec(schedule=ScheduleSpec(
            mode="scoreboard", runahead=0)).validate()
    with pytest.raises(ValueError, match="sync"):
        tiny_spec(schedule=ScheduleSpec(
            mode="sync", runahead=4)).validate()
    with pytest.raises(ValueError, match="unknown schedule mode"):
        tiny_spec(schedule=ScheduleSpec(mode="warp")).validate()


def test_adapter_rejects_unknown_algorithm_params():
    from repro.exp import make_algorithm

    with pytest.raises(ValueError, match="nu_typo"):
        Experiment(tiny_spec(params={"nu_typo": 1.0})).run()
    # caught at adapter construction — the CLI --dry-run path — without
    # building data or models
    with pytest.raises(ValueError, match="nu_typo"):
        make_algorithm(tiny_spec(params={"nu_typo": 1.0}))
    with pytest.raises(ValueError, match="scoop"):
        make_algorithm(tiny_spec("supervised", params={"scoop": "pooled"}))


def test_registry_capabilities():
    for name in ("mhd", "fedmd", "fedavg", "supervised"):
        assert name in ALGORITHMS
    mhd = ALGORITHMS.get("mhd")(tiny_spec())
    assert mhd.capabilities.supports_async and mhd.capabilities.decentralized
    fedavg = ALGORITHMS.get("fedavg")(tiny_spec("fedavg"))
    assert not fedavg.capabilities.heterogeneous_clients
    assert not fedavg.capabilities.supports_async


def test_capability_checks_reject_impossible_specs():
    with pytest.raises(ValueError, match="async"):
        Experiment(tiny_spec("fedavg",
                             schedule=ScheduleSpec(mode="async"))).run()
    het = (ClientSpec("resnet_tiny"), ClientSpec("resnet_tiny34"))
    with pytest.raises(ValueError, match="identical"):
        Experiment(tiny_spec("fedavg", clients=het)).run()
    # pooled supervised needs a uniform fleet (one model is trained)
    with pytest.raises(ValueError, match="pooled"):
        Experiment(tiny_spec("supervised", {"scope": "pooled"},
                             clients=het)).run()
    # distillation algorithms need a public pool
    spec = tiny_spec("mhd")
    spec = spec.from_dict({**json.loads(spec.to_json()),
                           "partition": {**json.loads(spec.to_json())
                                         ["partition"], "gamma_pub": 0.0}})
    with pytest.raises(ValueError, match="gamma_pub"):
        Experiment(spec).run()
    # fleet must carry at least num_aux_heads heads everywhere
    mixed_heads = (ClientSpec("resnet_tiny", aux_heads=2),
                   ClientSpec("resnet_tiny", aux_heads=1))
    with pytest.raises(ValueError, match="aux heads"):
        Experiment(tiny_spec("mhd", {"pool_size": 2, "pool_update_every": 2},
                             clients=mixed_heads)).run()
    # spec blocks an algorithm cannot consume must fail loudly, not be
    # silently ignored: transports, staleness gates, rates under sync
    def replace(spec, **kw):
        import dataclasses
        return dataclasses.replace(spec, **kw)

    with pytest.raises(ValueError, match="transport"):
        Experiment(replace(
            tiny_spec("fedmd"),
            transport=TransportSpec(kind="simulated", drop_prob=0.9))).run()
    with pytest.raises(ValueError, match="max_staleness"):
        Experiment(tiny_spec("supervised", max_staleness=10)).run()
    with pytest.raises(ValueError, match="rates"):
        tiny_spec(schedule=ScheduleSpec(mode="sync",
                                        rates=(1, 1))).validate()


# -- runner equivalence with direct construction -----------------------------


def test_mhd_experiment_matches_direct_trainer():
    """Acceptance: Experiment.run() on an MHD spec reproduces direct
    DecentralizedTrainer construction — same step metrics, same eval
    history, metric for metric."""
    steps, s_p, labels, K = 6, 2, 6, 2
    spec = tiny_spec(
        "mhd", {"pool_size": K, "pool_update_every": s_p, "delta": 1,
                "nu_emb": 1.0, "nu_aux": 1.0},
        clients=ExperimentSpec.uniform_fleet(K, aux_heads=1),
        steps=steps, eval_every=3)

    runner_steps = []
    result = Experiment(spec).run(
        on_step=lambda t, m: runner_steps.append(m))

    # -- direct path: hand-rolled construction, old-harness style --------
    ds = make_synthetic_vision(num_labels=labels, samples_per_label=30,
                               image_size=8, noise=2.0, seed=0)
    test = make_synthetic_vision(num_labels=labels, samples_per_label=15,
                                 image_size=8, noise=2.0, seed=991,
                                 prototype_seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=K, num_labels=labels, labels_per_client=3,
        assignment="random", skew=100.0, gamma_pub=0.15, seed=0))
    bundles = [build_bundle(resnet_tiny(labels, num_aux_heads=1))
               for _ in range(K)]
    opt = make_optimizer(OptimizerConfig(init_lr=0.05, total_steps=steps))
    trainer = DecentralizedTrainer(
        bundles, opt,
        MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=1, delta=1,
                  pool_size=K, pool_update_every=s_p),
        RunConfig(steps=steps, batch_size=16, public_batch_size=16,
                  eval_every=0, seed=0),
        {"images": ds.images, "labels": ds.labels},
        part.client_indices, part.public_indices, complete_graph(K), labels)
    test_arrays = {"images": test.images, "labels": test.labels}
    direct_steps, direct_history = [], []
    for t in range(steps):
        direct_steps.append(trainer.step(t))
        if (t + 1) % 3 == 0:
            direct_history.append((t + 1, trainer.evaluate(test_arrays)))

    assert len(runner_steps) == len(direct_steps)
    for m_run, m_dir in zip(runner_steps, direct_steps):
        assert m_run == m_dir
    assert [t for t, _ in result.history] == [t for t, _ in direct_history]
    for (_, ev_run), (_, ev_dir) in zip(result.history, direct_history):
        assert ev_run == ev_dir
    assert result.metrics == direct_history[-1][1]


def test_scoreboard_experiment_matches_lockstep_bitwise():
    """mode="scoreboard" through the runner: without pacing or a binding
    run-ahead window, out-of-order issue walks the same op order as the
    lockstep policy — identical step metrics and final eval."""
    params = {"pool_size": 2, "pool_update_every": 2}
    fleet = ExperimentSpec.uniform_fleet(2, aux_heads=1)
    lock_steps, sb_steps = [], []
    lock = Experiment(tiny_spec(
        "mhd", params, fleet,
        schedule=ScheduleSpec(mode="lockstep", rates=(1, 2)))).run(
            on_step=lambda t, m: lock_steps.append(m))
    sb = Experiment(tiny_spec(
        "mhd", params, fleet,
        schedule=ScheduleSpec(mode="scoreboard", rates=(1, 2),
                              runahead=64))).run(
            on_step=lambda t, m: sb_steps.append(m))
    assert lock_steps == sb_steps
    assert lock.metrics == sb.metrics


# -- all four algorithms through one runner ----------------------------------


@pytest.mark.parametrize("algo,params,clients", [
    ("mhd", {"pool_size": 2, "pool_update_every": 2}, "aux"),
    ("fedmd", {"digest_weight": 0.5}, "het"),
    ("fedavg", {"average_every": 2}, None),
    ("supervised", {"scope": "pooled"}, None),
    ("supervised", {"scope": "separate"}, None),
])
def test_algorithms_share_runner_and_metric_namespace(algo, params, clients):
    fleets = {"aux": ExperimentSpec.uniform_fleet(2, aux_heads=1),
              "het": (ClientSpec("resnet_tiny"), ClientSpec("resnet_tiny34")),
              None: None}
    result = Experiment(tiny_spec(algo, params, fleets[clients])).run()
    # metrics parity: every algorithm reports both betas per client + mean
    for key in ("mean/main/beta_sh", "mean/main/beta_priv",
                "c0/main/beta_sh", "c0/main/beta_priv"):
        assert key in result.metrics, (algo, key)
        assert np.isfinite(result.metrics[key])
    # the _trainer leak is gone: results are JSON-serializable
    json.dumps(result.metrics)
    json.dumps(result.to_payload())
    assert result.trainer is not None  # live object rides out-of-band


def test_unified_private_streams_across_algorithms():
    """MHD, FedMD, FedAvg and separate-supervised draw client i's private
    batches from the same client_stream_seed stream."""
    from repro.core.fedavg import FedAvgTrainer
    from repro.core.fedmd import FedMDTrainer
    from repro.core.supervised import SupervisedTrainer

    assert client_stream_seed(5, 3) == 5 + 13 * 3
    spec = tiny_spec()
    exp = Experiment(spec)
    b = exp.build_bindings()
    opt = b.optimizer
    mhd = DecentralizedTrainer(
        b.bundles, opt, MHDConfig(num_aux_heads=0, pool_size=2,
                                  pool_update_every=2),
        RunConfig(steps=2, batch_size=16, public_batch_size=16, seed=0),
        b.arrays, b.partition.client_indices, b.partition.public_indices,
        b.graph, b.num_labels)
    fedmd = FedMDTrainer(b.bundles, opt, b.arrays,
                         b.partition.client_indices,
                         b.partition.public_indices, b.num_labels,
                         batch_size=16, seed=0)
    fedavg = FedAvgTrainer(b.bundles[0], opt, b.arrays,
                           b.partition.client_indices, b.num_labels,
                           batch_size=16, seed=0)
    sup = SupervisedTrainer(b.bundles, opt, b.arrays,
                            b.partition.client_indices, b.num_labels,
                            batch_size=16, scope="separate", seed=0)
    for i in range(2):
        want = mhd.clients[i].private_iter.next()
        for other in (fedmd.iters[i], fedavg.iters[i], sup.iters[i]):
            got = other.next()
            np.testing.assert_array_equal(got["labels"], want["labels"])
            np.testing.assert_array_equal(got["images"], want["images"])


# -- runner extras -----------------------------------------------------------


def test_runner_checkpointing(tmp_path):
    ck = str(tmp_path / "ckpt")
    spec = tiny_spec("supervised", {"scope": "separate"}, steps=2,
                     checkpoint_dir=ck)
    Experiment(spec).run()
    # final checkpoint for both isolated clients
    for i in range(2):
        assert os.path.isdir(os.path.join(ck, f"client_{i}",
                                          f"step_{2:010d}"))


def test_spec_file_and_dry_run_cli(tmp_path):
    spec_path = str(tmp_path / "exp.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    script = os.path.join(REPO, "scripts", "run_experiment.py")
    out = subprocess.run(
        [sys.executable, script, "--preset", "gossip",
         "--save-spec", spec_path],
        env=env, capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stderr
    out = subprocess.run(
        [sys.executable, script, "--spec", spec_path, "--dry-run"],
        env=env, capture_output=True, text=True, cwd=REPO)
    assert out.returncode == 0, out.stderr
    assert "spec OK" in out.stdout
    assert "SimulatedNetwork" in out.stdout
