"""Loop-aware HLO cost analysis vs closed-form expectations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    V5E,
    active_params,
    model_flops,
    roofline_from_artifacts,
)
from repro.roofline.hlo_cost import analyze, parse_computations
from repro.roofline.hlo_parse import collective_bytes_from_hlo, parse_shape_bytes


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_dot_flops_exact():
    f = lambda a, b: a @ b
    c = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 32), jnp.float32))
    cost = analyze(c.as_text())
    assert cost.flops == 2 * 64 * 128 * 32


def test_scan_trip_count_multiplied():
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = _compile(f, s, s)
    cost = analyze(c.as_text())
    expected = 2 * 64 * 64 * 64 * 10
    assert abs(cost.flops - expected) / expected < 0.01
    # XLA's own analysis counts the body once — ours must be ~10x larger
    # (newer jax returns one cost dict per device as a list)
    ca = c.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert cost.flops > 5 * ca["flops"]


def test_nested_scan():
    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    s = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    cost = analyze(_compile(f, s, s).as_text())
    expected = 2 * 32 ** 3 * 12
    assert abs(cost.flops - expected) / expected < 0.01


def test_bytes_reasonable_for_elementwise():
    f = lambda x: x * 2.0 + 1.0
    s = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    cost = analyze(_compile(f, s).as_text())
    # one fused read + one write = 8 MB; allow copies
    assert 8e6 <= cost.bytes <= 4e7


def test_parse_shape_bytes():
    assert parse_shape_bytes("bf16[4,8]") == 64
    assert parse_shape_bytes("f32[10] s32[2,2]") == 56
    assert parse_shape_bytes("f32[]") == 4


def test_model_flops_moe_active():
    from repro.configs import get_config
    cfg = get_config("deepseek-v3-671b")
    total = 100
    # synthetic: just verify the MoE discount direction on the real config
    from repro.common.pytree import tree_size
    from repro.models.zoo import build_bundle
    shapes = jax.eval_shape(build_bundle(cfg).init, jax.random.PRNGKey(0))
    n = tree_size(shapes)
    act = active_params(cfg, n)
    assert act < 0.2 * n  # 37B active vs 671B total ballpark
    assert act > 0.02 * n


def test_roofline_report_dominant():
    from repro.configs import get_config
    cfg = get_config("qwen2.5-32b")
    rep = roofline_from_artifacts(
        "qwen2.5-32b", "train_4k", "16x16", 256,
        cost={"flops": 1e15, "bytes accessed": 1e12},
        collectives={"total": 1e11},
        memory={"argument_size_in_bytes": 1e9, "temp_size_in_bytes": 1e9,
                "output_size_in_bytes": 0},
        cfg=cfg, total_params=32e9, tokens=256 * 4096, mode="train")
    assert rep.dominant == "compute"
    assert rep.fits_hbm
    assert rep.compute_s == pytest.approx(1e15 / V5E.peak_flops)
