"""Tests for beyond-paper extensions: top-k wire kernel, alternative
confidence measures, dynamic graphs, runtime checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mhd import MHDConfig, _confidence, multi_head_distillation_loss
from repro.kernels.ref import topk_wire_ref
from repro.kernels.topk_wire import topk_wire


@pytest.mark.parametrize("B,V,k", [(4, 130, 8), (7, 1024, 32), (2, 64, 4)])
def test_topk_wire_kernel(B, V, k):
    x = jax.random.normal(jax.random.PRNGKey(0), (B, V)) * 3
    v, i, lse = topk_wire(x, k, block_rows=4, interpret=True)
    v_r, i_r, lse_r = topk_wire_ref(x, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_r), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), rtol=1e-5)


def test_topk_wire_ops_dispatch():
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(0), (3, 50))
    v, i, lse = ops.topk_wire(x, 5)  # CPU -> ref
    v_r, i_r, _ = topk_wire_ref(x, 5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_r), rtol=1e-6)


@pytest.mark.parametrize("measure", ["max", "entropy", "margin"])
def test_confidence_measures_order_peaked_above_uniform(measure):
    peaked = jnp.zeros((1, 10)).at[0, 3].set(8.0)
    uniform = jnp.zeros((1, 10))
    cp = float(_confidence(peaked, measure)[0])
    cu = float(_confidence(uniform, measure)[0])
    assert cp > cu, (measure, cp, cu)


@pytest.mark.parametrize("measure", ["entropy", "margin"])
def test_mhd_loss_with_alt_confidence(measure):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    student = {"embedding": jax.random.normal(ks[0], (5, 8)),
               "logits": jax.random.normal(ks[1], (5, 7)),
               "aux_logits": jax.random.normal(ks[2], (2, 5, 7))}
    teachers = {"embedding": jax.random.normal(ks[3], (1, 5, 8)),
                "logits": jax.random.normal(ks[4], (1, 5, 7)),
                "aux_logits": jax.random.normal(ks[5], (1, 2, 5, 7))}
    cfg = MHDConfig(num_aux_heads=2, confidence=measure)
    loss, metrics = multi_head_distillation_loss(student, teachers, cfg)
    assert np.isfinite(float(loss)) and float(loss) >= 0


def test_random_regular_graph_fn():
    from repro.core.graph import random_regular_graph_fn, validate_adjacency

    fn = random_regular_graph_fn(6, degree=2, reshuffle_every=10)
    g0 = fn(0)
    validate_adjacency(g0)
    assert all(len(n) == 2 for n in g0)
    assert fn(5) == g0  # same epoch
    assert fn(10) != g0 or fn(20) != g0  # reshuffles eventually


def test_runtime_checkpoint_roundtrip(tmp_path):
    from repro.core import DecentralizedTrainer, RunConfig, complete_graph
    from repro.data import (PartitionConfig, make_synthetic_vision,
                            partition_dataset)
    from repro.models.resnet import resnet_tiny
    from repro.models.zoo import build_bundle
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    ds = make_synthetic_vision(num_labels=6, samples_per_label=20,
                               image_size=8, seed=0)
    part = partition_dataset(ds.labels, PartitionConfig(
        num_clients=2, num_labels=6, labels_per_client=3, gamma_pub=0.2,
        seed=0))
    arrays = {"images": ds.images, "labels": ds.labels}

    def make_trainer():
        bundles = [build_bundle(resnet_tiny(6, num_aux_heads=1))
                   for _ in range(2)]
        return DecentralizedTrainer(
            bundles, make_optimizer(OptimizerConfig(total_steps=10)),
            MHDConfig(num_aux_heads=1, pool_size=2, pool_update_every=5),
            RunConfig(steps=10, batch_size=8, public_batch_size=8, seed=0),
            arrays, part.client_indices, part.public_indices,
            complete_graph(2), 6)

    tr = make_trainer()
    for t in range(3):
        tr.step(t)
    tr.save(str(tmp_path / "run"), step=3)

    tr2 = make_trainer()
    restored = tr2.restore(str(tmp_path / "run"))
    assert restored == 3
    for a, b in zip(jax.tree.leaves(tr.clients[0].params),
                    jax.tree.leaves(tr2.clients[0].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tr2.step(3)  # can continue training
