"""Serving correctness: token-by-token decode must reproduce the full
teacher-forced forward pass for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.transformer import prefill_cross_caches
from repro.models.zoo import build_bundle

pytestmark = pytest.mark.slow  # per-arch decode loops — minutes on CPU


def _decode_all(bundle, params, tokens, caches):
    step = jax.jit(bundle.decode_step)
    logits = []
    for t in range(tokens.shape[1]):
        lg, caches = step(params, tokens[:, t:t + 1], caches)
        logits.append(lg)
    return jnp.concatenate(logits, axis=1)


@pytest.mark.parametrize("arch", [
    "qwen2.5-32b",        # dense GQA + qkv bias
    "gemma3-12b",         # sliding-window ring caches + tied embeddings
    "mamba2-370m",        # pure SSM state caches
    "zamba2-7b",          # hybrid + shared attention block
    "deepseek-v3-671b",   # MLA absorbed decode + MoE
    "minitron-4b",        # relu2 dense
])
def test_decode_matches_full_forward(arch):
    import dataclasses

    cfg = get_reduced(arch)
    if cfg.moe is not None:
        # capacity dropping is seq-length dependent (full forward routes all
        # positions jointly; decode routes one) — compare dropless
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, T = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    full = jax.jit(bundle.apply)(params, {"tokens": tokens})["logits"]
    caches = bundle.init_cache(B, T, jnp.float32)
    dec = _decode_all(bundle, params, tokens, caches)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_vlm_decode_with_cross_cache():
    cfg = get_reduced("llama-3.2-vision-90b")
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, T = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    vis = jax.random.normal(jax.random.PRNGKey(2),
                            (B, cfg.vision.num_patches, cfg.vision.embed_dim))
    full = jax.jit(bundle.apply)(
        params, {"tokens": tokens, "vision_embeds": vis})["logits"]
    caches = bundle.init_cache(B, T, jnp.float32)
    caches = prefill_cross_caches(params, cfg, caches, vision_embeds=vis)
    dec = _decode_all(bundle, params, tokens, caches)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_whisper_decode_with_encoder_cache():
    cfg = get_reduced("whisper-large-v3")
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, T_enc, T_dec = 1, 16, 12
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (B, T_enc, cfg.audio.frame_dim))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T_dec), 0,
                                cfg.vocab_size)
    full = jax.jit(bundle.apply)(
        params, {"tokens": tokens, "audio_frames": frames})["logits"]
    caches = bundle.init_cache(B, T_enc, jnp.float32)
    caches = prefill_cross_caches(params, cfg, caches, audio_frames=frames)
    dec = _decode_all(bundle, params, tokens, caches)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)
