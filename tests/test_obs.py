"""Tests for `repro.obs`: the span tracer, Chrome-trace export and
cross-process merge, phase attribution, and the experiment wiring."""
import json

import pytest

from repro.obs import (
    load_trace,
    merge_traces,
    to_chrome_events,
    write_trace,
)
from repro.obs import tracer as trace
from repro.obs.metrics import (
    collect_obs,
    flow_coverage,
    phase_attribution,
    self_times,
    stall_spans,
)
from repro.obs.tracer import Tracer, flow_id


@pytest.fixture(autouse=True)
def _no_global_tracer():
    """Every test starts and ends in no-op mode — a leaked enable() would
    make unrelated suites pay tracing costs."""
    trace.disable()
    yield
    trace.disable()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_disabled_mode_is_inert():
    """Off by default: now() is 0.0, span() is the shared no-op, and no
    module-level call raises or allocates events."""
    assert trace.get() is None and trace.active() is False
    assert trace.now() == 0.0
    with trace.span("x", a=1):
        pass
    trace.complete("x", 0.0)
    trace.instant("x")
    trace.counter("x", 1)
    trace.flow_start(1)
    trace.flow_end(1)
    trace.set_anchor("x")
    assert trace.span("a") is trace.span("b")  # one shared no-op object


def test_enable_records_spans_and_disable_stops():
    tracer = trace.enable(rank=3, process_name="r3")
    assert trace.get() is tracer and trace.active() is True
    with trace.span("outer", k=1):
        with trace.span("inner"):
            pass
    trace.instant("tick", step=2)
    t0 = trace.now()
    trace.complete("retro", t0, n=5)
    trace.disable()
    with trace.span("after_disable"):
        pass
    evs = tracer.events()
    names = [e["name"] for e in evs]
    assert names == ["inner", "outer", "tick", "retro"]  # emit-on-exit order
    spans = {e["name"]: e for e in evs}
    assert spans["outer"]["ph"] == "X" and spans["outer"]["args"] == {"k": 1}
    assert spans["inner"]["ts"] >= spans["outer"]["ts"]
    assert spans["tick"]["ph"] == "i"
    assert spans["retro"]["args"] == {"n": 5}
    assert tracer.rank == 3 and tracer.process_name == "r3"


def test_ring_buffer_drops_oldest_and_counts():
    tracer = trace.enable(capacity=4)
    for i in range(10):
        trace.instant("e", i=i)
    stats = tracer.stats()
    assert stats["emitted"] == 10 and stats["kept"] == 4
    assert stats["dropped"] == 6
    assert [e["args"]["i"] for e in tracer.events()] == [6, 7, 8, 9]


def test_flow_id_is_deterministic_and_distinct():
    """Both ends derive the id from frame-header fields alone; distinct
    (src, dst, step) triples must not collide."""
    assert flow_id(1, 2, 7) == flow_id(1, 2, 7)
    ids = {flow_id(s, d, t)
           for s in range(4) for d in range(4) for t in (0, 1, 2, 1 << 31)}
    assert len(ids) == 4 * 4 * 4


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def test_chrome_events_convert_to_microseconds():
    tracer = Tracer()
    tracer._emit({"ph": "X", "name": "work", "ts": 1.0, "dur": 0.5,
                  "tid": 0, "args": {}})
    tracer.instant("mark")
    tracer.flow_start(42)
    tracer.flow_end(42)
    ch = to_chrome_events(tracer.events(), pid=5)
    x = next(e for e in ch if e["ph"] == "X")
    assert x["pid"] == 5 and x["ts"] == pytest.approx(1.0e6)
    assert x["dur"] == pytest.approx(0.5e6)
    assert next(e for e in ch if e["ph"] == "i")["s"] == "t"
    s = next(e for e in ch if e["ph"] == "s")
    f = next(e for e in ch if e["ph"] == "f")
    assert s["id"] == f["id"] == 42 and s["cat"] == f["cat"] == "flow"
    assert f["bp"] == "e"  # binds to the enclosing slice


def test_write_load_roundtrip(tmp_path):
    tracer = trace.enable(rank=1, process_name="rank 1")
    with trace.span("a"):
        pass
    trace.set_anchor("rendezvous_send")
    path = write_trace(str(tmp_path / "t.json"), tracer, meta={"k": "v"})
    data = load_trace(path)
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               for e in data["traceEvents"])
    od = data["otherData"]
    assert od["rank"] == 1 and od["meta"] == {"k": "v"}
    assert "rendezvous_send" in od["anchors"]
    assert od["stats"]["kept"] == 1.0


def test_merge_aligns_clocks_with_rendezvous_anchors(tmp_path):
    """Two ranks whose perf_counter epochs differ by exactly 10s: the
    handshake anchors must cancel the offset, landing the simultaneous
    spans at the same merged timestamp (re-based to 0)."""
    paths, skew = {}, {0: 0.0, 1: 10.0}
    for r in (0, 1):
        tr = Tracer(rank=r, process_name=f"rank {r}")
        # child clock = parent clock - skew[r]; handshake at parent t=1.0
        tr.set_anchor("rendezvous_send", 1.0 - skew[r])
        tr.set_anchor("rendezvous_recv", 1.0 - skew[r])
        tr._emit({"ph": "X", "name": "work", "ts": 2.0 - skew[r],
                  "dur": 0.5, "tid": 0, "args": {}})  # parent t=2.0 on both
        paths[r] = write_trace(str(tmp_path / f"r{r}.json"), tr)
    out = merge_traces(paths, str(tmp_path / "merged.json"),
                       parent_anchors={0: (1.0, 1.0), 1: (1.0, 1.0)})
    data = load_trace(out)
    work = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert {e["pid"] for e in work} == {0, 1}
    # identical parent-clock instants merge to one timestamp; earliest
    # (the rendezvous-anchored t=2.0 spans are all there is) re-bases to 0
    assert work[0]["ts"] == pytest.approx(work[1]["ts"], abs=1.0)
    assert min(e["ts"] for e in work) == pytest.approx(0.0, abs=1e-6)
    assert data["otherData"]["offsets_s"]["1"] == pytest.approx(10.0)
    assert data["otherData"]["merged"] is True


def test_merge_without_anchors_uses_zero_offset(tmp_path):
    tr = Tracer(rank=0)
    tr._emit({"ph": "X", "name": "w", "ts": 5.0, "dur": 1.0,
              "tid": 0, "args": {}})
    p = write_trace(str(tmp_path / "r0.json"), tr)
    out = merge_traces({0: p}, str(tmp_path / "m.json"))
    data = load_trace(out)
    assert data["otherData"]["offsets_s"]["0"] == 0.0


# ---------------------------------------------------------------------------
# phase attribution
# ---------------------------------------------------------------------------

def _x(name, ts_s, dur_s, pid=0, tid=0):
    return {"ph": "X", "name": name, "pid": pid, "tid": tid,
            "ts": ts_s * 1e6, "dur": dur_s * 1e6, "args": {}}


def test_self_times_subtract_children():
    """A 10s step containing an 8s distill contributes 2s of self-time;
    idle is the uncovered remainder of the rank extent."""
    evs = [_x("runtime/step", 0.0, 10.0),
           _x("runtime/distill", 1.0, 8.0),
           _x("runtime/step", 12.0, 2.0)]
    st = self_times(evs)[0]
    assert st["runtime/step"] == pytest.approx(4.0)
    assert st["runtime/distill"] == pytest.approx(8.0)
    assert st["#wall"] == pytest.approx(14.0)
    assert st["#idle"] == pytest.approx(2.0)  # the [10, 12) gap


def test_self_times_survive_retro_emission_overlap():
    """A retro-emitted span that ends a hair after its successor starts
    (the emit call's own cost) must NOT adopt the successor as a child —
    the regression that drove setup self-time negative."""
    evs = [_x("gossip/setup", 0.0, 5.000001),
           _x("gossip/train", 5.0, 30.0)]
    st = self_times(evs)[0]
    assert st["gossip/setup"] == pytest.approx(5.0, abs=1e-3)
    assert st["gossip/train"] == pytest.approx(30.0, abs=1e-3)
    assert all(v >= 0.0 for v in st.values())


def test_phase_attribution_sums_to_wall():
    evs = [_x("gossip/setup", 0.0, 3.0),
           _x("runtime/step", 4.0, 10.0),
           _x("runtime/distill", 5.0, 8.0),
           _x("publish/encode", 14.5, 1.0),
           _x("unknown/thing", 16.0, 0.5)]
    row = phase_attribution(evs)[0]
    assert row["wall"] == pytest.approx(16.5)
    assert row["setup"] == pytest.approx(3.0)
    assert row["distill"] == pytest.approx(8.0)
    assert row["encode"] == pytest.approx(1.0)
    assert row["other"] == pytest.approx(0.5)
    total = sum(v for k, v in row.items() if k != "wall")
    assert total == pytest.approx(row["wall"])


def test_stall_spans_and_flow_coverage():
    evs = [_x("socket/drain_wait", 0.0, 2.0),
           _x("gossip/finish_barrier", 3.0, 5.0, pid=1),
           _x("runtime/distill", 0.0, 9.0)]  # work, not a stall
    evs += [{"ph": "s", "id": 7, "ts": 0, "pid": 0, "tid": 0,
             "name": "flow", "args": {}},
            {"ph": "f", "id": 7, "ts": 1, "pid": 1, "tid": 0,
             "name": "flow", "args": {}},
            {"ph": "s", "id": 9, "ts": 2, "pid": 0, "tid": 0,
             "name": "flow", "args": {}}]  # never delivered
    stalls = stall_spans(evs, top=5)
    assert [s["name"] for s in stalls] == \
        ["gossip/finish_barrier", "socket/drain_wait"]
    assert stalls[0]["rank"] == 1 and stalls[0]["dur_s"] == pytest.approx(5.0)
    cov = flow_coverage(evs)
    assert cov == {"flow_starts": 2.0, "flow_ends": 1.0, "flow_pairs": 1.0}


# ---------------------------------------------------------------------------
# collect_obs + experiment wiring
# ---------------------------------------------------------------------------

def test_collect_obs_folds_meter_and_tracer():
    from repro.comm import CommMeter

    class FakeTrainer:
        meter = CommMeter()

    FakeTrainer.meter.record(0, 0, 1, 100)
    FakeTrainer.meter.record_delivery(0, 0, 1, 100)
    FakeTrainer.meter.record_gate(0, fresh=2, stale=1)
    tracer = trace.enable(rank=0)
    with trace.span("runtime/distill", bundle="b"):
        pass
    trace.disable()
    snap = collect_obs(trainer=FakeTrainer(), tracer=tracer)
    m = snap.to_metrics()
    assert m["obs/comm/total_bytes"] == 100.0
    assert m["obs/comm/delivered_bytes"] == 100.0
    assert m["obs/gate/c0/fresh"] == 2.0
    assert m["obs/trace/kept"] == 1.0
    assert m["obs/phase/r0/distill"] > 0.0
    assert m["obs/phase/r0/wall"] == pytest.approx(
        sum(v for k, v in m.items()
            if k.startswith("obs/phase/r0/") and not k.endswith("/wall")))


@pytest.mark.slow
def test_experiment_trace_dir_writes_trace_and_obs_metrics(tmp_path):
    """TrainSpec.trace_dir turns the runner's tracing on: a Chrome trace
    lands in the dir and the result metrics gain the obs/ namespace,
    roofline rows included."""
    from repro.exp import (DataSpec, Experiment, ExperimentSpec,
                           OptimizerSpec, PartitionSpec, TrainSpec)

    def tiny_spec(steps, **train_kw):
        return ExperimentSpec(
            name="tiny_obs",
            data=DataSpec(num_labels=6, samples_per_label=30),
            partition=PartitionSpec(labels_per_client=3, gamma_pub=0.15),
            clients=ExperimentSpec.uniform_fleet(2, aux_heads=1),
            optimizer=OptimizerSpec(init_lr=0.05, total_steps=steps),
            train=TrainSpec(steps=steps, batch_size=16,
                            public_batch_size=16, **train_kw))

    spec = tiny_spec(steps=4, trace_dir=str(tmp_path / "tr"))
    res = Experiment(spec).run()
    assert trace.get() is None  # runner disabled its tracer on exit
    data = load_trace(str(tmp_path / "tr" / "trace.json"))
    names = {e["name"] for e in data["traceEvents"]}
    assert "runtime/distill" in names and "runtime/step" in names
    assert res.metrics["obs/trace/dropped"] == 0.0
    assert res.metrics["obs/phase/r0/distill"] > 0.0
    roofline = {k: v for k, v in res.metrics.items()
                if k.startswith("obs/roofline/")}
    assert any(k.endswith("/flops") for k in roofline)
    assert any(k.endswith("/achieved_flops_per_s") for k in roofline)
    # tracing is opt-in: a plain run leaves no obs/ keys behind
    res2 = Experiment(tiny_spec(steps=2)).run()
    assert not any(k.startswith("obs/") for k in res2.metrics)
