"""Tests for the §Perf optimization paths: chunked CE, iterative top-k,
expert-parallel fallback, the distributed MHD step, and the sparse-teacher
CE of the top-k wire format."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mhd import MHDConfig
from repro.core.mhd_distributed import (
    DistributedMHDConfig,
    _dense_xent_and_conf,
    _sparse_xent_and_conf,
    _topk_iterative,
    _topk_pack,
    make_distributed_mhd_step,
)
from repro.models.transformer import _chunked_xent, softmax_xent


@pytest.mark.parametrize("B,V,k", [(3, 100, 5), (2, 257, 8), (1, 64, 64)])
def test_topk_iterative_matches_lax(B, V, k):
    x = jax.random.normal(jax.random.PRNGKey(0), (B, V))
    v, i = _topk_iterative(x, k)
    v_r, i_r = jax.lax.top_k(x, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_r))


@pytest.mark.parametrize("B,T,V,chunk", [(3, 17, 11, 5), (2, 16, 33, 8),
                                         (1, 7, 9, 16)])
def test_chunked_xent_matches_dense(B, T, V, chunk):
    h = jax.random.normal(jax.random.PRNGKey(0), (B, T, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, V))
    lab = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, V)
    dense = softmax_xent(jnp.einsum("btd,dv->btv", h, w), lab)
    ch = _chunked_xent(h, w, lab, chunk=chunk)
    np.testing.assert_allclose(float(ch), float(dense), rtol=1e-5)


def test_chunked_xent_gradients_match():
    h = jax.random.normal(jax.random.PRNGKey(0), (2, 12, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 20))
    lab = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 20)
    g_dense = jax.grad(lambda w_: softmax_xent(
        jnp.einsum("btd,dv->btv", h, w_), lab))(w)
    g_chunk = jax.grad(lambda w_: _chunked_xent(h, w_, lab, 4))(w)
    np.testing.assert_allclose(np.asarray(g_chunk), np.asarray(g_dense),
                               rtol=1e-4, atol=1e-6)


def test_moe_a2a_falls_back_to_scatter_on_cpu():
    """No 'model' mesh axis on CPU -> identical results to moe_apply."""
    from repro.models.config import MoEConfig
    from repro.models.moe import init_moe, moe_apply
    from repro.models.moe_a2a import moe_apply_a2a

    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=2.0)
    params = init_moe(jax.random.PRNGKey(0), 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8))
    y1, a1 = moe_apply(params, x, cfg)
    y2, a2 = moe_apply_a2a(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)


def test_sparse_xent_matches_dense_for_peaked_teacher():
    """When the teacher's mass is inside the top-k, the truncated wire
    format is (nearly) exact, and top-1 confidence is exact."""
    V, k = 50, 8
    t = jnp.zeros((4, V)).at[:, 3].set(10.0).at[:, 7].set(8.0)
    s = jax.random.normal(jax.random.PRNGKey(0), (4, V))
    dense_ce, dense_conf = _dense_xent_and_conf(s, t)
    vals, idx = jax.lax.top_k(t, k)
    packed = {"vals": vals, "idx": idx,
              "lse": jax.nn.logsumexp(t.astype(jnp.float32), -1)}
    sparse_ce, sparse_conf = _sparse_xent_and_conf(s, packed)
    np.testing.assert_allclose(np.asarray(sparse_conf),
                               np.asarray(dense_conf), rtol=1e-5)
    # the truncated tail (~0.3% teacher mass here) is the wire format's
    # documented approximation
    np.testing.assert_allclose(np.asarray(sparse_ce), np.asarray(dense_ce),
                               rtol=2e-2)


@pytest.mark.parametrize("exchange", ["full", "topk"])
def test_distributed_mhd_step_runs(exchange):
    """The pod-parallel MHD step on CPU (roll degrades to an in-memory
    swap): loss finite, params move, both wire formats."""
    from repro.configs import get_reduced
    from repro.models.zoo import build_bundle
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    cfg = dataclasses.replace(get_reduced("minitron-4b"), num_aux_heads=2)
    bundle = build_bundle(cfg)
    opt = make_optimizer(OptimizerConfig(init_lr=0.01, total_steps=5))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=2)
    dist = DistributedMHDConfig(num_clients=2, exchange=exchange, topk=8)
    step = make_distributed_mhd_step(bundle, opt, mhd, dist)

    params = jax.vmap(lambda k: bundle.init(k))(
        jax.random.split(jax.random.PRNGKey(0), 2))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = {
        "private_tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 2, 16), 0, cfg.vocab_size),
        "public_tokens": jax.random.randint(
            jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size),
    }
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(state["params"]), jax.tree.leaves(new_state["params"])))
    assert moved > 0


def test_hlo_cost_fusion_slice_awareness():
    """A scan whose body slices a big stacked operand must not charge the
    full stack per iteration."""
    from repro.roofline.hlo_cost import analyze

    def f(stack, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, stack)
        return out

    stack = jax.ShapeDtypeStruct((32, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(stack, x).compile()
    cost = analyze(c.as_text())
    stack_bytes = 32 * 128 * 128 * 4
    # naive accounting charges the full stack per iteration (~32 x 2 MB plus
    # carries = 67+ MB); slice-aware accounting stays well under half that
    assert cost.bytes < 16 * stack_bytes, cost.bytes


def test_nested_remat_same_loss():
    """remat='nested' must not change the computed loss."""
    from repro.configs import get_reduced
    from repro.models.zoo import build_bundle

    cfg = get_reduced("qwen2.5-32b")
    cfg12 = dataclasses.replace(cfg, num_layers=12,
                                stages=cfg.stages[:1].__class__(
                                    [dataclasses.replace(cfg.stages[0],
                                                         repeats=12)]))
    bundle = build_bundle(dataclasses.replace(cfg12, remat="unit"))
    params = bundle.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    l1, _ = bundle.loss(params, batch)
    bundle2 = build_bundle(dataclasses.replace(cfg12, remat="nested"))
    l2, _ = bundle2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: bundle.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: bundle2.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)
