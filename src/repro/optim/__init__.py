from repro.optim.optimizers import (
    Optimizer,
    sgd_momentum,
    adamw,
    make_optimizer,
)
from repro.optim.schedules import (
    cosine_decay_schedule,
    constant_schedule,
    warmup_cosine_schedule,
)

__all__ = [
    "Optimizer",
    "sgd_momentum",
    "adamw",
    "make_optimizer",
    "cosine_decay_schedule",
    "constant_schedule",
    "warmup_cosine_schedule",
]
