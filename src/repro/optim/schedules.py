"""Learning-rate schedules.

The paper (§4.1) trains with SGD + momentum 0.9, initial LR 0.1 and cosine
decay; we implement that exactly, plus linear-warmup cosine for the LLM
architectures.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def schedule(step):
        return jnp.asarray(lr, dtype=jnp.float32)

    return schedule


def cosine_decay_schedule(init_lr: float, total_steps: int, final_scale: float = 0.0):
    """Cosine from init_lr to final_scale * init_lr over total_steps."""

    def schedule(step):
        t = jnp.minimum(jnp.asarray(step, jnp.float32), total_steps) / max(total_steps, 1)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return init_lr * (final_scale + (1.0 - final_scale) * cos)

    return schedule


def warmup_cosine_schedule(
    init_lr: float,
    total_steps: int,
    warmup_steps: int = 0,
    final_scale: float = 0.0,
):
    cosine = cosine_decay_schedule(init_lr, max(total_steps - warmup_steps, 1), final_scale)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = init_lr * step / jnp.maximum(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cosine(step - warmup_steps))

    return schedule
