"""Optimizers implemented from scratch in JAX (no optax in this container).

``Optimizer`` is a pair of pure functions (init, update) — the same contract
as optax — so the training loop, FedAvg and the MHD runtime all stay
optimizer-agnostic.

The paper trains with SGD + momentum 0.9 (§4.1); AdamW is provided for the
assigned LLM architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], tuple]  # (grads, state, params, step) -> (new_params, new_state)


def _global_norm(grads):
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def sgd_momentum(
    schedule: Callable,
    momentum: float = 0.9,
    nesterov: bool = False,
    weight_decay: float = 0.0,
    grad_clip_norm: Optional[float] = None,
    state_dtype=jnp.float32,
) -> Optimizer:
    """SGD with (heavy-ball) momentum — the paper's optimizer.

    ``state_dtype`` lets huge models keep momentum in bf16 (a §Perf lever:
    halves optimizer-state HBM for the 480B/671B MoE configs).
    """

    def init(params):
        return {
            "momentum": jax.tree.map(
                lambda p: jnp.zeros(p.shape, dtype=state_dtype), params
            )
        }

    def update(grads, state, params, step):
        lr = schedule(step)
        if grad_clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip_norm)

        def upd(m, g, p):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m.astype(jnp.float32) + g32
            if nesterov:
                d = g32 + momentum * m_new
            else:
                d = m_new
            p_new = p.astype(jnp.float32) - lr * d
            return m_new.astype(state_dtype), p_new.astype(p.dtype)

        flat = jax.tree.map(upd, state["momentum"], grads, params)
        m_new = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        p_new = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        return p_new, {"momentum": m_new}

    return Optimizer(init=init, update=update)


def adamw(
    schedule: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip_norm: Optional[float] = 1.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        lr = schedule(step)
        if grad_clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - jnp.power(b1, t)
        bc2 = 1.0 - jnp.power(b2, t)

        def upd(m, v, g, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m_new / bc1
            vhat = v_new / bc2
            d = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * d
            return m_new.astype(state_dtype), v_new.astype(state_dtype), p_new.astype(p.dtype)

        flat = jax.tree.map(upd, state["m"], state["v"], grads, params)
        is_t = lambda t_: isinstance(t_, tuple)
        m_new = jax.tree.map(lambda t_: t_[0], flat, is_leaf=is_t)
        v_new = jax.tree.map(lambda t_: t_[1], flat, is_leaf=is_t)
        p_new = jax.tree.map(lambda t_: t_[2], flat, is_leaf=is_t)
        return p_new, {"m": m_new, "v": v_new}

    return Optimizer(init=init, update=update)


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sgd_momentum"  # or "adamw"
    init_lr: float = 0.1
    total_steps: int = 60_000
    warmup_steps: int = 0
    momentum: float = 0.9
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = None
    state_dtype: str = "float32"


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    from repro.optim.schedules import warmup_cosine_schedule

    schedule = warmup_cosine_schedule(cfg.init_lr, cfg.total_steps, cfg.warmup_steps)
    state_dtype = jnp.dtype(cfg.state_dtype)
    if cfg.name == "sgd_momentum":
        return sgd_momentum(
            schedule,
            momentum=cfg.momentum,
            weight_decay=cfg.weight_decay,
            grad_clip_norm=cfg.grad_clip_norm,
            state_dtype=state_dtype,
        )
    if cfg.name == "adamw":
        return adamw(
            schedule,
            weight_decay=cfg.weight_decay,
            grad_clip_norm=cfg.grad_clip_norm,
            state_dtype=state_dtype,
        )
    raise ValueError(f"unknown optimizer {cfg.name!r}")
