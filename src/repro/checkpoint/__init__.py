from repro.checkpoint.io import save_pytree, load_pytree, CheckpointManager
from repro.checkpoint.pool import CheckpointPool, PoolEntry

__all__ = [
    "save_pytree",
    "load_pytree",
    "CheckpointManager",
    "CheckpointPool",
    "PoolEntry",
]
