"""The paper's rolling checkpoint pool (§4.1).

Each client C_i keeps a pool P_i of N_P checkpoints of *other* clients.
Every S_P steps one new checkpoint (of a client adjacent in the current
communication graph) is inserted, replacing a random existing entry. Each
training step the client samples Δ pool entries as distillation teachers.

The pool stores (client_id, params) pairs; params may be stale — that lag is
part of the method (the paper: "infrequent pool updates would typically
introduce a time lag causing the model to distill knowledge from somewhat
outdated checkpoints").
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PoolEntry:
    client_id: int
    params: Any
    step: int  # global step at which this checkpoint was taken


class CheckpointPool:
    def __init__(self, capacity: int, update_every: int, seed: int = 0):
        if capacity < 1:
            raise ValueError("pool capacity must be >= 1")
        self.capacity = capacity
        self.update_every = update_every
        self.entries: List[PoolEntry] = []
        self.rng = np.random.default_rng(seed)

    def should_update(self, step: int) -> bool:
        return step % self.update_every == 0

    def insert(self, entry: PoolEntry) -> None:
        """Insert, replacing a random entry once at capacity (paper §4.1)."""
        if len(self.entries) < self.capacity:
            self.entries.append(entry)
        else:
            slot = int(self.rng.integers(len(self.entries)))
            self.entries[slot] = entry

    def sample(self, delta: int) -> List[PoolEntry]:
        """Sample Δ distinct teachers for this step (fewer if pool is small)."""
        if not self.entries:
            return []
        k = min(delta, len(self.entries))
        idx = self.rng.choice(len(self.entries), size=k, replace=False)
        return [self.entries[int(i)] for i in idx]

    def __len__(self) -> int:
        return len(self.entries)

    def staleness(self, step: int) -> float:
        """Mean age (in steps) of pool entries — a telemetry signal."""
        if not self.entries:
            return 0.0
        return float(np.mean([step - e.step for e in self.entries]))
