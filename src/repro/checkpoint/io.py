"""Checkpoint I/O: pytree <-> .npz with path-keyed leaves.

No orbax in this container; this implements the subset a real deployment
needs — atomic writes, step-indexed directories, retention, and structural
restore (leaves are loaded back into the *given* target structure so sharded
restores can re-shard on device_put).
"""
from __future__ import annotations

import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.common.pytree import flatten_with_paths

_STEP_RE = re.compile(r"^step_(\d+)$")


def save_pytree(path: str, tree: Any) -> None:
    """Atomic save of a pytree of arrays to ``<path>.npz``-style file."""
    flat = flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, target: Any) -> Any:
    """Load leaves saved by ``save_pytree`` back into ``target``'s structure."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    tgt_flat = flatten_with_paths(target)
    missing = set(tgt_flat) - set(flat)
    extra = set(flat) - set(tgt_flat)
    if missing or extra:
        raise ValueError(
            f"checkpoint structure mismatch: missing={sorted(missing)[:5]} "
            f"extra={sorted(extra)[:5]}"
        )
    leaves_in_order = []
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    for path_keys, leaf in paths:
        key = "/".join(_key_str(p) for p in path_keys)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves_in_order.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves_in_order)


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    """Step-indexed checkpoint directory with retention."""

    def __init__(self, directory: str, max_to_keep: int = 3):
        self.directory = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def save(self, step: int, tree: Any) -> str:
        d = self._step_dir(step)
        os.makedirs(d, exist_ok=True)
        save_pytree(os.path.join(d, "state.npz"), tree)
        self._gc()
        return d

    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, target: Any, step: Optional[int] = None) -> Any:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(os.path.join(self._step_dir(step), "state.npz"), target)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)


def save_client_states(directory: str, step: int, states,
                       max_to_keep: int = 2, ids=None) -> None:
    """Per-client `(params, opt_state)` checkpoints under
    ``directory/client_{i}`` — the layout every fleet trainer
    (decentralized, FedMD, FedAvg, supervised) shares, so a run is
    resumable per-client regardless of algorithm.

    ``ids`` names the client id of each state (default: positional) — a
    multi-process gossip rank saving only its own clients must not have
    them renumbered from zero."""
    states = list(states)
    ids = range(len(states)) if ids is None else list(ids)
    for i, (params, opt) in zip(ids, states):
        mgr = CheckpointManager(os.path.join(directory, f"client_{i}"),
                                max_to_keep=max_to_keep)
        mgr.save(step, {"params": params, "opt": opt})


def restore_client_states(directory: str, states, step: Optional[int] = None,
                          ids=None):
    """Inverse of `save_client_states`: restores into the given
    ``(params, opt_state)`` targets; returns ``(step, new_states)``."""
    restored = 0
    out = []
    states = list(states)
    ids = range(len(states)) if ids is None else list(ids)
    for i, (params, opt) in zip(ids, states):
        mgr = CheckpointManager(os.path.join(directory, f"client_{i}"))
        state = mgr.restore({"params": params, "opt": opt}, step)
        out.append((state["params"], state["opt"]))
        restored = mgr.latest_step() if step is None else step
    return int(restored), out
