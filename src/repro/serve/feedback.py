"""Serve→distill feedback: production traffic becomes the public stream.

The paper's public pool D_* is "any unlabeled data all clients can see".
A serving front is exactly such a source: every query it answers is an
unlabeled sample every client observed being served. `TrafficLog`
accumulates the served inputs; `attach_traffic` swaps a live trainer's
`PublicPool` for one backed by that log — after which the *existing*
machinery does the rest: clients publish prediction windows on traffic
batches through the metered wire codecs, pull each other's windows, and
distill. Serving is the data pipeline; production load keeps improving
the fleet.

The swap follows the trainer's own ``restore()`` discipline: windows
published against the old pool are invalid under the new sample stream
(`_decode_window` checks sample ids against ``trainer.public``), so
pool entries and pending pulls are cleared and the pools reseeded at the
attach step. ``run_feedback`` is the driver: attach, step N times,
report per-step distill activity and the wire bytes the feedback
traffic cost.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.data.pipeline import PublicPool


class TrafficLog:
    """Served inputs, in arrival order — the feedback corpus."""

    def __init__(self):
        self._images: List[np.ndarray] = []

    def log(self, image: np.ndarray) -> None:
        self._images.append(np.asarray(image))

    def __len__(self) -> int:
        return len(self._images)

    def arrays(self) -> Dict[str, np.ndarray]:
        if not self._images:
            raise ValueError("traffic log is empty; nothing was served")
        return {"images": np.stack(self._images)}


def attach_traffic(trainer, traffic: TrafficLog, step: int) -> PublicPool:
    """Make ``traffic`` the trainer's public distillation stream.

    Mirrors ``DecentralizedTrainer.restore``: the old pool's windows and
    pending pulls are dropped (their sample ids no longer verify), then
    pools are reseeded at ``step`` — publishing fresh windows scored on
    traffic batches over the metered wire."""
    arrays = traffic.arrays()
    pool = PublicPool(arrays, np.arange(len(traffic)),
                      trainer.public.batch_size, seed=trainer.public.seed)
    trainer.public = pool
    for c in trainer.clients:
        c.pool.entries.clear()
    if trainer.exchange != "params":
        trainer._pending = {c.client_id: {} for c in trainer.clients}
    trainer._seed_pools(step=step)
    return pool


def run_feedback(trainer, traffic: TrafficLog, start_step: int,
                 steps: int) -> List[Dict[str, float]]:
    """Attach served traffic and distill ``steps`` more steps from it.
    Returns the per-step metric dicts (``c{i}/distill_active`` says who
    actually distilled from production load)."""
    if steps < 1:
        raise ValueError("run_feedback needs steps >= 1")
    attach_traffic(trainer, traffic, step=start_step)
    return [trainer.step(start_step + k) for k in range(steps)]


def feedback_summary(step_metrics: List[Dict[str, float]],
                     num_clients: int,
                     wire_bytes: Optional[int] = None) -> Dict[str, float]:
    """Fold per-step feedback metrics into the serve report: how many
    client-steps distilled from served traffic, and what it cost on the
    wire."""
    distilled = sum(m.get(f"c{i}/distill_active", 0.0)
                    for m in step_metrics for i in range(num_clients))
    out = {"steps": float(len(step_metrics)),
           "distill_steps": float(distilled)}
    if wire_bytes is not None:
        out["wire_bytes"] = float(wire_bytes)
    return out
