"""Request/response types of the serving front.

One `ServeRequest` is one user query against the fleet. Three kinds:

  * ``"classify"`` — an image scored by one *personalized* client model
    (the router picks which; paper: each client "preserves and enhances
    performance on its private task").
  * ``"teacher"`` — the ensemble prediction of a teacher set on one
    public-pool window (what the distillation wire ships); hot windows
    are served from the `TeacherPredictionCache`.
  * ``"generate"`` — greedy LM decoding through the continuous-batching
    engine (`repro.serve.engine`).

Responses carry the payload plus the serving bookkeeping the benchmarks
aggregate (which client served, cache hit, wall latency, engine ticks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

KINDS = ("classify", "teacher", "generate")


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    kind: str = "classify"
    # classify
    image: Optional[np.ndarray] = None  # (H, W, C)
    label_hint: Optional[int] = None  # routing hint (label affinity)
    client_id: Optional[int] = None  # routing pin (client_id policy)
    # teacher
    window_id: Optional[int] = None  # public-pool step (PublicPool.sample)
    teachers: Optional[Tuple[int, ...]] = None  # None = the whole fleet
    # generate
    prompt: Optional[np.ndarray] = None  # (T,) int32 token ids
    max_new_tokens: int = 16

    def validate(self) -> "ServeRequest":
        if self.kind not in KINDS:
            raise ValueError(f"unknown request kind {self.kind!r}; "
                             f"known: {KINDS}")
        if self.kind == "classify" and self.image is None:
            raise ValueError(f"classify request {self.request_id} "
                             "has no image")
        if self.kind == "teacher" and self.window_id is None:
            raise ValueError(f"teacher request {self.request_id} "
                             "has no window_id")
        if self.kind == "generate":
            if self.prompt is None or np.asarray(self.prompt).ndim != 1:
                raise ValueError(f"generate request {self.request_id} "
                                 "needs a 1-D token prompt")
            if self.max_new_tokens < 1:
                raise ValueError(f"generate request {self.request_id} "
                                 "asks for < 1 new token")
        return self


@dataclasses.dataclass
class ServeResponse:
    request_id: int
    kind: str
    client_id: Optional[int] = None  # who served it (classify/generate)
    label: Optional[int] = None  # classify: argmax class
    logits: Optional[np.ndarray] = None  # classify: (num_labels,)
    predictions: Optional[Dict[str, np.ndarray]] = None  # teacher ensemble
    cache_hit: Optional[bool] = None  # teacher: served from cache?
    tokens: Optional[List[int]] = None  # generate: greedy continuation
    latency_s: float = 0.0  # submit -> complete wall time
    admit_tick: int = -1  # generate: engine tick admitted
    finish_tick: int = -1  # generate: engine tick retired
