"""`ServeFront` — the fleet's inference front door.

One front holds the K personalized models a gossip run trained (loaded
straight from a fleet snapshot — `repro.fleet.snapshot` is the serving
format, no export step), the `Router` that picks who answers, the
`TeacherPredictionCache` for hot-window ensemble queries, the optional
`ContinuousBatchingEngine` for LM generation, and the `TrafficLog` that
turns everything it served into the next distillation stream.

`run_serve_scenario` is the end-to-end story the preset/benchmark/smoke
all drive: train a fleet → snapshot → serve a mixed request stream
against the snapshot → feed the served traffic back as the public pool
and watch clients distill from production load over the metered wire.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import PublicPool
from repro.obs import tracer as trace
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.feedback import TrafficLog, feedback_summary, run_feedback
from repro.serve.request import ServeRequest, ServeResponse
from repro.serve.router import Router
from repro.serve.teacher_cache import TeacherPredictionCache


class ServeFront:
    def __init__(self, bundles: List[Any], params: List[Any],
                 router: Router, public: PublicPool,
                 cache: Optional[TeacherPredictionCache] = None,
                 engine: Optional[ContinuousBatchingEngine] = None,
                 log_traffic: bool = True,
                 snapshot_step: Optional[int] = None):
        if len(bundles) != len(params):
            raise ValueError(f"{len(bundles)} bundles, "
                             f"{len(params)} param sets")
        self.bundles = bundles
        self.params = params
        self.router = router
        self.public = public
        self.cache = cache if cache is not None else TeacherPredictionCache()
        self.engine = engine
        self.traffic = TrafficLog() if log_traffic else None
        self.snapshot_step = snapshot_step
        self._apply_cache: Dict[str, Callable] = {}
        self.served: Dict[str, int] = {"classify": 0, "teacher": 0,
                                       "generate": 0}

    # -- construction ------------------------------------------------------

    @classmethod
    def from_snapshot(cls, spec, snapshot_dir: str,
                      data: Optional[Tuple] = None,
                      engine: Optional[ContinuousBatchingEngine] = None
                      ) -> "ServeFront":
        """Serve a trained fleet directly from its snapshot directory.

        ``spec`` is the `ExperimentSpec` the fleet trained under (it
        determines architectures, the partition, and the public pool's
        sample stream); ``data`` forwards a pre-materialized
        ``(arrays, test_arrays, partition)`` triple to skip regenerating
        the dataset."""
        from repro.exp.runner import build_bundles, materialize_data
        from repro.fleet.snapshot import load_client_params

        arrays, _test, part = (data if data is not None else
                               materialize_data(spec.data, spec.partition,
                                                spec.num_clients))
        bundles = build_bundles(spec)
        params, steps = [], []
        for i, b in enumerate(bundles):
            # any key works: load_client_params only needs the pytree
            # structure and shapes; the loaded values replace every leaf
            like = b.init(jax.random.fold_in(
                jax.random.PRNGKey(spec.train.seed), i))
            p, s = load_client_params(snapshot_dir, i, like)
            params.append(p)
            steps.append(s)
        serve = getattr(spec, "serve", None)
        router = Router.from_partition(
            part, arrays["labels"], spec.data.num_labels,
            policy=serve.router if serve is not None else "label_affinity")
        public = PublicPool(arrays, part.public_indices,
                            spec.train.public_batch_size,
                            seed=spec.train.seed)
        cache = TeacherPredictionCache(
            serve.cache_windows if serve is not None else 8)
        return cls(bundles, params, router, public, cache=cache,
                   engine=engine, snapshot_step=min(steps))

    def _apply(self, bundle) -> Callable:
        if bundle.name not in self._apply_cache:
            def apply_fn(params, batch):
                return bundle.apply(params, batch)["logits"]

            self._apply_cache[bundle.name] = jax.jit(apply_fn)
        return self._apply_cache[bundle.name]

    # -- the three request kinds ------------------------------------------

    def classify(self, request: ServeRequest) -> ServeResponse:
        t0 = time.perf_counter()
        cid = self.router.route(request)
        with trace.span("serve/classify", request=request.request_id,
                        client=cid):
            logits = np.asarray(self._apply(self.bundles[cid])(
                self.params[cid],
                {"images": jnp.asarray(request.image[None])}))[0]
        if self.traffic is not None:
            self.traffic.log(request.image)
        self.served["classify"] += 1
        return ServeResponse(
            request_id=request.request_id, kind="classify", client_id=cid,
            label=int(np.argmax(logits)), logits=logits,
            latency_s=time.perf_counter() - t0)

    def teacher_window(self, request: ServeRequest) -> ServeResponse:
        t0 = time.perf_counter()
        teachers = (request.teachers if request.teachers is not None
                    else tuple(range(len(self.bundles))))
        window_id = int(request.window_id)

        def compute() -> Dict[str, np.ndarray]:
            batch = {k: jnp.asarray(v)
                     for k, v in self.public.sample(window_id).items()}
            stacked = np.stack([
                np.asarray(self._apply(self.bundles[t])(
                    self.params[t], batch)) for t in teachers])
            return {"logits": stacked.mean(axis=0),
                    "sample_ids":
                        self.public.sample_ids(window_id).astype(np.uint64)}

        preds, hit = self.cache.get_or_compute(window_id, teachers, compute)
        if self.traffic is not None and not hit:
            for img in self.public.sample(window_id)["images"]:
                self.traffic.log(img)
        self.served["teacher"] += 1
        return ServeResponse(
            request_id=request.request_id, kind="teacher",
            predictions=preds, cache_hit=hit,
            latency_s=time.perf_counter() - t0)

    def generate(self, requests: List[ServeRequest]) -> List[ServeResponse]:
        if self.engine is None:
            raise ValueError("this front has no decode engine "
                             "(ServeSpec.engine_arch unset)")
        for r in requests:
            self.engine.submit(r)
        out = self.engine.run()
        self.served["generate"] += len(out)
        return out

    def serve(self, request: ServeRequest) -> ServeResponse:
        request.validate()
        if request.kind == "classify":
            return self.classify(request)
        if request.kind == "teacher":
            return self.teacher_window(request)
        return self.generate([request])[0]

    # -- reporting ---------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            f"served/{k}": float(v) for k, v in self.served.items()}
        for k, v in self.cache.ledger.summary().items():
            out[f"cache/{k}"] = v
        for k, v in self.router.summary().items():
            out[f"route/{k}"] = v
        if self.engine is not None:
            for k, v in self.engine.summary().items():
                out[f"engine/{k}"] = v
        return out


# -- the end-to-end scenario --------------------------------------------------


@dataclasses.dataclass
class ServeScenarioResult:
    """Everything the serve scenario produced: JSON-safe ``metrics`` plus
    the live front/trainer for drill-downs (never serialized)."""

    spec: Any
    metrics: Dict[str, float]
    responses: List[ServeResponse]
    front: ServeFront = dataclasses.field(repr=False)
    experiment: Any = dataclasses.field(repr=False)


def _request_stream(spec, test_arrays, rng) -> List[ServeRequest]:
    """A mixed stream: classify queries with label hints (drawn from the
    held-out set) interleaved with teacher-window queries cycling over a
    few hot windows — the cycle (not a random draw) guarantees window
    reuse whenever a window is queried twice, so the cache-hit
    acceptance holds even for the 8-request smoke."""
    serve = spec.serve
    n = serve.requests
    hot_windows = max(1, n // 8)
    out: List[ServeRequest] = []
    images, labels = test_arrays["images"], test_arrays["labels"]
    teacher_queries = 0
    for rid in range(n):
        if rid % 3 == 2:  # every third query asks for teacher predictions
            out.append(ServeRequest(
                request_id=rid, kind="teacher",
                window_id=teacher_queries % hot_windows,
                teachers=serve.teachers))
            teacher_queries += 1
        else:
            i = int(rng.integers(0, images.shape[0]))
            out.append(ServeRequest(
                request_id=rid, kind="classify", image=images[i],
                label_hint=int(labels[i])))
    return out


def _generate_stream(spec, vocab_size: int, rng) -> List[ServeRequest]:
    """Mixed-length decode requests — the lengths are deliberately skewed
    so static batching visibly stalls short requests behind long ones."""
    serve = spec.serve
    out = []
    for rid in range(max(serve.num_slots * 2, 4)):
        prompt_len = int(rng.integers(4, 9))
        out.append(ServeRequest(
            request_id=10_000 + rid, kind="generate",
            prompt=rng.integers(0, vocab_size, size=prompt_len,
                                dtype=np.int32),
            max_new_tokens=int(rng.integers(1, serve.max_new_tokens + 1))))
    return out


def build_engine(spec, admission: str = "continuous"
                 ) -> ContinuousBatchingEngine:
    """The spec's decode engine: a reduced zoo LM with deterministic
    params (`ServeSpec.engine_arch`/``seed``)."""
    from repro.configs import get_reduced
    from repro.models.zoo import build_bundle

    serve = spec.serve
    cfg = get_reduced(serve.engine_arch)
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(serve.seed))
    cache_len = 8 + serve.max_new_tokens  # prompt lengths top out at 8
    return ContinuousBatchingEngine(bundle, params,
                                    num_slots=serve.num_slots,
                                    cache_len=cache_len,
                                    admission=admission)


def run_serve_scenario(spec, workdir: str) -> ServeScenarioResult:
    """Train → snapshot → serve → feed back. The one path behind the
    ``serve_loop`` preset, `benchmarks/serve.py --smoke`, and the
    end-to-end tests."""
    from repro.exp.runner import materialize_data, run_spec

    serve = spec.serve
    if serve is None or serve.requests <= 0:
        raise ValueError("spec.serve.requests must be > 0 to serve")
    snap_dir = os.path.join(workdir, "snapshots")
    train = spec.train
    if not train.snapshot_dir:
        train = dataclasses.replace(
            train, snapshot_dir=snap_dir,
            snapshot_every=train.snapshot_every or train.steps)
        spec = dataclasses.replace(spec, train=train)
    if serve.feedback_steps > 0 and spec.optimizer.total_steps is None:
        # the cosine schedule reaches exactly zero at total_steps — the
        # run is really train.steps + feedback_steps long, and feedback
        # updates at lr=0 would "distill" without moving a single param
        spec = dataclasses.replace(spec, optimizer=dataclasses.replace(
            spec.optimizer,
            total_steps=train.steps + serve.feedback_steps))
    spec = spec.validate()

    data = materialize_data(spec.data, spec.partition, spec.num_clients)
    result = run_spec(spec, data=data)

    engine = None
    if serve.engine_arch is not None:
        engine = build_engine(spec)
    front = ServeFront.from_snapshot(spec, spec.train.snapshot_dir,
                                     data=data, engine=engine)

    rng = np.random.default_rng(serve.seed)
    t_serve = time.perf_counter()
    responses = [front.serve(r)
                 for r in _request_stream(spec, data[1], rng)]
    if engine is not None:
        responses.extend(front.generate(_generate_stream(
            spec, engine.bundle.config.vocab_size, rng)))
    serve_wall = time.perf_counter() - t_serve

    metrics: Dict[str, float] = dict(front.stats())
    metrics["serve/wall_s"] = serve_wall
    metrics["serve/requests_per_s"] = len(responses) / max(serve_wall, 1e-9)
    lat = sorted(r.latency_s for r in responses)
    metrics["serve/p50_ms"] = lat[len(lat) // 2] * 1e3
    metrics["serve/p99_ms"] = lat[min(len(lat) - 1,
                                      int(len(lat) * 0.99))] * 1e3
    metrics["serve/snapshot_step"] = float(front.snapshot_step)

    if serve.feedback_steps > 0:
        trainer = result.trainer
        bytes_before = trainer.meter.total_bytes
        fb = run_feedback(trainer, front.traffic, spec.train.steps,
                          serve.feedback_steps)
        for k, v in feedback_summary(
                fb, spec.num_clients,
                wire_bytes=trainer.meter.total_bytes - bytes_before).items():
            metrics[f"feedback/{k}"] = v

    return ServeScenarioResult(spec=spec, metrics=metrics,
                               responses=responses, front=front,
                               experiment=result)
