"""Continuous-batching greedy-decode engine over the zoo's ``decode_step``.

The unit of batching is a *slot*: a lane of a vmapped decode step with
its own KV/state cache (batch=1 per lane, stacked on a leading slot
axis). ``jax.vmap(decode_step)`` makes every per-lane cache leaf —
including the scalar ring-buffer ``index`` — independent per slot, so
lanes sit at *different* decode positions inside one jitted step. That
is what makes the batching continuous: a finished request retires its
lane and a queued request is admitted into it at the next tick, while
the other lanes keep decoding — mixed generation lengths never stall
each other.

Admission is a policy on the same engine:

  * ``"continuous"`` — fill any free lane at any tick (the production
    mode).
  * ``"static"`` — admit only when *all* lanes are free (classic static
    batching: the batch drains fully before the next one forms). The
    benchmark's continuous-vs-static comparison flips this one flag, so
    the two modes share 100% of the compute path.

Prompt ingestion is the fused `Prefill`: one jitted
``lax.scan(decode_step)`` over the whole prompt, bitwise-identical to
the token-by-token python loop it replaced (asserted in
tests/test_serve.py) but one device dispatch instead of T.

Numerics contract: a lane's cache is written wholesale at admission
(prefill runs at batch=1, exactly the solo path), and vmap keeps lane
computations independent — so a request's greedy token sequence does not
depend on which other requests share the engine. Batched XLA reductions
may reorder float adds vs a solo B=1 run, so cross-shape comparisons are
argmax-token-exact rather than logit-bitwise (see tests).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import tracer as trace
from repro.serve.request import ServeRequest, ServeResponse

ADMISSION = ("continuous", "static")


class Prefill:
    """Fused full-prompt prefill: one jitted scan over ``decode_step``.

    ``__call__(params, tokens(B, T), caches)`` returns
    ``(caches, logits(T, B, 1, V))`` — the caches warmed through the
    whole prompt and every step's logits (``logits[-1]`` feeds the first
    generated token). jit retraces per (B, T) shape; the traced scan body
    is exactly one ``decode_step``, so the math is the step-wise loop's,
    fused."""

    def __init__(self, bundle):
        if not getattr(bundle, "is_lm", False):
            raise ValueError(f"bundle {bundle.name!r} has no decode path")
        self.bundle = bundle

        def _prefill(params, tokens, caches):
            def body(caches, tok):
                logits, caches = bundle.decode_step(
                    params, tok[:, None], caches)
                return caches, logits

            return jax.lax.scan(body, caches, tokens.T)

        self._fn = jax.jit(_prefill)

    def __call__(self, params, tokens, caches):
        return self._fn(params, tokens, caches)


@dataclasses.dataclass
class _Lane:
    """One occupied slot: the request plus its accumulated greedy tokens."""

    request: ServeRequest
    tokens: List[int]
    submit_s: float
    admit_tick: int


class ContinuousBatchingEngine:
    """Greedy decoding for a stream of `ServeRequest`s over one model.

    ``submit`` enqueues; ``tick`` advances the engine one decode step
    (admitting and retiring lanes as it goes) and returns the responses
    completed that tick; ``run`` ticks until drained. One engine serves
    one (bundle, params) pair — a fleet front holds one per distinct
    model it decodes with.
    """

    def __init__(self, bundle, params, num_slots: int = 4,
                 cache_len: int = 64, admission: str = "continuous",
                 cache_dtype=jnp.float32):
        if admission not in ADMISSION:
            raise ValueError(f"unknown admission policy {admission!r}; "
                             f"known: {ADMISSION}")
        if num_slots < 1:
            raise ValueError("engine needs at least one slot")
        self.bundle = bundle
        self.params = params
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.admission = admission
        self.cache_dtype = cache_dtype
        self.prefill = Prefill(bundle)
        # vmap over the slot axis: params broadcast, token + cache per-lane
        self._vstep = jax.jit(jax.vmap(bundle.decode_step,
                                       in_axes=(None, 0, 0)))
        lane_cache = bundle.init_cache(1, cache_len, cache_dtype)
        self.caches = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * num_slots), lane_cache)
        self.tokens = jnp.zeros((num_slots, 1, 1), dtype=jnp.int32)
        self.lanes: List[Optional[_Lane]] = [None] * num_slots
        self.queue: Deque[ServeRequest] = deque()
        self._submit_s: Dict[int, float] = {}
        # occupancy/throughput counters (benchmarks/serve.py)
        self.ticks = 0
        self.decode_ticks = 0
        self.prefills = 0
        self.completed = 0
        self.lane_ticks_busy = 0
        self.lane_ticks_total = 0

    # -- request intake ----------------------------------------------------

    def submit(self, request: ServeRequest) -> None:
        request.validate()
        if request.kind != "generate":
            raise ValueError(f"engine only decodes; request "
                             f"{request.request_id} is {request.kind!r}")
        total = len(np.asarray(request.prompt)) + request.max_new_tokens
        if total > self.cache_len:
            raise ValueError(
                f"request {request.request_id} needs {total} cache "
                f"positions, engine has {self.cache_len} (ring wrap "
                "would corrupt full attention)")
        self._submit_s[request.request_id] = time.perf_counter()
        self.queue.append(request)

    # -- lane lifecycle ----------------------------------------------------

    def _write_lane(self, slot: int, caches, tok0: int) -> None:
        self.caches = jax.tree_util.tree_map(
            lambda full, one: full.at[slot].set(one.astype(full.dtype)),
            self.caches, caches)
        self.tokens = self.tokens.at[slot, 0, 0].set(tok0)

    def _retire(self, slot: int, done: List[ServeResponse]) -> None:
        lane = self.lanes[slot]
        self.lanes[slot] = None
        self.completed += 1
        done.append(ServeResponse(
            request_id=lane.request.request_id, kind="generate",
            tokens=list(lane.tokens),
            latency_s=time.perf_counter() - lane.submit_s,
            admit_tick=lane.admit_tick, finish_tick=self.ticks))

    def _admit(self, done: List[ServeResponse]) -> None:
        free = [i for i, lane in enumerate(self.lanes) if lane is None]
        if self.admission == "static" and len(free) != self.num_slots:
            return  # static batching: drain the whole batch first
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.popleft()
            prompt = jnp.asarray(
                np.asarray(req.prompt, dtype=np.int32)[None, :])
            with trace.span("serve/prefill", request=req.request_id,
                            slot=slot, prompt_len=int(prompt.shape[1])):
                caches = self.bundle.init_cache(1, self.cache_len,
                                                self.cache_dtype)
                caches, logits = self.prefill(self.params, prompt, caches)
                tok0 = int(jnp.argmax(logits[-1][0, -1]))
            self.prefills += 1
            self._write_lane(slot, caches, tok0)
            self.lanes[slot] = _Lane(
                request=req, tokens=[tok0],
                submit_s=self._submit_s.pop(req.request_id,
                                            time.perf_counter()),
                admit_tick=self.ticks)
            if req.max_new_tokens == 1:
                self._retire(slot, done)  # prompt-only ask: done at admit

    # -- stepping ----------------------------------------------------------

    def tick(self) -> List[ServeResponse]:
        """One engine tick: admit into free lanes, then one vmapped decode
        step for every lane (idle lanes decode garbage that nobody
        reads). Returns the requests completed this tick."""
        done: List[ServeResponse] = []
        self._admit(done)
        active = [i for i, lane in enumerate(self.lanes) if lane is not None]
        if active:
            with trace.span("serve/decode", active=len(active),
                            tick=self.ticks):
                logits, self.caches = self._vstep(
                    self.params, self.tokens, self.caches)
                nxt = jnp.argmax(logits[:, :, -1], axis=-1)  # (S, 1)
                self.tokens = nxt[:, :, None].astype(jnp.int32)
                nxt_np = np.asarray(nxt)
            self.decode_ticks += 1
            self.lane_ticks_busy += len(active)
            self.lane_ticks_total += self.num_slots
            for slot in active:
                lane = self.lanes[slot]
                lane.tokens.append(int(nxt_np[slot, 0]))
                if len(lane.tokens) >= lane.request.max_new_tokens:
                    self._retire(slot, done)
        self.ticks += 1
        return done

    def run(self, max_ticks: Optional[int] = None) -> List[ServeResponse]:
        """Tick until every queued and in-flight request completes."""
        out: List[ServeResponse] = []
        while self.queue or any(lane is not None for lane in self.lanes):
            out.extend(self.tick())
            if max_ticks is not None and self.ticks >= max_ticks:
                raise RuntimeError(
                    f"engine did not drain within {max_ticks} ticks "
                    f"({len(self.queue)} queued, "
                    f"{sum(l is not None for l in self.lanes)} in flight)")
        return out

    # -- reporting ---------------------------------------------------------

    def occupancy(self) -> float:
        """Busy lane-ticks / total lane-ticks over decode ticks — the
        number static batching loses on mixed generation lengths."""
        return (self.lane_ticks_busy / self.lane_ticks_total
                if self.lane_ticks_total else 0.0)

    def summary(self) -> Dict[str, float]:
        return {"ticks": float(self.ticks),
                "decode_ticks": float(self.decode_ticks),
                "prefills": float(self.prefills),
                "completed": float(self.completed),
                "occupancy": self.occupancy()}


def solo_generate(bundle, params, prompt: np.ndarray, max_new_tokens: int,
                  cache_len: int) -> List[int]:
    """Reference single-request greedy decode: fused prefill + an
    unbatched ``jit(decode_step)`` loop at B=1, no slot engine and no
    vmap — the determinism oracle the continuous-batch tests compare
    against."""
    tokens = jnp.asarray(np.asarray(prompt, dtype=np.int32)[None, :])
    caches = bundle.init_cache(1, cache_len, jnp.float32)
    caches, logits = Prefill(bundle)(params, tokens, caches)
    step = jax.jit(bundle.decode_step)
    tok = jnp.argmax(logits[-1][:, -1:], axis=-1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    while len(out) < max_new_tokens:
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out
