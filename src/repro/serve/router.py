"""Request router: which personalized client model answers a query.

The fleet that comes out of a gossip run is not one model — it is K
*personalized* models, each strongest on its own primary labels (the
paper's β_priv axis). Routing is therefore a first-class serving
decision:

  * ``"client_id"`` — the request pins a client (a returning user hits
    their own model); unpinned requests fall back to round-robin.
  * ``"label_affinity"`` — route by the request's label hint to the
    client whose private shard is densest in that label (the partition's
    label histogram, the same affinity map `DecentralizedTrainer` keeps
    as ``ClientState.label_hist``); hintless requests round-robin.
  * ``"round_robin"`` — plain load spreading.

`Router.from_partition` builds the affinity map from the run's
`Partition`, so the router and the trainer agree on who owns what.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Optional

import numpy as np

from repro.obs import tracer as trace
from repro.serve.request import ServeRequest

POLICIES = ("client_id", "label_affinity", "round_robin")


class Router:
    def __init__(self, num_clients: int,
                 affinity: Optional[np.ndarray] = None,
                 policy: str = "label_affinity"):
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"known: {POLICIES}")
        if policy == "label_affinity":
            if affinity is None:
                raise ValueError(
                    "label_affinity routing needs the (K, num_labels) "
                    "affinity map; build via Router.from_partition")
            affinity = np.asarray(affinity, dtype=np.float64)
            if affinity.ndim != 2 or affinity.shape[0] != num_clients:
                raise ValueError(
                    f"affinity shape {affinity.shape} does not cover "
                    f"{num_clients} clients")
        self.num_clients = num_clients
        self.affinity = affinity
        self.policy = policy
        self._rr = 0
        self.by_client: Dict[int, int] = defaultdict(int)

    @classmethod
    def from_partition(cls, partition, labels: np.ndarray,
                       num_labels: int,
                       policy: str = "label_affinity") -> "Router":
        """Affinity rows are each client's private-shard label histogram —
        identical to the trainer's per-client ``label_hist``."""
        from repro.core.evaluation import label_histogram

        affinity = np.stack([
            label_histogram(labels, idx, num_labels)
            for idx in partition.client_indices])
        return cls(len(partition.client_indices), affinity=affinity,
                   policy=policy)

    def _round_robin(self) -> int:
        cid = self._rr % self.num_clients
        self._rr += 1
        return cid

    def route(self, request: ServeRequest) -> int:
        with trace.span("serve/route", request=request.request_id,
                        policy=self.policy):
            cid = self._decide(request)
        self.by_client[cid] += 1
        return cid

    def _decide(self, request: ServeRequest) -> int:
        if request.client_id is not None:
            cid = int(request.client_id)
            if not 0 <= cid < self.num_clients:
                raise ValueError(f"request {request.request_id} pins "
                                 f"client {cid} of {self.num_clients}")
            return cid
        if self.policy == "label_affinity" and \
                request.label_hint is not None:
            # argmax ties resolve to the lowest client id — deterministic
            return int(np.argmax(self.affinity[:, int(request.label_hint)]))
        return self._round_robin()

    def summary(self) -> Dict[str, float]:
        total = sum(self.by_client.values())
        out = {"routed": float(total)}
        for cid in range(self.num_clients):
            out[f"c{cid}"] = float(self.by_client.get(cid, 0))
        return out
