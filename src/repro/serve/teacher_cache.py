"""Teacher-prediction cache: reuse ensemble predictions on hot windows.

Serving a teacher ensemble is the expensive query class — K forward
passes over a public batch. But public-pool windows are *deterministic
in (seed, step)* (`PublicPool.sample`), so the ensemble output for a
(window id, teacher set) pair is a pure value: repeated queries against
a hot window can be answered from cache, byte-identical to recompute
(asserted in tests/test_serve.py).

`TeacherPredictionCache` is an LRU keyed by
``(window_id, tuple(sorted(teacher_set)))`` — teacher-set order never
splits an entry. `CacheLedger` is the `CommMeter`-style book of what
the cache did: hit/miss/eviction counts, the bytes each book moved, and
per-window hit counters (which windows are actually hot), with a
``summary()`` the benchmarks fold into their rows.
"""
from __future__ import annotations

from collections import OrderedDict, defaultdict
from typing import Any, Callable, Dict, Tuple

import numpy as np

from repro.obs import tracer as trace

CacheKey = Tuple[int, Tuple[int, ...]]


def _nbytes(value: Dict[str, np.ndarray]) -> int:
    return int(sum(np.asarray(v).nbytes for v in value.values()))


class CacheLedger:
    """Hit/miss/eviction books of the teacher cache (CommMeter idiom:
    plain counters + dict books, ``summary()`` for the metric fold)."""

    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.hit_bytes = 0  # bytes served without recompute
        self.miss_bytes = 0  # bytes computed and inserted
        self.by_window_hits: Dict[int, int] = defaultdict(int)
        self.by_window_misses: Dict[int, int] = defaultdict(int)

    def record_hit(self, window_id: int, nbytes: int) -> None:
        self.hits += 1
        self.hit_bytes += nbytes
        self.by_window_hits[window_id] += 1

    def record_miss(self, window_id: int, nbytes: int) -> None:
        self.misses += 1
        self.miss_bytes += nbytes
        self.by_window_misses[window_id] += 1

    def record_eviction(self, window_id: int) -> None:
        self.evictions += 1

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def summary(self) -> Dict[str, float]:
        return {"hits": float(self.hits),
                "misses": float(self.misses),
                "evictions": float(self.evictions),
                "hit_rate": self.hit_rate(),
                "hit_bytes": float(self.hit_bytes),
                "miss_bytes": float(self.miss_bytes)}

    def format_table(self) -> str:
        lines = ["window     hits   misses"]
        for w in sorted(set(self.by_window_hits)
                        | set(self.by_window_misses)):
            lines.append(f"{w:6d} {self.by_window_hits[w]:8d} "
                         f"{self.by_window_misses[w]:8d}")
        s = self.summary()
        lines.append(f"total: {self.hits} hits / {self.misses} misses "
                     f"({s['hit_rate']:.0%}), {self.evictions} evicted")
        return "\n".join(lines)


class TeacherPredictionCache:
    """LRU of ensemble predictions keyed by (window id, teacher set)."""

    def __init__(self, capacity: int = 8, ledger: CacheLedger = None):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.ledger = ledger if ledger is not None else CacheLedger()
        self._store: "OrderedDict[CacheKey, Dict[str, np.ndarray]]" = \
            OrderedDict()

    @staticmethod
    def key(window_id: int, teachers) -> CacheKey:
        return (int(window_id), tuple(sorted(int(t) for t in teachers)))

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: CacheKey) -> bool:
        return key in self._store

    def get_or_compute(self, window_id: int, teachers,
                       compute: Callable[[], Dict[str, np.ndarray]]
                       ) -> Tuple[Dict[str, np.ndarray], bool]:
        """The cached value for (window, teacher set), computing and
        inserting on miss. Returns ``(predictions, hit)``; a hit returns
        the stored arrays themselves — byte-identical to what the miss
        computed."""
        key = self.key(window_id, teachers)
        with trace.span("serve/cache", window=key[0],
                        teachers=len(key[1])):
            if key in self._store:
                self._store.move_to_end(key)
                value = self._store[key]
                self.ledger.record_hit(key[0], _nbytes(value))
                return value, True
            value = compute()
            self._store[key] = value
            self.ledger.record_miss(key[0], _nbytes(value))
            while len(self._store) > self.capacity:
                old_key, _ = self._store.popitem(last=False)
                self.ledger.record_eviction(old_key[0])
            return value, False
