"""repro.serve — the fleet's inference path (ROADMAP item 5).

A trained gossip fleet is K personalized models; this package serves
them:

  request.py        `ServeRequest` / `ServeResponse` — classify,
                    teacher-window, and generate query kinds.
  router.py         `Router` — client-id / label-affinity / round-robin
                    mapping from request to personalized model, built
                    from the run's `Partition`.
  engine.py         `ContinuousBatchingEngine` + fused `Prefill` — slot
                    -based greedy decoding over the zoo's ``decode_step``
                    (vmapped per-lane caches; admit/retire at any tick),
                    with static batching as a one-flag admission policy
                    for the benchmark comparison.
  teacher_cache.py  `TeacherPredictionCache` + `CacheLedger` — LRU of
                    ensemble predictions keyed by (public window,
                    teacher set); hits are byte-identical to recompute.
  feedback.py       `TrafficLog` / `attach_traffic` / `run_feedback` —
                    served traffic becomes the public distillation
                    stream of a live trainer (serve→distill loop).
  front.py          `ServeFront` — snapshot-loading front door tying the
                    above together, and `run_serve_scenario`, the
                    train→snapshot→serve→feed-back end-to-end driver.

Declared via `ServeSpec` on the `ExperimentSpec` surface (preset
``serve_loop``); measured by `benchmarks/serve.py` → BENCH_serve.json;
traced under the ``serve/*`` spans (`docs/serving.md`).
"""
from repro.serve.engine import (
    ContinuousBatchingEngine,
    Prefill,
    solo_generate,
)
from repro.serve.feedback import (
    TrafficLog,
    attach_traffic,
    feedback_summary,
    run_feedback,
)
from repro.serve.front import (
    ServeFront,
    ServeScenarioResult,
    build_engine,
    run_serve_scenario,
)
from repro.serve.request import ServeRequest, ServeResponse
from repro.serve.router import Router
from repro.serve.teacher_cache import CacheLedger, TeacherPredictionCache

__all__ = [
    "CacheLedger",
    "ContinuousBatchingEngine",
    "Prefill",
    "Router",
    "ServeFront",
    "ServeRequest",
    "ServeResponse",
    "ServeScenarioResult",
    "TeacherPredictionCache",
    "TrafficLog",
    "attach_traffic",
    "build_engine",
    "feedback_summary",
    "run_feedback",
    "run_serve_scenario",
    "solo_generate",
]
