from repro.common.pytree import (
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_axpy,
    tree_l2_norm,
    tree_size,
    tree_bytes,
    tree_cast,
)
from repro.common.registry import Registry
from repro.common.dtypes import DtypePolicy

__all__ = [
    "tree_zeros_like",
    "tree_add",
    "tree_scale",
    "tree_axpy",
    "tree_l2_norm",
    "tree_size",
    "tree_bytes",
    "tree_cast",
    "Registry",
    "DtypePolicy",
]
