"""Mixed-precision policy.

TPU v5e target: params stored bf16/fp32, compute bf16, reductions fp32.
On CPU (tests / tiny experiments) everything defaults to fp32.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    accum_dtype: jnp.dtype = jnp.float32

    @staticmethod
    def tpu_bf16() -> "DtypePolicy":
        return DtypePolicy(
            param_dtype=jnp.bfloat16,
            compute_dtype=jnp.bfloat16,
            accum_dtype=jnp.float32,
        )

    @staticmethod
    def fp32() -> "DtypePolicy":
        return DtypePolicy()

    def cast_compute(self, x):
        return x.astype(self.compute_dtype)
