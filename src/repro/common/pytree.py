"""Pytree utilities used across the framework.

These are deliberately tiny wrappers over ``jax.tree_util`` so that optimizer,
checkpointing and FedAvg code reads as math, not as tree plumbing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x, y):
    """a * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_mean(trees):
    """Leafwise mean of a list of pytrees (FedAvg primitive)."""
    n = float(len(trees))
    out = trees[0]
    for t in trees[1:]:
        out = tree_add(out, t)
    return tree_scale(out, 1.0 / n)


def tree_l2_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_size(tree) -> int:
    """Total number of parameters."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_any_nan(tree):
    leaves = [
        jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    ]
    if not leaves:
        return jnp.asarray(False)
    return jnp.any(jnp.stack(leaves))


def flatten_with_paths(tree):
    """Return {'/'-joined-path: leaf} dict — stable naming for checkpoints."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)
