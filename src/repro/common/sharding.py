"""Activation-sharding helpers usable from inside model code.

``maybe_shard(x, *axes)`` applies a ``with_sharding_constraint`` when tracing
under a mesh context (pjit path) and is a no-op otherwise (CPU tests, tiny
experiments). Axis entries may be None, a mesh-axis name, or a tuple of
names; names not present in the active mesh are dropped, so the same model
code serves the (data, model) pod mesh and the (pod, data, model) multi-pod
mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisLike = Union[None, str, Tuple[str, ...]]

# Logical roles used by model code; launch/shardings.py can override this
# mapping (a §Perf lever — e.g. sequence-sharding long contexts).
_LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "model": "model",
    "expert": "model",
    "fsdp_tokens": ("pod", "data"),  # token/slot dims inside manual regions
    "none": None,
}


def set_logical_rule(role: str, axes: AxisLike) -> None:
    _LOGICAL_RULES[role] = axes


def get_logical_rule(role: str) -> AxisLike:
    return _LOGICAL_RULES.get(role)


def _active_mesh_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not getattr(mesh, "axis_names", None):
        return None
    return set(mesh.axis_names)


def _filter(axis: AxisLike, names) -> AxisLike:
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in names else None
    kept = tuple(a for a in axis if a in names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def _mesh_axis_sizes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return {}
    if mesh is None or not getattr(mesh, "axis_names", None):
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _divisible(axis: AxisLike, dim: int, sizes) -> AxisLike:
    """Drop the constraint when the dim doesn't divide the axis product —
    otherwise XLA falls back to 'involuntary full rematerialization'."""
    if axis is None:
        return None
    names = (axis,) if isinstance(axis, str) else axis
    prod = 1
    for n in names:
        prod *= sizes.get(n, 1)
    return axis if prod > 1 and dim % prod == 0 else None


def maybe_shard(x, *roles: str):
    """Constrain ``x`` so dim i lies on the mesh axes for logical role i."""
    sizes = _mesh_axis_sizes()
    if not sizes:
        return x
    names = set(sizes)
    axes = tuple(_filter(_LOGICAL_RULES.get(r), names) for r in roles)
    if len(axes) != x.ndim:
        raise ValueError(f"maybe_shard got {len(axes)} roles for rank-{x.ndim} array")
    axes = tuple(_divisible(a, x.shape[i], sizes) for i, a in enumerate(axes))
    if all(a is None for a in axes):
        return x
    return jax.lax.with_sharding_constraint(x, P(*axes))
