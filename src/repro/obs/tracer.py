"""Span/counter/instant tracer with an explicit no-op mode.

Design constraints, in order:

  1. **Disabled is free.** Tracing is off by default and the instrumented
     hot paths (`runtime.step_client`, `socket.send`, `bus.deliver`, the
     wire codecs) run per message / per step. Every module-level hook
     (``span``/``instant``/``counter``/``flow_*``) is one global read and
     an early return of a shared immutable no-op context manager — no
     allocation beyond the kwargs dict, no lock, no clock read. The
     acceptance bound is < 2% on the in-process ``quick`` preset.
  2. **Enabled is bounded.** Events land in a ring buffer
     (``capacity`` events, oldest dropped first, drops counted) behind a
     lock, so a run that produces millions of events degrades to a
     truncated trace instead of unbounded memory.
  3. **Timestamps are local.** ``time.perf_counter()`` — monotonic but
     with a per-process arbitrary epoch. Cross-process alignment is the
     merge step's job (`export.merge_traces`), using rendezvous-handshake
     *anchors* recorded here via ``set_anchor``.

Event kinds map 1:1 onto Chrome trace-event phases (`export.py`):
``"X"`` complete span, ``"i"`` instant, ``"C"`` counter, ``"s"``/``"f"``
flow start/finish. A flow links one socket send span to its delivery
span across processes; both ends derive the same 64-bit id from
``flow_id(src, dst, sent_step)`` so no coordination is needed.

Usage::

    from repro.obs import trace

    trace.enable(rank=3)                      # or leave disabled (no-op)
    with trace.span("encode", client=1, nbytes=n):
        ...
    trace.instant("gate_skip", client=1)
    trace.counter("mailbox", 4, client=1)
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = [
    "Tracer", "active", "complete", "counter", "disable", "enable",
    "flow_end", "flow_id", "flow_start", "get", "instant", "now", "span",
    "set_anchor",
]


def flow_id(src: int, dst: int, sent_step: int) -> int:
    """Deterministic 64-bit flow id for one frame on one edge: both the
    sending and the receiving process compute the same id from what the
    frame header carries, so send→delivery arrows need no handshake.
    (One publish produces at most one frame per (src, dst, step).)"""
    return (((src & 0xFFFF) << 48) | ((dst & 0xFFFF) << 32)
            | (sent_step & 0xFFFFFFFF))


class _NoopSpan:
    """Shared do-nothing context manager — the disabled-mode fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._tracer._emit({"ph": "X", "name": self._name, "ts": self._t0,
                            "dur": t1 - self._t0, "tid": _tid(),
                            "args": self._args or {}})
        return False


def _tid() -> int:
    return threading.get_ident()


class Tracer:
    """Ring-buffered event recorder for one process (one trace track)."""

    def __init__(self, capacity: int = 1 << 17, rank: int = 0,
                 process_name: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.rank = int(rank)
        self.process_name = process_name or f"rank {rank}"
        self._events: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.emitted = 0
        self.anchors: Dict[str, float] = {}

    # -- recording --------------------------------------------------------

    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(ev)
            self.emitted += 1

    def span(self, name: str,
             args: Optional[Dict[str, Any]] = None) -> _Span:
        return _Span(self, name, args)

    def complete(self, name: str, start: float, **args) -> None:
        """Retroactively emit a span that began at ``start`` (a ``now()``
        reading) and ends now — for conditional instrumentation, e.g. a
        socket drain span emitted only when bytes actually arrived."""
        t1 = time.perf_counter()
        self._emit({"ph": "X", "name": name, "ts": start, "dur": t1 - start,
                    "tid": _tid(), "args": args})

    def instant(self, name: str, **args) -> None:
        self._emit({"ph": "i", "name": name, "ts": time.perf_counter(),
                    "tid": _tid(), "args": args})

    def counter(self, name: str, value: float, **args) -> None:
        a = {"value": float(value)}
        a.update(args)
        self._emit({"ph": "C", "name": name, "ts": time.perf_counter(),
                    "tid": _tid(), "args": a})

    def flow_start(self, fid: int, name: str = "frame") -> None:
        self._emit({"ph": "s", "name": name, "id": int(fid),
                    "ts": time.perf_counter(), "tid": _tid(), "args": {}})

    def flow_end(self, fid: int, name: str = "frame") -> None:
        self._emit({"ph": "f", "name": name, "id": int(fid),
                    "ts": time.perf_counter(), "tid": _tid(), "args": {}})

    def set_anchor(self, key: str, ts: Optional[float] = None) -> float:
        """Record a named clock anchor (default: now) — the rendezvous
        handshake timestamps the cross-process merge aligns clocks with."""
        t = time.perf_counter() if ts is None else float(ts)
        self.anchors[key] = t
        return t

    # -- reading ----------------------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        return self.emitted - len(self._events)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            kept = len(self._events)
        return {"emitted": float(self.emitted),
                "kept": float(kept),
                "dropped": float(self.emitted - kept),
                "capacity": float(self.capacity)}

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.emitted = 0


# -- module-level hooks (the instrumented code calls these) ------------------

_tracer: Optional[Tracer] = None


def enable(capacity: int = 1 << 17, rank: int = 0,
           process_name: Optional[str] = None) -> Tracer:
    """Install (and return) a fresh process-global tracer."""
    global _tracer
    _tracer = Tracer(capacity=capacity, rank=rank,
                     process_name=process_name)
    return _tracer


def disable() -> None:
    """Back to no-op mode (the default)."""
    global _tracer
    _tracer = None


def get() -> Optional[Tracer]:
    return _tracer


def active() -> bool:
    return _tracer is not None


def now() -> float:
    """A timestamp for a later ``complete``; 0.0 when tracing is off so
    callers can skip their own bookkeeping on the no-op path."""
    return time.perf_counter() if _tracer is not None else 0.0


def span(name: str, **args):
    t = _tracer
    if t is None:
        return _NOOP
    return t.span(name, args)


def complete(name: str, start: float, **args) -> None:
    t = _tracer
    if t is not None:
        t.complete(name, start, **args)


def instant(name: str, **args) -> None:
    t = _tracer
    if t is not None:
        t.instant(name, **args)


def counter(name: str, value: float, **args) -> None:
    t = _tracer
    if t is not None:
        t.counter(name, value, **args)


def flow_start(fid: int, name: str = "frame") -> None:
    t = _tracer
    if t is not None:
        t.flow_start(fid, name)


def flow_end(fid: int, name: str = "frame") -> None:
    t = _tracer
    if t is not None:
        t.flow_end(fid, name)


def set_anchor(key: str, ts: Optional[float] = None) -> Optional[float]:
    t = _tracer
    if t is not None:
        return t.set_anchor(key, ts)
    return None
