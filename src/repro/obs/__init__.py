"""repro.obs — fleet-wide tracing & metrics (observability layer).

BENCH_socket.json showed the real TCP wire 3.5× slower than simulation
with incomplete delivery, and the repo could meter *bytes* (`CommMeter`)
but not *time*: nobody could say which phase — encode, kernel socket I/O,
hold-back waits, jit, barriers — ate the gap. This package records it:

  tracer.py   near-zero-overhead span/counter/instant API with a
              thread-safe ring buffer. Disabled by default: every hook in
              the hot paths is one attribute read + one shared no-op
              context manager. ``with trace.span("encode", client=i): ...``
  export.py   Chrome trace-event JSON (load in Perfetto / chrome://tracing):
              one track per rank, per-edge *flow events* linking a socket
              send span to its delivery span across processes, and a
              merge step that aligns per-rank clocks via the gossip
              rendezvous handshake timestamps.
  metrics.py  one typed snapshot folding the `CommMeter` books, the
              scheduler's freshness/gate stats, tracer phase attribution,
              and `roofline/hlo_cost` achieved-vs-attainable FLOPs for
              the distill step — exported by `Experiment.run()` under the
              ``obs/`` metric namespace.

Instrumented: `core/runtime.py` (publish / pull / resolve / distill-step /
comm-tick), `core/scheduler.py` (pool rounds, clock), `comm/socket.py`
(connect, send, drain, hold-back), `comm/bus.py` (deliver, tombstone),
`comm/wire.py` (serialize/deserialize) and `launch/gossip.py`
(rendezvous, barriers). Opt in with ``TrainSpec.trace_dir``; analyze with
``scripts/trace_report.py``. See docs/observability.md.
"""
from __future__ import annotations

from repro.obs import tracer as trace
from repro.obs.export import (
    load_trace,
    merge_traces,
    to_chrome_events,
    write_trace,
)
from repro.obs.metrics import ObsSnapshot, collect_obs, distill_step_cost
from repro.obs.tracer import Tracer, flow_id

__all__ = [
    "ObsSnapshot",
    "Tracer",
    "collect_obs",
    "distill_step_cost",
    "flow_id",
    "load_trace",
    "merge_traces",
    "to_chrome_events",
    "trace",
    "write_trace",
]
