"""Unified observability snapshot: comm books + freshness + trace + roofline.

`collect_obs` folds four previously disjoint telemetry sources into one
typed `ObsSnapshot`:

  * the `CommMeter` books (offered / delivered / tombstoned bytes, gate
    counters) — what the fleet *sent*;
  * the scheduler's freshness report (per-client mailbox vs its own
    clock) — what the fleet *sees*;
  * the tracer's phase attribution (self-time per span name, idle as the
    remainder) — where the wall-clock *went*;
  * `roofline/hlo_cost` analysis of the jitted distill update — what the
    step *should* cost on the modeled hardware, and (when a trace is
    available) the achieved-vs-attainable FLOP/s gap.

``ObsSnapshot.to_metrics()`` flattens everything under the ``obs/``
namespace, which `Experiment.run()` merges into the result metrics when
``TrainSpec.trace_dir`` is set.

Phase attribution
  Span self-time: a span's duration minus its children's durations, so
  nested instrumentation never double-counts (a ``runtime/step`` span
  containing a ``runtime/distill`` span contributes only its own
  overhead). Ranks are single-threaded, so spans nest cleanly; the sweep
  is a per-(pid, tid) stack over time-sorted complete events. ``idle`` is
  defined as the rank's timeline extent minus the sum of all self-times —
  by construction the phase table sums exactly to the observed wall.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.roofline.analysis import V5E, HardwareSpec
from repro.roofline.hlo_cost import analyze_to_dict

# span name -> report phase; names not listed fall back to their first
# path segment ("sched/tick" -> "sched"). The report's headline phases:
PHASE_OF = {
    "runtime/distill": "distill",
    "runtime/supervised": "distill",
    "publish/forward": "encode",
    "publish/encode": "encode",
    "wire/serialize": "encode",
    "socket/send": "wire",
    "socket/connect": "wire",
    "socket/drain": "wire",
    "wire/deserialize": "wire",
    "wire/decode": "wire",
    "bus/deliver": "wire",
    "socket/drain_wait": "drain_wait",
    "gossip/rendezvous": "barrier",
    "gossip/finish_barrier": "barrier",
    "gossip/setup": "setup",
    "runtime/step": "step_other",
    "runtime/resolve": "step_other",
    "sched/tick": "step_other",
    # scoreboard stalls: pace/idle waits and run-ahead backpressure
    "sched/wait": "sched_stall",
    "sched/backpressure": "sched_stall",
    # serving phases (repro.serve): routing decision, fused prompt
    # prefill, vmapped decode tick, teacher-cache lookup+compute; the
    # classify forward is the decode-equivalent serving compute
    "serve/route": "route",
    "serve/prefill": "prefill",
    "serve/decode": "decode",
    "serve/classify": "decode",
    "serve/cache": "cache",
}

PHASE_ORDER = ["distill", "encode", "wire", "drain_wait", "sched_stall",
               "barrier", "setup", "step_other", "route", "prefill",
               "decode", "cache", "other", "idle"]

# spans that are *waits*, not work — what the stall report ranks
STALL_NAMES = frozenset({
    "socket/drain_wait", "socket/connect",
    "gossip/rendezvous", "gossip/finish_barrier",
    "sched/wait", "sched/backpressure",
})


def self_times(chrome_events: List[Dict[str, Any]]
               ) -> Dict[int, Dict[str, float]]:
    """Per-pid self-time (seconds) per span name from Chrome "X" events
    (ts/dur in µs). Also returns the rank's timeline extent as ``#wall``
    and the idle remainder as ``#idle`` (reserved names: real spans use
    path-like names, never ``#``)."""
    spans: Dict[tuple, List[Dict[str, Any]]] = defaultdict(list)
    for ev in chrome_events:
        if ev.get("ph") == "X":
            spans[(ev.get("pid", 0), ev.get("tid", 0))].append(ev)

    out: Dict[int, Dict[str, float]] = defaultdict(lambda: defaultdict(float))
    extent: Dict[int, List[float]] = {}
    for (pid, _tid), evs in spans.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        lo = min(e["ts"] for e in evs)
        hi = max(e["ts"] + e["dur"] for e in evs)
        if pid in extent:
            extent[pid][0] = min(extent[pid][0], lo)
            extent[pid][1] = max(extent[pid][1], hi)
        else:
            extent[pid] = [lo, hi]
        # stack sweep: [name, end_ts, child_dur_acc]
        stack: List[List[Any]] = []

        def pop(frame):
            name, _end, child = frame[0], frame[1], frame[2]
            out[pid][name] += (frame[3] - child) / 1e6

        for ev in evs:
            end = ev["ts"] + ev["dur"]
            while stack and stack[-1][1] <= ev["ts"] + 1e-9:
                pop(stack.pop())
            # retro-emitted spans can end a hair *after* their successor
            # starts (the emit call itself takes time): if the open span
            # ends mid-way through the new one they overlap rather than
            # nest — close the earlier span instead of adopting the whole
            # successor as its child (which would drive its self-time
            # negative by the successor's full duration)
            while stack and stack[-1][1] < end - 1e-9:
                pop(stack.pop())
            if stack:
                stack[-1][2] += ev["dur"]
            stack.append([ev["name"], end, 0.0, ev["dur"]])
        while stack:
            pop(stack.pop())
    for pid, (lo, hi) in extent.items():
        wall = (hi - lo) / 1e6
        out[pid]["#wall"] = wall
        out[pid]["#idle"] = max(0.0, wall - sum(
            v for k, v in out[pid].items() if not k.startswith("#")))
    return {pid: dict(d) for pid, d in out.items()}


def phase_attribution(chrome_events: List[Dict[str, Any]]
                      ) -> Dict[int, Dict[str, float]]:
    """Per-pid seconds per report phase (see ``PHASE_ORDER``) + ``wall``.
    Phases + idle sum to wall by construction."""
    out: Dict[int, Dict[str, float]] = {}
    for pid, names in self_times(chrome_events).items():
        row = {p: 0.0 for p in PHASE_ORDER}
        row["wall"] = names.pop("#wall", 0.0)
        row["idle"] = names.pop("#idle", 0.0)
        for name, secs in names.items():
            phase = PHASE_OF.get(name)
            if phase is None:
                head = name.split("/", 1)[0]
                phase = head if head in row else "other"
            row[phase] += secs
        out[pid] = row
    return out


def stall_spans(chrome_events: List[Dict[str, Any]],
                top: int = 10) -> List[Dict[str, Any]]:
    """The ``top`` longest wait spans (see ``STALL_NAMES``), longest
    first — the "where did the 49 seconds go" list."""
    stalls = [ev for ev in chrome_events
              if ev.get("ph") == "X" and ev["name"] in STALL_NAMES]
    stalls.sort(key=lambda e: -e["dur"])
    return [{"rank": ev.get("pid", 0), "name": ev["name"],
             "start_s": ev["ts"] / 1e6, "dur_s": ev["dur"] / 1e6,
             "args": ev.get("args", {})}
            for ev in stalls[:top]]


def stall_attribution(chrome_events: List[Dict[str, Any]],
                      prefix: str = "sched/") -> List[Dict[str, Any]]:
    """Aggregate *scheduler* stall spans by (span name, gated op):
    count, total and max seconds per group, largest total first. The
    ``op`` key is the span's ``op`` arg (``sched/backpressure`` records
    which op class the run-ahead credit held back) falling back to
    ``reason`` (``sched/wait`` records why the issue loop slept) — the
    per-op answer to "what did the scoreboard's waiting pay for"."""
    groups: Dict[tuple, Dict[str, float]] = {}
    for ev in chrome_events:
        if ev.get("ph") != "X" or not ev["name"].startswith(prefix) \
                or ev["name"] not in STALL_NAMES:
            continue
        args = ev.get("args", {})
        op = str(args.get("op") or args.get("reason") or "?")
        g = groups.setdefault((ev["name"], op),
                              {"count": 0.0, "total_s": 0.0, "max_s": 0.0})
        dur = ev["dur"] / 1e6
        g["count"] += 1
        g["total_s"] += dur
        g["max_s"] = max(g["max_s"], dur)
    return [{"name": name, "op": op, **g}
            for (name, op), g in sorted(groups.items(),
                                        key=lambda kv: -kv[1]["total_s"])]


def flow_coverage(chrome_events: List[Dict[str, Any]]) -> Dict[str, float]:
    """How many send→delivery flow pairs actually matched up across
    tracks: a merged multi-process trace should pair nearly every ``s``
    with an ``f`` (the acceptance bar is ≥ 90% of delivered frames)."""
    starts = {ev["id"] for ev in chrome_events if ev.get("ph") == "s"}
    ends = {ev["id"] for ev in chrome_events if ev.get("ph") == "f"}
    return {"flow_starts": float(len(starts)),
            "flow_ends": float(len(ends)),
            "flow_pairs": float(len(starts & ends))}


# -- roofline of the distill step --------------------------------------------


def distill_step_cost(trainer, hw: HardwareSpec = V5E
                      ) -> Dict[str, Dict[str, float]]:
    """Loop-aware HLO cost of each architecture's jitted distill update.

    The runtime records the update's abstract arg shapes the first time
    each bundle takes a distillation step
    (``trainer._distill_arg_shapes``); lowering the cached jitted
    function against those shapes yields the optimized HLO that
    `roofline/hlo_cost.analyze` prices. Attainable FLOP/s is the roofline
    ``min(peak, bw · intensity)`` on ``hw``. Returns {} for trainers
    that never distilled (or legacy baselines without the cache)."""
    shapes = getattr(trainer, "_distill_arg_shapes", None) or {}
    cache = getattr(trainer, "_update_cache", None) or {}
    out: Dict[str, Dict[str, float]] = {}
    for name, args in shapes.items():
        fn = cache.get(name)
        if fn is None:
            continue
        hlo = fn.lower(*args).compile().as_text()
        cost = analyze_to_dict(hlo)
        flops, nbytes = cost["flops"], cost["bytes"]
        intensity = flops / nbytes if nbytes else 0.0
        out[name] = dict(cost)
        out[name]["intensity"] = intensity
        out[name]["attainable_flops_per_s"] = min(
            hw.peak_flops, hw.hbm_bw * intensity)
    return out


def _achieved_flops(roofline: Dict[str, Dict[str, float]],
                    tracer) -> None:
    """Annotate each bundle's roofline row with the achieved FLOP/s from
    its traced ``runtime/distill`` span durations (in place)."""
    if tracer is None:
        return
    durs: Dict[str, List[float]] = defaultdict(list)
    for ev in tracer.events():
        if ev["ph"] == "X" and ev["name"] == "runtime/distill":
            b = ev.get("args", {}).get("bundle")
            if b is not None:
                durs[b].append(ev["dur"])
    for name, row in roofline.items():
        if durs.get(name):
            mean_s = sum(durs[name]) / len(durs[name])
            row["distill_span_mean_s"] = mean_s
            row["achieved_flops_per_s"] = (
                row["flops"] / mean_s if mean_s > 0 else 0.0)
            att = row.get("attainable_flops_per_s", 0.0)
            row["roofline_fraction"] = (
                row["achieved_flops_per_s"] / att if att else 0.0)


# -- the snapshot ------------------------------------------------------------


@dataclasses.dataclass
class ObsSnapshot:
    """One run's observability state, all-float leaves (JSON-safe)."""

    comm: Dict[str, float]
    gates: Dict[int, Dict[str, float]]
    freshness: Dict[int, Dict[str, float]]
    tracer_stats: Dict[str, float]
    phases: Dict[int, Dict[str, float]]
    roofline: Dict[str, Dict[str, float]]

    def to_metrics(self) -> Dict[str, float]:
        """Flatten under the ``obs/`` namespace for the unified metric
        dict (`Experiment.run()`)."""
        out: Dict[str, float] = {}
        for k, v in self.comm.items():
            out[f"obs/comm/{k}"] = float(v)
        for cid, g in self.gates.items():
            for k, v in g.items():
                out[f"obs/gate/c{cid}/{k}"] = float(v)
        for cid, f in self.freshness.items():
            for k, v in f.items():
                out[f"obs/fresh/c{cid}/{k}"] = float(v)
        for k, v in self.tracer_stats.items():
            out[f"obs/trace/{k}"] = float(v)
        for pid, row in self.phases.items():
            for k, v in row.items():
                out[f"obs/phase/r{pid}/{k}"] = float(v)
        for name, row in self.roofline.items():
            for k, v in row.items():
                out[f"obs/roofline/{name}/{k}"] = float(v)
        return out


def collect_obs(trainer=None, scheduler=None, tracer=None,
                hw: HardwareSpec = V5E,
                with_roofline: bool = False) -> ObsSnapshot:
    """Assemble the snapshot from whatever sources exist; every argument
    is optional and a missing source contributes an empty section.
    ``with_roofline`` gates the HLO lowering (an extra compile of each
    distill update — cheap but not free, so opt-in)."""
    comm: Dict[str, float] = {}
    gates: Dict[int, Dict[str, float]] = {}
    meter = getattr(trainer, "meter", None)
    if meter is not None:
        comm = meter.summary()
        gates = meter.gate_summary()

    freshness: Dict[int, Dict[str, float]] = {}
    if scheduler is not None:
        freshness = scheduler.freshness_report()

    tracer_stats: Dict[str, float] = {}
    phases: Dict[int, Dict[str, float]] = {}
    if tracer is not None:
        from repro.obs.export import to_chrome_events

        tracer_stats = tracer.stats()
        phases = phase_attribution(
            to_chrome_events(tracer.events(), pid=tracer.rank))

    roofline: Dict[str, Dict[str, float]] = {}
    if with_roofline and trainer is not None:
        roofline = distill_step_cost(trainer, hw=hw)
        _achieved_flops(roofline, tracer)

    return ObsSnapshot(comm=comm, gates=gates, freshness=freshness,
                       tracer_stats=tracer_stats, phases=phases,
                       roofline=roofline)
