"""Chrome trace-event export and cross-process merge.

One `Tracer` produces one *track*: its events become Chrome trace-event
JSON (the ``traceEvents`` array format that Perfetto and chrome://tracing
load directly) with ``pid`` = rank and one ``tid`` per Python thread.
Timestamps are converted from `time.perf_counter()` seconds to the
format's microseconds.

Cross-process merge
  Each gossip child writes its own ``trace_r{rank}.json``; its clock is
  `perf_counter` with a per-process arbitrary epoch, so raw timestamps
  from different ranks are NOT comparable. The launcher's port rendezvous
  is a natural two-way handshake, and both ends record its timestamps as
  tracer *anchors*:

      child:  c_send (just before reporting its port)
              c_recv (just after receiving the port broadcast)
      parent: p_recv (when it received that child's port)
              p_send (when it broadcast the map)

  The classic symmetric-delay estimate maps a child clock onto the
  parent's:

      offset_r = ((p_recv - c_send) + (p_send - c_recv)) / 2

  i.e. parent_time ≈ child_time + offset_r, exact when the pipe delay is
  symmetric. On one host the residual error is well under the span
  durations being attributed (milliseconds); see docs/observability.md
  for the caveats.

`merge_traces` shifts every rank onto the parent clock, re-bases the
whole timeline at zero, and emits one Perfetto-loadable file whose
per-edge flow events (same ``flow_id`` computed on both ends) draw
send→delivery arrows across rank tracks.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs.tracer import Tracer

TRACE_VERSION = 1

_US = 1e6  # perf_counter seconds -> trace microseconds


def to_chrome_events(events: List[Dict[str, Any]], pid: int,
                     offset_s: float = 0.0,
                     base_s: float = 0.0) -> List[Dict[str, Any]]:
    """Tracer events -> Chrome trace-event dicts on track ``pid``.

    ``offset_s`` shifts this track onto the reference clock (cross-process
    alignment); ``base_s`` re-bases the merged timeline at zero (applied
    after the offset)."""
    out: List[Dict[str, Any]] = []
    tids: Dict[int, int] = {}
    for ev in events:
        tid = tids.setdefault(ev.get("tid", 0), len(tids))
        ts = (ev["ts"] + offset_s - base_s) * _US
        ch: Dict[str, Any] = {"ph": ev["ph"], "name": ev["name"],
                              "pid": pid, "tid": tid,
                              "ts": ts, "args": ev.get("args", {})}
        if ev["ph"] == "X":
            ch["dur"] = ev["dur"] * _US
        elif ev["ph"] == "i":
            ch["s"] = "t"  # thread-scoped instant
        elif ev["ph"] in ("s", "f"):
            ch["cat"] = "flow"
            ch["id"] = ev["id"]
            if ev["ph"] == "f":
                ch["bp"] = "e"  # bind to the enclosing slice
        out.append(ch)
    return out


def _track_metadata(pid: int, name: str) -> List[Dict[str, Any]]:
    return [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
             "args": {"name": name}},
            {"ph": "M", "name": "process_sort_index", "pid": pid, "tid": 0,
             "args": {"sort_index": pid}}]


def write_trace(path: str, tracer: Tracer,
                meta: Optional[Dict[str, Any]] = None) -> str:
    """One process's trace as a self-contained Chrome trace JSON.

    The file is directly Perfetto-loadable on its own AND carries enough
    metadata (``otherData``: rank, clock anchors, drop stats) for
    `merge_traces` to fold it into a fleet timeline later."""
    events = tracer.events()
    chrome = _track_metadata(tracer.rank, tracer.process_name)
    chrome += to_chrome_events(events, pid=tracer.rank)
    payload = {
        "traceEvents": chrome,
        "displayTimeUnit": "ms",
        "otherData": {
            "version": TRACE_VERSION,
            "rank": tracer.rank,
            "process_name": tracer.process_name,
            "anchors": dict(tracer.anchors),
            "stats": tracer.stats(),
            "meta": meta or {},
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f)
        f.write("\n")
    return path


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def rendezvous_offset(anchors: Dict[str, float],
                      parent_recv: float, parent_send: float) -> float:
    """child-clock -> parent-clock offset from the rendezvous handshake
    (see module docstring). Falls back to 0.0 — a same-clock merge — when
    a child never recorded its anchors (tracing enabled mid-run)."""
    c_send = anchors.get("rendezvous_send")
    c_recv = anchors.get("rendezvous_recv")
    if c_send is None or c_recv is None:
        return 0.0
    return ((parent_recv - c_send) + (parent_send - c_recv)) / 2.0


def merge_traces(rank_paths: Dict[int, str], out_path: str,
                 parent_anchors: Optional[Dict[int, Any]] = None,
                 meta: Optional[Dict[str, Any]] = None) -> str:
    """Merge per-rank trace files into one fleet timeline.

    ``rank_paths`` maps rank -> its ``write_trace`` output.
    ``parent_anchors`` maps rank -> (parent_recv, parent_send) rendezvous
    timestamps on the parent clock; None merges without alignment (only
    correct when every file shares one process clock — the in-process
    case)."""
    loaded: Dict[int, Dict[str, Any]] = {}
    offsets: Dict[int, float] = {}
    for rank, path in sorted(rank_paths.items()):
        data = load_trace(path)
        loaded[rank] = data
        if parent_anchors is not None and rank in parent_anchors:
            p_recv, p_send = parent_anchors[rank]
            offsets[rank] = rendezvous_offset(
                data["otherData"].get("anchors", {}),
                float(p_recv), float(p_send))
        else:
            offsets[rank] = 0.0

    # re-base the merged timeline so the earliest aligned event is t=0
    base_us = None
    for rank, data in loaded.items():
        for ev in data["traceEvents"]:
            if ev["ph"] == "M":
                continue
            ts = ev["ts"] + offsets[rank] * _US
            if base_us is None or ts < base_us:
                base_us = ts
    base_us = base_us or 0.0

    merged: List[Dict[str, Any]] = []
    other: Dict[str, Any] = {"version": TRACE_VERSION, "merged": True,
                             "ranks": sorted(loaded),
                             "offsets_s": {str(r): offsets[r]
                                           for r in sorted(offsets)},
                             "per_rank": {}, "meta": meta or {}}
    for rank, data in sorted(loaded.items()):
        shift_us = offsets[rank] * _US - base_us
        for ev in data["traceEvents"]:
            ev = dict(ev)
            ev["pid"] = rank
            if ev["ph"] != "M":
                ev["ts"] = ev["ts"] + shift_us
            merged.append(ev)
        od = data.get("otherData", {})
        other["per_rank"][str(rank)] = {
            "anchors": od.get("anchors", {}),
            "stats": od.get("stats", {}),
            "meta": od.get("meta", {}),
        }
    with open(out_path, "w") as f:
        json.dump({"traceEvents": merged, "displayTimeUnit": "ms",
                   "otherData": other}, f)
        f.write("\n")
    return out_path
