"""The paper's data-partition protocol (§3.3).

Given a labeled dataset with sample labels ``y``:

1. A fraction ``gamma_pub`` of samples is held out as the *public unlabeled
   pool* D_*.
2. Each client C_i is assigned a set of *primary labels* l_i, either
   - ``even``:   every label has exactly ``m`` primary clients, or
   - ``random``: each client draws a random fixed-size label subset
     (so labels may have 0..K primary clients — the paper's Fig. in §3.3).
3. Remaining (private) samples are distributed *without repetition*: a sample
   with label l goes to client i with probability proportional to
   ``1 + s`` if l is primary for i, else ``1`` — ``s`` is the *skewness*
   (s=0 → iid; s→∞ → samples only to primary clients).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    num_clients: int = 8
    num_labels: int = 1000
    labels_per_client: int = 250
    assignment: str = "random"  # "random" | "even"
    skew: float = 100.0  # the paper's s
    gamma_pub: float = 0.1  # public pool fraction
    even_multiplicity: int = 2  # m for "even" assignment
    seed: int = 0


@dataclasses.dataclass
class Partition:
    """Result of partitioning: index arrays into the source dataset."""

    public_indices: np.ndarray  # (N_pub,)
    client_indices: List[np.ndarray]  # K arrays of private sample indices
    primary_labels: List[np.ndarray]  # K arrays of primary label ids
    config: PartitionConfig

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)

    def primary_mask(self, client: int) -> np.ndarray:
        """Boolean (num_labels,) mask of the client's primary labels."""
        mask = np.zeros(self.config.num_labels, dtype=bool)
        mask[self.primary_labels[client]] = True
        return mask


def assign_primary_labels(cfg: PartitionConfig, rng: np.random.Generator) -> List[np.ndarray]:
    """Primary label sets per client, per the paper's 'even'/'random' schemes."""
    K, L = cfg.num_clients, cfg.num_labels
    if cfg.assignment == "random":
        return [
            np.sort(rng.choice(L, size=min(cfg.labels_per_client, L), replace=False))
            for _ in range(K)
        ]
    if cfg.assignment == "even":
        # Each label gets exactly `m` primary clients: lay out labels repeated m
        # times, shuffle, deal round-robin into K equal hands.
        m = cfg.even_multiplicity
        deck = np.repeat(np.arange(L), m)
        rng.shuffle(deck)
        hands: List[List[int]] = [[] for _ in range(K)]
        # Deal while avoiding duplicate label in the same hand where possible.
        for idx, label in enumerate(deck):
            order = np.argsort([len(h) for h in hands])
            for c in order:
                if label not in hands[c]:
                    hands[c].append(int(label))
                    break
            else:  # all hands already contain it — allowed fallback
                hands[int(order[0])].append(int(label))
        return [np.sort(np.unique(np.asarray(h, dtype=np.int64))) for h in hands]
    raise ValueError(f"unknown assignment {cfg.assignment!r}")


def partition_dataset(labels: np.ndarray, cfg: PartitionConfig) -> Partition:
    """Split sample indices into public pool + K skewed private shards."""
    labels = np.asarray(labels)
    n = labels.shape[0]
    rng = np.random.default_rng(cfg.seed)

    perm = rng.permutation(n)
    n_pub = int(round(cfg.gamma_pub * n))
    public_indices = perm[:n_pub]
    private_pool = perm[n_pub:]

    primary = assign_primary_labels(cfg, rng)
    # (K, L) primary indicator
    K, L = cfg.num_clients, cfg.num_labels
    is_primary = np.zeros((K, L), dtype=bool)
    for i, labs in enumerate(primary):
        is_primary[i, labs] = True

    # Per-label client weights: 1 + s for primary clients, 1 otherwise.
    weights = 1.0 + cfg.skew * is_primary.astype(np.float64)  # (K, L)
    probs = weights / weights.sum(axis=0, keepdims=True)  # normalized over clients

    priv_labels = labels[private_pool]
    assignment = np.empty(private_pool.shape[0], dtype=np.int64)
    for l in np.unique(priv_labels):
        sel = np.nonzero(priv_labels == l)[0]
        assignment[sel] = rng.choice(K, size=sel.shape[0], p=probs[:, l])

    client_indices = [
        private_pool[assignment == i] for i in range(K)
    ]
    return Partition(
        public_indices=public_indices,
        client_indices=client_indices,
        primary_labels=primary,
        config=cfg,
    )


def shared_test_split(labels: np.ndarray, per_label: int, num_labels: int,
                      seed: int = 1234) -> np.ndarray:
    """Uniform-label-distribution eval set (the paper's 'shared' test set)."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    picks = []
    for l in range(num_labels):
        idx = np.nonzero(labels == l)[0]
        if idx.shape[0] == 0:
            continue
        take = min(per_label, idx.shape[0])
        picks.append(rng.choice(idx, size=take, replace=False))
    return np.concatenate(picks) if picks else np.empty((0,), dtype=np.int64)
