from repro.data.partition import (
    PartitionConfig,
    assign_primary_labels,
    partition_dataset,
    Partition,
)
from repro.data.synthetic import (
    SyntheticVisionDataset,
    SyntheticTextDataset,
    make_synthetic_vision,
    make_synthetic_text,
)
from repro.data.pipeline import BatchIterator, PublicPool, client_stream_seed

__all__ = [
    "PartitionConfig",
    "assign_primary_labels",
    "partition_dataset",
    "Partition",
    "SyntheticVisionDataset",
    "SyntheticTextDataset",
    "make_synthetic_vision",
    "make_synthetic_text",
    "BatchIterator",
    "PublicPool",
    "client_stream_seed",
]
