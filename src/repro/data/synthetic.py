"""Synthetic datasets.

The paper runs on ImageNet; this container has no dataset and one CPU, so the
experiment harness uses *class-conditional synthetic data* with controllable
difficulty. The partition protocol, training loop and all MHD machinery are
identical to what would run on real data — only the pixel source differs
(documented in DESIGN.md §7).

Vision: each class has a fixed random prototype image; a sample is
``prototype + sigma * noise``. With enough classes and a small model this
gives ImageNet-like qualitative behaviour (underfit/overfit regimes, useful
teacher signal) at CPU scale.

Text: per-domain bigram language models over a shared vocab; clients' private
"domains" play the role of label subsets for next-token-prediction MHD.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticVisionDataset:
    images: np.ndarray  # (N, H, W, C) float32
    labels: np.ndarray  # (N,) int32
    num_labels: int

    def __len__(self) -> int:
        return self.images.shape[0]


def make_synthetic_vision(
    num_labels: int = 20,
    samples_per_label: int = 100,
    image_size: int = 8,
    channels: int = 3,
    noise: float = 1.0,
    prototype_scale: float = 1.0,
    seed: int = 0,
    prototype_seed: Optional[int] = None,
) -> SyntheticVisionDataset:
    """``prototype_seed`` pins the class definitions: train/test splits use
    the same prototype_seed with different sample seeds."""
    proto_rng = np.random.default_rng(
        seed if prototype_seed is None else prototype_seed)
    rng = np.random.default_rng(seed)
    protos = prototype_scale * proto_rng.standard_normal(
        (num_labels, image_size, image_size, channels)
    ).astype(np.float32)
    n = num_labels * samples_per_label
    labels = np.repeat(np.arange(num_labels), samples_per_label).astype(np.int32)
    imgs = protos[labels] + noise * rng.standard_normal(
        (n, image_size, image_size, channels)
    ).astype(np.float32)
    perm = rng.permutation(n)
    return SyntheticVisionDataset(imgs[perm], labels[perm], num_labels)


@dataclasses.dataclass
class SyntheticTextDataset:
    tokens: np.ndarray  # (N, T) int32 sequences
    labels: np.ndarray  # (N,) int32 domain label per sequence
    num_labels: int
    vocab_size: int

    def __len__(self) -> int:
        return self.tokens.shape[0]


def make_synthetic_text(
    num_domains: int = 8,
    sequences_per_domain: int = 64,
    seq_len: int = 64,
    vocab_size: int = 256,
    temperature: float = 0.5,
    seed: int = 0,
    table_seed: Optional[int] = None,
) -> SyntheticTextDataset:
    """Per-domain bigram LMs: domain d has transition logits L_d (V, V).

    ``table_seed`` pins the domain languages (the transition tables):
    train/test splits use the same table_seed with different sample
    seeds — the text twin of the vision sets' ``prototype_seed``. None
    keeps the historical single-stream draw (tables and samples from
    ``seed``), bitwise.
    """
    rng = np.random.default_rng(seed)
    table_rng = rng if table_seed is None else np.random.default_rng(table_seed)
    n = num_domains * sequences_per_domain
    tokens = np.empty((n, seq_len), dtype=np.int32)
    labels = np.repeat(np.arange(num_domains), sequences_per_domain).astype(np.int32)
    for d in range(num_domains):
        logits = table_rng.standard_normal((vocab_size, vocab_size)) / temperature
        probs = np.exp(logits - logits.max(axis=1, keepdims=True))
        probs /= probs.sum(axis=1, keepdims=True)
        cdf = np.cumsum(probs, axis=1)
        for s in range(sequences_per_domain):
            row = d * sequences_per_domain + s
            tok = rng.integers(vocab_size)
            for t in range(seq_len):
                tokens[row, t] = tok
                u = rng.random()
                tok = int(np.searchsorted(cdf[tok], u))
                tok = min(tok, vocab_size - 1)
    perm = rng.permutation(n)
    return SyntheticTextDataset(tokens[perm], labels[perm], num_domains, vocab_size)
