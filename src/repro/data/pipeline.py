"""Batching pipeline: private per-client iterators + the public pool.

Host-side numpy batching (the realistic layout for a decentralized system:
each client owns its input pipeline); device transfer happens at the jit
boundary. Deterministic given seeds.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

# Stride between per-client private-batch rng streams. Every algorithm
# (MHD runtime, FedMD, FedAvg, supervised baselines) must derive client
# iterator seeds through `client_stream_seed` so that cross-algorithm
# comparisons train on *identical* private sample orders — the paper's
# tables are comparative, and a different shuffle is a confound.
PRIVATE_STREAM_STRIDE = 13


def client_stream_seed(seed: int, client_id: int) -> int:
    """Seed of client ``client_id``'s private `BatchIterator` stream."""
    return seed + PRIVATE_STREAM_STRIDE * client_id


class BatchIterator:
    """Infinite shuffled minibatch iterator over index-selected arrays."""

    def __init__(
        self,
        arrays: Dict[str, np.ndarray],
        indices: np.ndarray,
        batch_size: int,
        seed: int = 0,
        drop_remainder: bool = True,
    ):
        if indices.shape[0] == 0:
            raise ValueError("BatchIterator got an empty index set")
        self.arrays = arrays
        self.indices = np.asarray(indices)
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(self.indices.shape[0])
        self._pos = 0

    def next(self) -> Dict[str, np.ndarray]:
        n = self.indices.shape[0]
        take = []
        need = self.batch_size
        while need > 0:
            if self._pos >= n:
                self._order = self.rng.permutation(n)
                self._pos = 0
            grab = min(need, n - self._pos)
            take.append(self._order[self._pos : self._pos + grab])
            self._pos += grab
            need -= grab
        sel = self.indices[np.concatenate(take)]
        return {k: v[sel] for k, v in self.arrays.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    # -- snapshot/restore (repro.fleet) ---------------------------------

    def state_dict(self) -> Dict[str, object]:
        """The iterator's resumable state: shuffle order, cursor, and the
        rng that generates future epochs' permutations. Restoring it makes
        the stream continue bit-for-bit (`repro.fleet.snapshot`)."""
        return {"order": self._order.copy(), "pos": int(self._pos),
                "rng": self.rng.bit_generator.state}

    def load_state_dict(self, state: Dict[str, object]) -> None:
        self._order = np.asarray(state["order"])
        self._pos = int(state["pos"])
        self.rng.bit_generator.state = state["rng"]


class PublicPool:
    """The shared public unlabeled pool D_* (labels stripped).

    ``sample(step)`` is deterministic in (seed, step) so that *all clients
    draw the same public batch at the same global step* — exactly the
    paper's setup where teachers and students score the same samples. In the
    multi-pod runtime the same property lets each pod materialize the batch
    locally with zero communication (samples are identified by a hash —
    paper §"Communication efficiency").
    """

    def __init__(self, arrays: Dict[str, np.ndarray], indices: np.ndarray,
                 batch_size: int, seed: int = 0):
        self.arrays = {k: v for k, v in arrays.items() if k != "labels"}
        self.indices = np.asarray(indices)
        self.batch_size = batch_size
        self.seed = seed

    def sample(self, step: int) -> Dict[str, np.ndarray]:
        sel = self.sample_ids(step)
        return {k: v[sel] for k, v in self.arrays.items()}

    def sample_ids(self, step: int) -> np.ndarray:
        """Dataset indices of the step-t public batch — the per-sample
        identifiers of the exchange wire format (paper §3.2: samples are
        referenced by hash, never shipped)."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        return self.indices[rng.integers(0, self.indices.shape[0],
                                         size=self.batch_size)]

    @property
    def size(self) -> int:
        return int(self.indices.shape[0])
