"""Multi-pod MHD: clients mapped to the 'pod' mesh axis.

Deployment model (DESIGN.md §4): K clients co-train, client k living on pod
k — its parameters and private batch are sharded (data, model) *within* the
pod and stacked along a leading client dim that is sharded over 'pod'.
Every step each client scores the shared public batch; teacher predictions
move between pods along the same adjacency contract the host loop's
`repro.comm.bus.PredictionBus` uses — ``adj[i]`` names client i's
in-neighbors (`DistributedMHDConfig.neighbors`; None = the 1-hop ring).
Topology is no longer welded to the collective choice: a uniform ring
offset lowers to ``jnp.roll`` over the pod-sharded client dim (XLA emits
``collective-permute`` across the pod interconnect — the paper's Fig. 1
exchange as an actual collective), and any other one-teacher-per-client
permutation lowers to a gather (``jnp.take`` along the client dim). The
same graph that drives the host-loop bus can therefore drive the pod
fleet; see ``docs/async_runtime.md`` for how the scoreboard runtime uses
that shared adjacency on the host side.

Wire formats (the §Perf lever measured in EXPERIMENTS.md):
  * ``exchange="full"`` — ship full-vocab teacher logits (+ embeddings):
    the naive implementation; for a 262k vocab this dominates ICI traffic.
  * ``exchange="topk"`` — ship only the top-k logits + indices (+ the
    teacher's logsumexp so probabilities stay exact, and the embedding).
    This is precisely the paper's communication-efficiency argument
    (§3.2: "only requires a transmission of several highest-confidence
    predictions for each sample") turned into a wire format. Confidence
    Λ = max softmax prob is exact (= top-1 prob); CE against the truncated
    teacher distribution drops mass beyond k (documented approximation).

The packing / sparse-CE primitives are the shared `repro.comm.wire`
codecs (also used by the host-loop prediction exchange and the
comm_efficiency benchmark); this module keeps only the mesh-aware pieces
(`_topk_2stage` sharding constraints, the pod-ring collective).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.wire import (
    dense_xent_and_conf as _dense_xent_and_conf,
    sparse_xent_and_conf as _sparse_xent_and_conf,
    topk_iterative as _topk_iterative,
    topk_pack_outputs as _topk_pack,
)
from repro.core.mhd import MHDConfig
from repro.models.zoo import ModelBundle


@dataclasses.dataclass(frozen=True)
class DistributedMHDConfig:
    """Pod-fleet shape + wire format.

    ``neighbors`` is the bus-style adjacency (``adj[i]`` = client i's
    in-neighbors, the same contract as `PredictionBus.graph_fn`'s
    output) restricted to exactly one teacher per client — the pod
    runtime is the Δ=1 fused path. ``None`` keeps the historical 1-hop
    ring (client i distills from client i-1 mod K)."""

    num_clients: int = 2  # = number of pods
    exchange: str = "full"  # "full" | "topk"
    topk: int = 32
    max_public_positions: int = 0  # cap distilled positions (0 = all)
    neighbors: Optional[Tuple[Tuple[int, ...], ...]] = None


def _lm_outputs(bundle: ModelBundle, params, tokens, max_positions: int):
    from repro.core.lm_adapter import lm_mhd_outputs

    return lm_mhd_outputs(bundle, params, {"tokens": tokens},
                          max_positions=max_positions)


def _roll_clients(tree, shift: int = 1):
    """Ring exchange across the client (pod) dim — lowers to
    collective-permute when dim 0 is sharded over 'pod'."""
    return jax.tree.map(lambda x: jnp.roll(x, shift, axis=0), tree)


def _teacher_sources(dist: DistributedMHDConfig) -> List[int]:
    """Resolve the adjacency to ``src[i]`` = the client whose prediction
    client i distills from, validating the Δ=1 contract."""
    K = dist.num_clients
    if dist.neighbors is None:
        return [(i - 1) % K for i in range(K)]
    if len(dist.neighbors) != K:
        raise ValueError(
            f"{len(dist.neighbors)} neighbor rows for {K} clients")
    srcs = []
    for i, nbrs in enumerate(dist.neighbors):
        if len(nbrs) != 1:
            raise ValueError(
                f"client {i} has {len(nbrs)} in-neighbors; the pod "
                "runtime is the fused Δ=1 path — exactly one teacher "
                "per client (use the host-loop runtime for wider "
                "distillation neighborhoods)")
        j = int(nbrs[0])
        if not 0 <= j < K or j == i:
            raise ValueError(f"client {i} names teacher {j}, not a "
                             f"distinct client in [0, {K})")
        srcs.append(j)
    return srcs


def _exchange_teachers(tree, dist: DistributedMHDConfig):
    """Move each teacher's packed prediction to its student along the
    bus adjacency. A uniform ring offset keeps the ``jnp.roll`` lowering
    (collective-permute over a pod-sharded dim 0); any other permutation
    lowers to a client-dim gather."""
    K = dist.num_clients
    srcs = _teacher_sources(dist)
    for shift in range(1, K):
        if all(srcs[i] == (i - shift) % K for i in range(K)):
            return _roll_clients(tree, shift)
    idx = jnp.asarray(srcs)
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def _c(x, *axes):
    """Raw-axis-name sharding constraint (divisibility-checked, mesh-aware)."""
    from jax.sharding import PartitionSpec as P

    try:
        mesh = jax.sharding.get_abstract_mesh()
        if not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return x
    spec = []
    for dim, a in zip(x.shape, axes):
        if a is not None and a in sizes and sizes[a] > 1 and dim % sizes[a] == 0:
            spec.append(a)
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _topk_2stage(logits, k: int, block: int = 1024):
    """Exact-enough top-k for huge vocabs without a full-vocab sort.

    ``lax.top_k`` on a 262k vocab lowers to a full sort (O(V log V) compute
    and a V-sized f32 sort buffer per row — 573 GB temp at MHD batch sizes,
    measured). Two-stage: top-k within each vocab block, then top-k over the
    nb·k survivors. Exact whenever no block holds more than k of the true
    top-k (with k=32 and 256 blocks, overwhelmingly the case; same trick as
    TPU approx_max_k).
    """
    V = logits.shape[-1]
    nb = -(-V // block)
    pad = nb * block - V
    if pad:
        logits = jnp.pad(logits, [(0, 0)] * (logits.ndim - 1) + [(0, pad)],
                         constant_values=-1e30)
    blocked = logits.reshape(logits.shape[:-1] + (nb, block))
    # keep the blocked view sharded: vocab blocks over 'model', positions
    # over 'data', clients over 'pod' (XLA replicates the reshape otherwise)
    lead = ("pod", None, "data") if blocked.ndim == 5 else \
        (("pod", "data") if blocked.ndim == 4 else ("data",))
    blocked = _c(blocked, *lead, "model", None)
    v1, i1 = jax.lax.top_k(blocked, min(k, block))  # (..., nb, k)
    v1 = _c(v1, *lead, "model", None)
    flat_v = v1.reshape(v1.shape[:-2] + (nb * min(k, block),))
    flat_i = (i1 + (jnp.arange(nb) * block)[:, None]).reshape(
        i1.shape[:-2] + (nb * min(k, block),))
    flat_v = _c(flat_v, *lead, None)
    v2, i2 = jax.lax.top_k(flat_v, k)
    idx = jnp.take_along_axis(flat_i, i2, axis=-1)
    return v2, idx


def _distill_loss_one_client(student, teacher, mhd: MHDConfig,
                             exchange: str):
    """Eqs. (2),(4),(5) against ONE ring teacher (Δ=1 in the pod runtime).

    student: dense outputs; teacher: dense or top-k-packed (already
    stop-gradiented).
    """
    from repro.core.mhd import embedding_distillation_loss, _confidence

    total = jnp.zeros((), jnp.float32)
    emb = embedding_distillation_loss(
        student["embedding"], teacher["embedding"][None], mhd.nu_emb)

    m = mhd.num_aux_heads
    for k in range(1, m + 1):
        student_head = student["aux_logits"][k - 1]
        if k == 1:
            self_src = student["logits"]
        else:
            self_src = student["aux_logits"][k - 2]
        self_src = jax.lax.stop_gradient(self_src)

        if exchange == "topk":
            t_pack = (teacher["logits"] if k == 1
                      else jax.tree.map(lambda x: x[k - 2],
                                        teacher["aux_logits"]))
            ce_t, conf_t = _sparse_xent_and_conf(student_head, t_pack)
        else:
            t_logits = (teacher["logits"] if k == 1
                        else teacher["aux_logits"][k - 2])
            ce_t, conf_t = _dense_xent_and_conf(student_head, t_logits)
        ce_s, conf_s = _dense_xent_and_conf(student_head, self_src)

        use_teacher = conf_t >= conf_s  # Eq. 4 argmax over {teacher, self}
        per_sample = jnp.where(use_teacher, ce_t, ce_s)
        total = total + jnp.mean(per_sample)
    return mhd.nu_aux * total + emb


def make_distributed_mhd_step(bundle: ModelBundle, optimizer,
                              mhd: MHDConfig, dist: DistributedMHDConfig):
    """Returns train_step(state, batch) for the stacked-client layout.

    state["params"]: pytree stacked (K, ...) — shard dim 0 over 'pod'.
    batch: {"private_tokens": (K, B, T), "public_tokens": (B_pub, T)}.
    """
    K = dist.num_clients

    def step(state, batch):
        pub_tokens = batch["public_tokens"]

        def loss_fn(stacked_params):
            def client_outputs(p, priv):
                priv_out = _lm_outputs(bundle, p, priv, 0)
                pub_out = _lm_outputs(bundle, p, pub_tokens,
                                      dist.max_public_positions)
                return priv_out, pub_out

            priv_outs, pub_outs = jax.vmap(client_outputs)(
                stacked_params, batch["private_tokens"])

            # private CE (Eq. 1 first term), per client
            def priv_ce(out):
                logp = jax.nn.log_softmax(
                    out["logits"].astype(jnp.float32), axis=-1)
                ll = jnp.take_along_axis(
                    logp, out["labels"][:, None], axis=-1)[:, 0]
                return -jnp.mean(ll)

            ce = jnp.mean(jax.vmap(priv_ce)(priv_outs))

            # teacher exchange over the pod ring
            pub_pred = {"embedding": pub_outs["embedding"],
                        "logits": pub_outs["logits"],
                        "aux_logits": pub_outs["aux_logits"]}
            # stop-grad BEFORE packing: the top-k/sort must not be
            # differentiated (it only feeds the frozen teacher side)
            frozen = jax.lax.stop_gradient(pub_pred)
            if dist.exchange == "topk":
                # operates directly on the client-stacked tensors (leading
                # K dim is pod-sharded); no vmap, so the sharding
                # constraints inside the pack see the real mesh dims
                wire = _topk_pack(frozen, dist.topk)
            else:
                wire = frozen
            teachers = _exchange_teachers(wire, dist)

            dist_loss = jnp.mean(jax.vmap(
                lambda s, t: _distill_loss_one_client(s, t, mhd,
                                                      dist.exchange)
            )(pub_pred, teachers))

            aux = jnp.mean(pub_outs["aux_loss"]) + \
                jnp.mean(priv_outs["aux_loss"])
            return ce + dist_loss + aux, {"ce": ce, "dist": dist_loss}

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        params, opt = optimizer.update(grads, state["opt"], state["params"],
                                       state["step"])
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, **metrics}

    return step
