"""Communication graph topologies G_t (paper §3.1, §4.4, Fig. 5).

A graph is a list of out-neighbor tuples: ``adj[i]`` are the clients whose
checkpoints client i may receive (directed edges i -> e_t(i)). Graphs may be
static or a per-step callable (dynamic G_t).
"""
from __future__ import annotations

from typing import Callable, List, Sequence, Tuple, Union

import numpy as np

Adjacency = List[Tuple[int, ...]]
GraphFn = Callable[[int], Adjacency]  # step -> adjacency


def complete_graph(k: int) -> Adjacency:
    return [tuple(j for j in range(k) if j != i) for i in range(k)]


def cycle_graph(k: int, hops: int = 1) -> Adjacency:
    """Directed ring: i learns from (i+1..i+hops) mod k."""
    return [tuple((i + h) % k for h in range(1, hops + 1)) for i in range(k)]


def chain_graph(k: int) -> Adjacency:
    """Open chain: i learns from i+1; the last client learns from nobody."""
    return [((i + 1,) if i + 1 < k else ()) for i in range(k)]


def islands_graph(k: int, num_islands: int) -> Adjacency:
    """Disjoint complete subgraphs (paper Fig. 5 'Islands')."""
    assert k % num_islands == 0
    size = k // num_islands
    adj: Adjacency = []
    for i in range(k):
        isl = i // size
        members = range(isl * size, (isl + 1) * size)
        adj.append(tuple(j for j in members if j != i))
    return adj


def isolated_graph(k: int) -> Adjacency:
    """No communication — the paper's 'Separate' baseline."""
    return [() for _ in range(k)]


def random_regular_graph_fn(k: int, degree: int = 1, seed: int = 0,
                            reshuffle_every: int = 200) -> GraphFn:
    """Dynamic G_t (paper §3.1 allows per-step edge sets): every
    ``reshuffle_every`` steps each client gets ``degree`` fresh random
    out-neighbors. Models gossip-style decentralized systems where pairings
    rotate — beyond the paper's static topologies."""
    def graph(step: int) -> Adjacency:
        epoch = step // reshuffle_every
        rng = np.random.default_rng((seed << 16) ^ epoch)
        adj = []
        for i in range(k):
            others = [j for j in range(k) if j != i]
            picks = rng.choice(others, size=min(degree, len(others)),
                               replace=False)
            adj.append(tuple(int(j) for j in picks))
        return adj

    return graph


def as_graph_fn(graph: Union[Adjacency, GraphFn]) -> GraphFn:
    if callable(graph):
        return graph
    return lambda step: graph


def validate_adjacency(adj: Adjacency) -> None:
    k = len(adj)
    for i, nbrs in enumerate(adj):
        for j in nbrs:
            if not (0 <= j < k) or j == i:
                raise ValueError(f"bad edge {i}->{j} in a {k}-client graph")


def graph_distance_matrix(adj: Adjacency) -> np.ndarray:
    """Hop distances (BFS over directed edges). Used by the topology bench
    to report teacher-student distance effects (paper Fig. 6)."""
    k = len(adj)
    dist = np.full((k, k), np.inf)
    for s in range(k):
        dist[s, s] = 0
        frontier = [s]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for v in adj[u]:
                    if dist[s, v] == np.inf:
                        dist[s, v] = d
                        nxt.append(v)
            frontier = nxt
    return dist
