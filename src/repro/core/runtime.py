"""Decentralized MHD runtime (paper §4.1).

Orchestrates K clients, each with private data, an optimizer, and a rolling
checkpoint pool P_i of stale teacher snapshots (N_P entries, refreshed from
graph neighbors every S_P steps). Every global step each client:

  1. draws a private labeled batch and the *shared* public batch (all clients
     see the same public samples at step t — PublicPool is deterministic),
  2. samples Δ teachers from its pool and scores the public batch with them,
  3. takes one SGD step on Eq. (1): private CE + embedding distillation +
     confidence-gated multi-head distillation.

Clients may have different architectures (paper §4.5) as long as their
embedding dims and class counts agree (the paper's ResNet-18/34 setting).
Per-architecture jitted functions are cached so heterogeneous ensembles
don't retrace.

Exchange modes (``exchange=``):
  * ``"params"`` (legacy) — each client's pool holds neighbors' raw
    parameters and re-runs their forward passes locally. A simulation
    shortcut: nothing the paper would put on a wire.
  * ``"prediction_topk"`` / ``"prediction_dense"`` — the faithful §3.2
    protocol via `repro.comm`: every S_P steps a client *publishes* an
    encoded window of predictions on upcoming public batches to the
    `PredictionBus`; students decode received mail instead of running
    neighbor forward passes. Params never leave a client; every byte is
    metered. Under a lossless zero-latency transport (and a horizon
    covering the pool's staleness range) this reproduces the param-pool
    teacher schedule exactly — same rng streams, same teacher outputs.

Clients with no usable teachers (isolated topologies, dropped/expired
mail) fall back to a supervised-only step — every topology in
`core/graph.py` trains end-to-end.

Stepping models:
  * ``step(t)`` — the synchronous loop: every client takes one step at
    every global step t, pools refresh on the shared S_P cadence.
  * `core/scheduler` — the dependency-scoreboard runtime: each client's
    progress decomposes into LocalStep / Publish / Pull / Resolve ops
    issued against the op-granular entry points exposed here
    (``step_client(defer=True)``, ``publish_clients``, ``pull_client``,
    ``comm_pump``) on heterogeneous cadences, in lockstep
    (`AsyncScheduler`) or out of order (`ScoreboardScheduler`). The
    synchronous loop is the equal-rates special case, and both policies
    reproduce it bitwise (tests/test_scheduler.py).

Bounded staleness (``RunConfig.max_staleness``): when set, a sampled
teacher older than ``max_staleness`` steps (entry timestamp vs the
stepping client's current step — params and prediction modes alike) is
skipped at teacher-assembly time; a client whose whole sample is stale
falls back to the supervised-only step. Skips surface per client as the
``stale_skipped`` metric and in `CommMeter.gate_summary()`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.pool import CheckpointPool, PoolEntry
from repro.obs import tracer as trace
from repro.core.evaluation import (
    fleet_beta_metrics,
    label_histogram,
    per_label_head_accuracy,
)
from repro.core.graph import Adjacency, as_graph_fn, validate_adjacency
from repro.core.mhd import MHDConfig, mhd_total_loss
from repro.data.pipeline import BatchIterator, PublicPool, client_stream_seed
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


@dataclasses.dataclass
class RunConfig:
    steps: int = 1000
    batch_size: int = 32
    public_batch_size: int = 32
    eval_every: int = 200
    eval_batch_size: int = 256
    seed: int = 0
    # bounded-staleness gate: max age (in steps / wall ticks) of a pool
    # entry that may still serve as a distillation teacher. None =
    # unbounded (the paper's default — pool lag is part of the method).
    max_staleness: Optional[int] = None


@dataclasses.dataclass
class ClientState:
    client_id: int
    bundle: ModelBundle
    params: Any
    opt_state: Any
    pool: CheckpointPool
    private_iter: BatchIterator
    label_hist: np.ndarray  # private-label distribution, for β_priv


class DecentralizedTrainer:
    def __init__(
        self,
        bundles: Sequence[ModelBundle],
        optimizer: Optimizer,
        mhd_cfg: MHDConfig,
        run_cfg: RunConfig,
        arrays: Dict[str, np.ndarray],  # {"images": ..., "labels": ...}
        client_indices: Sequence[np.ndarray],
        public_indices: np.ndarray,
        graph: Adjacency,
        num_labels: int,
        exchange: str = "params",
        comm: Optional[Any] = None,  # repro.comm.CommConfig
        transport: Optional[Any] = None,  # repro.comm.Transport
        local_clients: Optional[Sequence[int]] = None,
        init_scheme: str = "legacy",
        membership: Optional[Any] = None,  # repro.fleet.Membership
    ):
        # ``local_clients`` restricts which clients this *process* drives
        # (multi-process gossip: one trainer per OS process, each stepping
        # and publishing only its own clients over a socket transport;
        # remote clients exist only as mailbox senders). None = all — the
        # single-process behavior, unchanged.
        #
        # ``init_scheme`` picks the model-init rng scheme:
        #   * "legacy" — one shared split chain: every process replays the
        #     whole fleet's init stream (client i's params are identical in
        #     every process, but a K-process fleet does O(K²) init work).
        #     Bitwise-identical to all pre-fleet runs.
        #   * "per_client" — client i inits from fold_in(PRNGKey(seed), i):
        #     a process materializes params only for the clients it
        #     drives — O(K) fleet startup. A different stream from legacy,
        #     hence opt-in (`ExperimentSpec.init_scheme`).
        #
        # ``membership`` (repro.fleet.Membership) makes the fleet elastic:
        # clients dead at construction start deactivated, and the bus
        # tombstones mail addressed to dead clients. The scripted churn
        # itself is driven from outside (repro.fleet.events.ChurnDriver).
        if local_clients is not None and exchange == "params":
            raise ValueError(
                "local_clients requires a prediction exchange: the legacy "
                "params mode reads neighbor parameters from shared memory, "
                "which other processes don't have")
        if init_scheme not in ("legacy", "per_client"):
            raise ValueError(f"unknown init_scheme {init_scheme!r}; "
                             "known: legacy, per_client")
        if init_scheme == "per_client" and exchange == "params":
            raise ValueError(
                "init_scheme='per_client' skips materializing non-local "
                "clients; the legacy params exchange reads every client's "
                "raw params and needs the legacy scheme")
        if not callable(graph):
            validate_adjacency(graph)
        self.graph_fn = as_graph_fn(graph)
        self.mhd_cfg = mhd_cfg
        self.run_cfg = run_cfg
        self.optimizer = optimizer
        self.num_labels = num_labels
        self.rng = np.random.default_rng(run_cfg.seed)
        self.public = PublicPool(arrays, public_indices,
                                 run_cfg.public_batch_size, seed=run_cfg.seed)
        self._teacher_apply_cache: Dict[str, Callable] = {}
        self._update_cache: Dict[str, Callable] = {}
        self._supervised_cache: Dict[str, Callable] = {}
        # abstract arg shapes of each bundle's distill update, captured on
        # its first distillation step — enough to re-lower the jitted
        # update for roofline costing (repro.obs.metrics.distill_step_cost)
        # without holding any concrete arrays
        self._distill_arg_shapes: Dict[str, Tuple] = {}

        self.exchange = exchange
        if exchange == "params":
            self.comm_cfg = self.codec = self.bus = self.meter = None
            pool_cls = CheckpointPool
        else:
            from repro.comm import (CommConfig, CommMeter, LoopbackTransport,
                                    PredictionBus, PredictionPool, make_codec)

            self.comm_cfg = comm or CommConfig()
            self.codec = make_codec(exchange, self.comm_cfg)
            self.meter = CommMeter()
            self.bus = PredictionBus(
                transport if transport is not None else LoopbackTransport(),
                self.graph_fn, len(bundles), meter=self.meter,
                membership=membership)
            self.horizon = self.comm_cfg.horizon or mhd_cfg.pool_update_every
            pool_cls = PredictionPool
            self._pending: Dict[int, Dict[int, int]] = {
                i: {} for i in range(len(bundles))}

        if local_clients is None:
            self.local_ids = list(range(len(bundles)))
        else:
            self.local_ids = sorted({int(c) for c in local_clients})
            if any(i < 0 or i >= len(bundles) for i in self.local_ids):
                raise ValueError(f"local_clients {self.local_ids} out of "
                                 f"range for {len(bundles)} clients")
        local_set = set(self.local_ids)

        self.init_scheme = init_scheme
        self.membership = membership
        self._arrays = arrays
        self._client_indices = list(client_indices)
        # which clients this trainer actually ran model init for — the
        # per_client scheme's O(K) startup claim is asserted on this
        self.initialized_clients: List[int] = []
        self.clients: List[ClientState] = []
        key = jax.random.PRNGKey(run_cfg.seed)
        for i, bundle in enumerate(bundles):
            if init_scheme == "legacy":
                key, sub = jax.random.split(key)
            else:
                sub = jax.random.fold_in(jax.random.PRNGKey(run_cfg.seed), i)
            if init_scheme == "legacy" or i in local_set:
                params = bundle.init(sub)
                opt_state = optimizer.init(params)
                self.initialized_clients.append(i)
            else:
                # per_client scheme: a remote client's params live in its
                # own process; here it exists only as a mailbox address
                params = opt_state = None
            self.clients.append(ClientState(
                client_id=i,
                bundle=bundle,
                params=params,
                opt_state=opt_state,
                pool=pool_cls(mhd_cfg.pool_size,
                              mhd_cfg.pool_update_every,
                              seed=run_cfg.seed + 101 * i),
                private_iter=BatchIterator(arrays, client_indices[i],
                                           run_cfg.batch_size,
                                           seed=client_stream_seed(
                                               run_cfg.seed, i)),
                label_hist=label_histogram(arrays["labels"],
                                           client_indices[i], num_labels),
            ))
        # clients dead at wall step 0 (scripted late joiners) start
        # deactivated: they neither step nor publish until activated
        self._dead: set = set()
        if membership is not None:
            alive0 = membership.alive(0)
            self._dead = {i for i in range(len(bundles)) if i not in alive0}
        self.local = [self.clients[i] for i in self.local_ids
                      if i not in self._dead]
        self._seed_pools(step=0)

    # -- jitted function caches ------------------------------------------

    def _teacher_apply(self, bundle: ModelBundle) -> Callable:
        if bundle.name not in self._teacher_apply_cache:
            def apply_fn(params, batch):
                out = bundle.apply(params, batch)
                keep = {"embedding": out["embedding"],
                        "logits": out["logits"],
                        "aux_logits": out["aux_logits"]}
                # positions-as-samples bundles (repro.lm) carry their own
                # targets + position→sequence map; the publish path never
                # puts these on the wire (its key list is explicit), but
                # the evaluator aggregates through them
                for k in ("labels", "sample_rows"):
                    if k in out:
                        keep[k] = out[k]
                return keep
            self._teacher_apply_cache[bundle.name] = jax.jit(apply_fn)
        return self._teacher_apply_cache[bundle.name]

    def _client_update(self, bundle: ModelBundle) -> Callable:
        if bundle.name not in self._update_cache:
            mhd_cfg = self.mhd_cfg
            opt = self.optimizer

            def loss_fn(params, private_batch, public_batch, teachers, rng):
                out_priv = bundle.apply(params, private_batch)
                out_pub = bundle.apply(params, public_batch)
                # positions-as-samples bundles (repro.lm) carry their own
                # CE targets (next tokens) and an auxiliary loss (MoE
                # router balancing); static dict membership, jit-safe
                labels = out_priv["labels"] if "labels" in out_priv \
                    else private_batch["labels"]
                loss, metrics = mhd_total_loss(out_priv, labels, out_pub,
                                               teachers, mhd_cfg, rng)
                if out_priv.get("aux_loss") is not None:
                    loss = loss + out_priv["aux_loss"]
                return loss, metrics

            def update(params, opt_state, private_batch, public_batch,
                       teachers, step, rng):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, private_batch,
                                           public_batch, teachers, rng)
                params, opt_state = opt.update(grads, opt_state, params, step)
                metrics["loss"] = loss
                return params, opt_state, metrics

            self._update_cache[bundle.name] = jax.jit(update)
        return self._update_cache[bundle.name]

    def _supervised_update(self, bundle: ModelBundle) -> Callable:
        """Fallback step for clients with no usable teachers (isolated
        topologies, empty mailboxes): Eq. (1) with both distillation terms
        zero — plain supervised CE on the private batch."""
        if bundle.name not in self._supervised_cache:
            opt = self.optimizer

            def loss_fn(params, private_batch):
                out = bundle.apply(params, private_batch)
                logits = out["logits"].astype(jnp.float32)
                labels = out["labels"] if "labels" in out \
                    else private_batch["labels"]
                logz = jax.nn.logsumexp(logits, axis=-1)
                ll = jnp.take_along_axis(
                    logits, labels[..., None], axis=-1)[..., 0]
                ce = jnp.mean(logz - ll)
                loss = ce
                if out.get("aux_loss") is not None:
                    loss = loss + out["aux_loss"]
                return loss, {"ce": ce}

            def update(params, opt_state, private_batch, step):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, private_batch)
                params, opt_state = opt.update(grads, opt_state, params, step)
                metrics["loss"] = loss
                return params, opt_state, metrics

            self._supervised_cache[bundle.name] = jax.jit(update)
        return self._supervised_cache[bundle.name]

    # -- pool mechanics ----------------------------------------------------

    def _seed_pools(self, step: int) -> None:
        """Fill each pool from its neighbors' initial state: params in
        legacy mode, published prediction windows in prediction mode."""
        if self.exchange != "params":
            self._publish_round(step)
        adj = self.graph_fn(step)
        for c in self.local:
            nbrs = adj[c.client_id]
            for j in nbrs:
                if len(c.pool) >= c.pool.capacity:
                    break
                entry = self._fetch_entry(c, j, step)
                if entry is not None:
                    c.pool.insert(entry)

    # -- client churn (repro.fleet) ----------------------------------------

    @property
    def active_ids(self) -> List[int]:
        """The locally driven clients currently alive (stepping order)."""
        return [c.client_id for c in self.local]

    def _require_local(self, cid: int) -> ClientState:
        if cid not in self.local_ids:
            raise ValueError(
                f"client {cid} is not driven by this process "
                f"(local: {self.local_ids})")
        return self.clients[cid]

    def deactivate_client(self, cid: int) -> None:
        """Kill one locally driven client: it stops stepping, publishing
        and pulling, and its volatile state — mailbox, pending pulls,
        teacher pool — dies with it (everything a crashed process loses;
        params/opt survive only in snapshots). Idempotent."""
        cid = int(cid)
        self._require_local(cid)
        self._dead.add(cid)
        self.local = [c for c in self.local if c.client_id != cid]
        if self.exchange != "params":
            self.bus.clear_mailbox(cid)
            self._pending[cid] = {}
        self.clients[cid].pool.entries.clear()

    def activate_client(self, cid: int) -> None:
        """(Re)activate a locally driven client. Its state must exist —
        restored from a snapshot (`repro.fleet.snapshot`) or freshly built
        via ``reinit_client`` — before it steps again."""
        cid = int(cid)
        c = self._require_local(cid)
        if c.params is None:
            raise ValueError(
                f"client {cid} has no materialized state; restore it from "
                "a snapshot or call reinit_client first")
        self._dead.discard(cid)
        self.local = [self.clients[i] for i in self.local_ids
                      if i not in self._dead]

    def reinit_client(self, cid: int) -> None:
        """Fresh state for a joining/restarting client: params from the
        per-client fold-in stream (deterministic regardless of the fleet's
        ``init_scheme``), fresh optimizer state, its private stream
        rewound to the start, and a freshly seeded pool — a brand-new
        process with no memory, matching what an actually relaunched
        gossip child would construct."""
        cid = int(cid)
        c = self._require_local(cid)
        sub = jax.random.fold_in(jax.random.PRNGKey(self.run_cfg.seed), cid)
        c.params = c.bundle.init(sub)
        c.opt_state = self.optimizer.init(c.params)
        c.private_iter = BatchIterator(
            self._arrays, self._client_indices[cid], self.run_cfg.batch_size,
            seed=client_stream_seed(self.run_cfg.seed, cid))
        c.pool = type(c.pool)(self.mhd_cfg.pool_size,
                              self.mhd_cfg.pool_update_every,
                              seed=self.run_cfg.seed + 101 * cid)
        self.initialized_clients.append(cid)

    def _maybe_update_pools(self, step: int) -> None:
        if step % self.mhd_cfg.pool_update_every != 0:
            self._comm_tick(step)
            return
        if self.exchange != "params":
            self._publish_round(step)
            self._resolve_pending(step)  # older rounds' pulls first
        adj = self.graph_fn(step)
        for c in self.local:
            self._pull_client(c, step, adj)

    def _comm_tick(self, step: int) -> None:
        """Between pool rounds: drain in-flight (latency) mail and complete
        late pulls. No-op in the legacy params mode."""
        if self.exchange != "params":
            self.bus.deliver(step)
            self._resolve_pending(step)

    # -- op-granular entry points (core/scheduler.py) ----------------------
    # The scoreboard scheduler decomposes a client's progress into
    # LocalStep / Publish / Pull / Resolve operations and issues them
    # independently; these are the public per-op surfaces it drives.
    # `step_client(defer=True)` below is the LocalStep+Resolve pair.

    def comm_pump(self, step: int) -> None:
        """The transport pump op: deliver in-flight mail at wall tick
        ``step`` and complete late pulls (`_resolve_pending`). Safe to
        call once per wall tick in any interleaving; a no-op in the
        legacy params mode."""
        self._comm_tick(step)

    def publish_clients(self, client_ids: Sequence[int],
                        step: int) -> int:
        """The Publish op for a group of clients: encode each one's
        prediction window over the next ``horizon`` public batches and
        put it on the bus. Grouped so co-boundary publishers share the
        batch materialization; delivery is the pump's job. Returns the
        number of clients that had a receiver under G_t."""
        return self._publish_clients(list(client_ids), step)

    def pull_client(self, client_id: int, step: int,
                    adj: Optional[Adjacency] = None) -> None:
        """The Pull op: one pool-refresh pull for one client (shared-rng
        neighbor draw; see `_pull_client` for the ordering contract)."""
        self._pull_client(self.clients[client_id], step, adj)

    def _pull_client(self, client: ClientState, step: int,
                     adj: Optional[Adjacency] = None) -> None:
        """One pool-refresh pull for one client: draw a random in-neighbor
        (shared rng — clients pulling at the same step consume the stream
        in client-id order) and insert its entry if usable. Pass a
        precomputed ``adj`` when pulling for many clients at one step."""
        nbrs = (adj if adj is not None
                else self.graph_fn(step))[client.client_id]
        if not nbrs:
            return
        j = int(self.rng.choice(list(nbrs)))
        entry = self._fetch_entry(client, j, step)
        trace.instant("runtime/pull", client=client.client_id, src=j,
                      step=step, hit=entry is not None)
        if entry is not None:
            client.pool.insert(entry)

    def _fetch_entry(self, client: ClientState, j: int,
                     step: int) -> Optional[PoolEntry]:
        """The pool-insert payload for teacher j: its raw params (legacy) or
        its decoded mailbox window. When j's message is dropped, in flight,
        or expired, the pull is recorded as *pending*: the insert happens
        on whatever later step usable mail from j arrives (zero-latency
        transports never hit this path, keeping the param-pool equivalence
        exact)."""
        if self.exchange == "params":
            return PoolEntry(j, self.clients[j].params, step)
        mail = self.bus.mailbox(client.client_id).get(j)
        if mail is None or mail.sent_step + self.horizon <= step:
            # one pending pull per sender: a newer pull supersedes, so a
            # single late message can't be inserted multiple times
            self._pending[client.client_id][j] = step
            return None
        return PoolEntry(j, self._decode_window(mail), mail.sent_step)

    def _resolve_pending(self, step: int) -> None:
        """Late-arriving mail: complete pulls that found no usable message
        at their pool-update step, as soon as a window that still covers
        the current step shows up. Pulls whose own round has fully expired
        are abandoned."""
        t0 = trace.now()
        resolved = 0
        for c in self.local:
            keep: Dict[int, int] = {}
            for j, rnd in self._pending[c.client_id].items():
                mail = self.bus.mailbox(c.client_id).get(j)
                if mail is not None and mail.sent_step >= rnd and \
                        mail.sent_step + self.horizon > step:
                    c.pool.insert(
                        PoolEntry(j, self._decode_window(mail),
                                  mail.sent_step))
                    resolved += 1
                elif rnd + self.horizon > step:
                    keep[j] = rnd
            self._pending[c.client_id] = keep
        if resolved:
            trace.complete("runtime/resolve", t0, step=step,
                           resolved=resolved)

    # -- prediction exchange (repro.comm) ----------------------------------

    def _publish_round(self, step: int) -> None:
        """Synchronous publish: every client with a subscriber encodes and
        publishes, then mail is delivered. Delivery is unconditional so
        in-flight (latency) mail keeps flowing even at a boundary where
        G_t leaves nobody subscribed — every step drains the transport."""
        self._publish_clients(None, step)
        self.bus.deliver(step)

    def _publish_clients(self, client_ids: Optional[Sequence[int]],
                         step: int) -> int:
        """The selected clients (None = all) encode predictions on the next
        ``horizon`` public batches and publish them on the bus (paper §3.2:
        only predictions and sample hashes cross the wire). Returns the
        number of clients that had a receiver under G_t; the caller is
        responsible for ``bus.deliver``. A publisher whose outputs the
        codec refuses (non-finite — a diverged client) is skipped and
        metered, never crashing the round."""
        from repro.comm import NonFiniteError

        adj = self.graph_fn(step)
        subscribed = {j for nbrs in adj for j in nbrs}
        selected = self.local if client_ids is None else \
            [self.clients[i] for i in client_ids]
        todo = [c for c in selected if c.client_id in subscribed]
        if not todo:
            return 0
        W = self.horizon
        ids = np.stack([self.public.sample_ids(step + w) for w in range(W)])
        batches = [{k: jnp.asarray(v)
                    for k, v in self.public.sample(step + w).items()}
                   for w in range(W)]
        for c in todo:
            t_fwd = trace.now()
            apply_fn = self._teacher_apply(c.bundle)
            frames = [apply_fn(c.params, b) for b in batches]
            # stacked on device: the forward stays fully async here, and a
            # codec with a device fast path (TopKCodec) packs wire arrays
            # in-graph — only wire-dtype bytes ever reach the host
            outs = {key: jnp.stack([f[key] for f in frames])
                    .astype(jnp.float32)
                    for key in ("embedding", "logits", "aux_logits")}
            trace.complete("publish/forward", t_fwd, client=c.client_id,
                           step=step, window=W)
            t_enc = trace.now()
            try:
                payload = self.codec.encode(c.client_id, step, step, ids,
                                            outs)
            except NonFiniteError:
                if self.meter is not None:
                    self.meter.rejected_publishes += 1
                continue
            trace.complete("publish/encode", t_enc, client=c.client_id,
                           step=step, nbytes=len(payload))
            self.bus.publish(c.client_id, payload, step)
        return len(todo)

    def _decode_window(self, mail) -> Any:
        from repro.comm import PredictionWindow

        with trace.span("wire/decode", src=mail.src,
                        nbytes=len(mail.payload)):
            msg = self.codec.decode(mail.payload)
            for w in range(msg.window):
                expect = self.public.sample_ids(msg.t0 + w).astype(np.uint64)
                if not np.array_equal(msg.arrays["sample_ids"][w], expect):
                    raise ValueError(
                        f"sample-id mismatch in message from client "
                        f"{msg.src} at public step {msg.t0 + w}")
            return PredictionWindow(msg.t0, self.codec.densify(msg))

    # -- teacher assembly ---------------------------------------------------

    def _stack_teachers(self, client: ClientState, public_batch,
                        step: int) -> Tuple[Optional[Any], int]:
        """Sample Δ pool entries, drop the ones the bounded-staleness gate
        rejects, and stack the survivors' public-batch outputs — scored
        locally from raw params in legacy mode, decoded from received
        predictions in prediction modes. Returns ``(teachers, skipped)``;
        teachers is None when nothing survived the gate (supervised
        fallback, never an error)."""
        entries = client.pool.sample(self.mhd_cfg.delta)
        sampled = len(entries)
        if self.exchange != "params":
            entries = client.pool.usable(entries, step)
        ms = self.run_cfg.max_staleness
        if ms is not None:
            entries = [e for e in entries if step - e.step <= ms]
        skipped = sampled - len(entries)
        if skipped:
            trace.instant("runtime/gate_skip", client=client.client_id,
                          step=step, fresh=len(entries), skipped=skipped)
        if self.meter is not None and sampled:
            self.meter.record_gate(client.client_id, len(entries), skipped)
        if not entries:
            return None, skipped
        # pad to Δ by cycling over the originally sampled entries
        entries = [entries[i % len(entries)]
                   for i in range(self.mhd_cfg.delta)]
        outs = []
        for e in entries:
            if self.exchange == "params":
                teacher_bundle = self.clients[e.client_id].bundle
                outs.append(self._teacher_apply(teacher_bundle)(
                    e.params, public_batch))
            else:
                outs.append({k: jnp.asarray(v)
                             for k, v in e.params.frame(step).items()})
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *outs), skipped

    # -- training loop -----------------------------------------------------

    def step_client(self, c: ClientState, public_batch, t: int,
                    opt_step: Optional[int] = None, defer: bool = False):
        """One local optimization step for one client at (wall) step t.

        ``opt_step`` is the client's optimizer/LR-schedule step — its
        *local* step count under the async scheduler; defaults to t (the
        synchronous loop, where wall and local clocks coincide).

        ``defer=False`` (the default) returns the metrics dict directly.
        ``defer=True`` returns a zero-arg *resolve* callable instead: the
        jitted update has been dispatched to the device, but the blocking
        host conversions (``float`` on the metrics) happen only when the
        callable runs. This is the compute/comm overlap hook — the caller
        runs the communication phase (encode, publish, socket drain)
        while the device is still chewing on the update, then resolves.
        Numerics, rng draws and their order are identical either way;
        only where the host blocks moves."""
        opt_step = t if opt_step is None else opt_step
        t_step = trace.now()
        if self.exchange != "params":
            self.bus.advance(c.client_id, t)
        private_np = c.private_iter.next()
        private_batch = {k: jnp.asarray(v) for k, v in private_np.items()}
        teachers, skipped = self._stack_teachers(c, public_batch, t)
        rng = jax.random.PRNGKey((t << 10) + c.client_id)
        step_arg = jnp.asarray(opt_step)
        if teachers is None:
            t_up = trace.now()
            update = self._supervised_update(c.bundle)
            c.params, c.opt_state, metrics = update(
                c.params, c.opt_state, private_batch, step_arg)
        else:
            if c.bundle.name not in self._distill_arg_shapes:
                self._distill_arg_shapes[c.bundle.name] = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        jnp.shape(x), jnp.result_type(x)),
                    (c.params, c.opt_state, private_batch, public_batch,
                     teachers, step_arg, rng))
            t_up = trace.now()
            update = self._client_update(c.bundle)
            c.params, c.opt_state, metrics = update(
                c.params, c.opt_state, private_batch, public_batch,
                teachers, step_arg, rng)

        def resolve() -> Dict[str, float]:
            # the float() conversions block on the device computation, so
            # the retro-emitted update span covers dispatch → completion;
            # overlapped comm spans emitted in between nest inside it and
            # the tracer's self-time sweep subtracts them
            out = {f"c{c.client_id}/{k}": float(v)
                   for k, v in metrics.items()}
            trace.complete(
                "runtime/supervised" if teachers is None
                else "runtime/distill",
                t_up, client=c.client_id, step=t, bundle=c.bundle.name)
            out[f"c{c.client_id}/stale_skipped"] = float(skipped)
            out[f"c{c.client_id}/distill_active"] = float(
                teachers is not None)
            if self.exchange != "params":
                # -1.0 = empty mailbox (bus.EMPTY_STALENESS), not "fresh"
                out[f"c{c.client_id}/mail_staleness"] = \
                    self.bus.staleness(c.client_id, t)
            trace.complete("runtime/step", t_step, client=c.client_id,
                           step=t, distill=teachers is not None)
            return out

        return resolve if defer else resolve()

    def step(self, t: int) -> Dict[str, float]:
        public_np = self.public.sample(t)
        public_batch = {k: jnp.asarray(v) for k, v in public_np.items()}
        # dispatch every client's update, run the communication phase
        # while the device computes, then block on the metrics. Resolved
        # LIFO so the retro-emitted per-client trace spans nest instead
        # of overlapping (the tracer assumes single-threaded nesting).
        pending = [self.step_client(c, public_batch, t, defer=True)
                   for c in self.local]
        self._maybe_update_pools(t + 1)
        all_metrics: Dict[str, float] = {}
        for resolve in reversed(pending):
            all_metrics.update(resolve())
        return all_metrics

    def train(self, eval_arrays: Optional[Dict[str, np.ndarray]] = None,
              log_every: int = 0,
              eval_hook: Optional[Callable[[int, Dict], None]] = None):
        history = []
        for t in range(self.run_cfg.steps):
            metrics = self.step(t)
            if log_every and t % log_every == 0:
                loss = np.mean([v for k, v in metrics.items()
                                if k.endswith("/loss")])
                print(f"step {t}: mean client loss {loss:.4f}")
            if eval_arrays is not None and self.run_cfg.eval_every and \
                    (t + 1) % self.run_cfg.eval_every == 0:
                ev = self.evaluate(eval_arrays)
                history.append((t + 1, ev))
                if eval_hook:
                    eval_hook(t + 1, ev)
        return history

    # -- checkpointing ------------------------------------------------------

    def save(self, directory: str, step: int) -> None:
        """Persist every *materialized* client's (params, opt_state) — a
        decentralized run is resumable per-client (each client would own
        its directory in a real deployment; under init_scheme='per_client'
        a process only has — and only saves — its own clients)."""
        from repro.checkpoint.io import save_client_states

        have = [c for c in self.clients if c.params is not None]
        save_client_states(directory, step,
                           [(c.params, c.opt_state) for c in have],
                           ids=[c.client_id for c in have])

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        from repro.checkpoint.io import restore_client_states

        have = [c for c in self.clients if c.params is not None]
        restored_step, states = restore_client_states(
            directory, [(c.params, c.opt_state) for c in have], step,
            ids=[c.client_id for c in have])
        for c, (params, opt_state) in zip(have, states):
            c.params = params
            c.opt_state = opt_state
        if self.exchange != "params":
            # construction-time windows are expired at the restored step —
            # drop them (and any stale pulls) so reseeding actually lands
            for c in self.clients:
                c.pool.entries.clear()
            self._pending = {c.client_id: {} for c in self.clients}
        self._seed_pools(step=restored_step)
        return int(restored_step)

    # -- evaluation (β_priv / β_sh, paper §4.2.1) ---------------------------

    def evaluate(self, arrays: Dict[str, np.ndarray]) -> Dict[str, float]:
        """Per-label accuracies on a uniform test set; β_sh = uniform mean,
        β_priv = mean weighted by the client's private label distribution.
        Delegates to the algorithm-agnostic `core.evaluation` reducers, so
        the baselines report the exact same metric."""
        m = self.mhd_cfg.num_aux_heads
        per_client = []
        for c in self.local:
            per_label, present = per_label_head_accuracy(
                self._teacher_apply(c.bundle), c.params, arrays,
                self.num_labels, m, self.run_cfg.eval_batch_size)
            per_client.append((c.client_id, per_label, present, c.label_hist))
        return fleet_beta_metrics(per_client, m)
