"""Supervised trainer — the paper's 'Supervised' upper bound and the
'Separate' baseline (each client trained in isolation on its shard)."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import BatchIterator
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


def make_train_step(bundle: ModelBundle, optimizer: Optimizer) -> Callable:
    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return jax.jit(train_step)


def train_supervised(
    bundle: ModelBundle,
    optimizer: Optimizer,
    arrays: Dict[str, np.ndarray],
    indices: np.ndarray,
    steps: int,
    batch_size: int,
    seed: int = 0,
    params: Any = None,
):
    """Train one model on the given index subset; returns trained params."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = bundle.init(key)
    opt_state = optimizer.init(params)
    it = BatchIterator(arrays, indices, batch_size, seed=seed)
    train_step = make_train_step(bundle, optimizer)
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in it.next().items()}
        params, opt_state, _ = train_step(params, opt_state, batch,
                                          jnp.asarray(t))
    return params


def eval_per_label_accuracy(bundle: ModelBundle, params, arrays, num_labels,
                            batch_size: int = 256, head: str = "main"):
    """Per-label accuracy vector over a test set (main or aux head)."""
    apply_fn = jax.jit(bundle.apply)
    labels = arrays["labels"]
    correct = np.zeros(num_labels)
    count = np.zeros(num_labels)
    for s in range(0, labels.shape[0], batch_size):
        batch = {k: jnp.asarray(v[s:s + batch_size])
                 for k, v in arrays.items() if k != "labels"}
        out = apply_fn(params, batch)
        logits = out["logits"] if head == "main" else out["aux_logits"][int(head[3:]) - 1]
        pred = np.asarray(jnp.argmax(logits, -1))
        lab = labels[s:s + batch_size]
        np.add.at(count, lab, 1)
        np.add.at(correct, lab[pred == lab], 1)
    per_label = correct / np.maximum(count, 1)
    return per_label, count > 0
