"""Supervised trainer — the paper's 'Supervised' upper bound and the
'Separate' baseline (each client trained in isolation on its shard).

`SupervisedTrainer` is the stepwise form the `repro.exp` Algorithm
protocol drives: ``scope="pooled"`` trains one model on the union of all
private shards (the upper bound), ``scope="separate"`` trains one model
per client on its own shard with no communication (the lower bound).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import BatchIterator, client_stream_seed
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


def make_train_step(bundle: ModelBundle, optimizer: Optimizer) -> Callable:
    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return jax.jit(train_step)


def train_supervised(
    bundle: ModelBundle,
    optimizer: Optimizer,
    arrays: Dict[str, np.ndarray],
    indices: np.ndarray,
    steps: int,
    batch_size: int,
    seed: int = 0,
    params: Any = None,
):
    """Train one model on the given index subset; returns trained params."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = bundle.init(key)
    opt_state = optimizer.init(params)
    it = BatchIterator(arrays, indices, batch_size, seed=seed)
    train_step = make_train_step(bundle, optimizer)
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in it.next().items()}
        params, opt_state, _ = train_step(params, opt_state, batch,
                                          jnp.asarray(t))
    return params


class SupervisedTrainer:
    """Stepwise supervised training over a client fleet.

    ``scope="pooled"``   — one model (bundles[0]) on all private shards.
    ``scope="separate"`` — K isolated models, one per client shard; model
    inits follow the decentralized trainer's key-split sequence and the
    private-batch streams come from `client_stream_seed`, so 'Separate'
    is MHD with the distillation terms removed — sample-order included.
    """

    def __init__(
        self,
        bundles: Sequence[ModelBundle],
        optimizer: Optimizer,
        arrays: Dict[str, np.ndarray],
        client_indices: Sequence[np.ndarray],
        num_labels: Optional[int] = None,
        batch_size: int = 32,
        scope: str = "separate",
        seed: int = 0,
        eval_batch_size: int = 256,
    ):
        from repro.core.evaluation import label_histogram

        if scope not in ("pooled", "separate"):
            raise ValueError(f"unknown supervised scope {scope!r}")
        self.scope = scope
        self.optimizer = optimizer
        if num_labels is None:
            num_labels = int(arrays["labels"].max()) + 1
        self.num_labels = num_labels
        self.eval_batch_size = eval_batch_size
        if scope == "pooled":
            if any(b.config != bundles[0].config for b in bundles[1:]):
                raise ValueError(
                    "scope='pooled' trains ONE model on the pooled shards; "
                    f"got a heterogeneous fleet "
                    f"{sorted({b.name for b in bundles})} — pick one "
                    "architecture or use scope='separate'")
            self.bundles = [bundles[0]]
            indices = [np.concatenate(list(client_indices))]
        else:
            self.bundles = list(bundles)
            indices = list(client_indices)
        key = jax.random.PRNGKey(seed)
        self.params: List[Any] = []
        self.opt_states: List[Any] = []
        for b in self.bundles:
            key, sub = jax.random.split(key)
            p = b.init(sub)
            self.params.append(p)
            self.opt_states.append(optimizer.init(p))
        self.iters = [BatchIterator(arrays, idx, batch_size,
                                    seed=client_stream_seed(seed, i))
                      for i, idx in enumerate(indices)]
        self.label_hists = [label_histogram(arrays["labels"], idx, num_labels)
                            for idx in indices]
        self._train_steps = {}
        self._apply_fns = {}  # eval cache: jit once per arch
        for b in self.bundles:
            if b.name not in self._train_steps:
                self._train_steps[b.name] = make_train_step(b, optimizer)
                self._apply_fns[b.name] = jax.jit(b.apply)

    @property
    def num_models(self) -> int:
        return len(self.bundles)

    def step(self, t: int) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for i, b in enumerate(self.bundles):
            batch = {k: jnp.asarray(v)
                     for k, v in self.iters[i].next().items()}
            self.params[i], self.opt_states[i], metrics = \
                self._train_steps[b.name](self.params[i], self.opt_states[i],
                                          batch, jnp.asarray(t))
            out.update({f"c{i}/{k}": float(v) for k, v in metrics.items()})
        return out

    def evaluate(self, arrays: Dict[str, np.ndarray]) -> Dict[str, float]:
        from repro.core.evaluation import (fleet_beta_metrics,
                                           per_label_head_accuracy)

        per_client = []
        for i, b in enumerate(self.bundles):
            per_label, present = per_label_head_accuracy(
                self._apply_fns[b.name], self.params[i], arrays,
                self.num_labels, num_aux_heads=0,
                batch_size=self.eval_batch_size)
            per_client.append((i, per_label, present, self.label_hists[i]))
        return fleet_beta_metrics(per_client, num_aux_heads=0)

    def save(self, directory: str, step: int) -> None:
        from repro.checkpoint.io import save_client_states

        save_client_states(directory, step,
                           zip(self.params, self.opt_states))

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        from repro.checkpoint.io import restore_client_states

        restored, states = restore_client_states(
            directory, zip(self.params, self.opt_states), step)
        self.params = [p for p, _ in states]
        self.opt_states = [s for _, s in states]
        return restored


def eval_per_label_accuracy(bundle: ModelBundle, params, arrays, num_labels,
                            batch_size: int = 256, head: str = "main"):
    """Per-label accuracy vector over a test set (main or aux head)."""
    apply_fn = jax.jit(bundle.apply)
    labels = arrays["labels"]
    correct = np.zeros(num_labels)
    count = np.zeros(num_labels)
    for s in range(0, labels.shape[0], batch_size):
        batch = {k: jnp.asarray(v[s:s + batch_size])
                 for k, v in arrays.items() if k != "labels"}
        out = apply_fn(params, batch)
        logits = out["logits"] if head == "main" else out["aux_logits"][int(head[3:]) - 1]
        pred = np.asarray(jnp.argmax(logits, -1))
        lab = labels[s:s + batch_size]
        np.add.at(count, lab, 1)
        np.add.at(correct, lab[pred == lab], 1)
    per_label = correct / np.maximum(count, 1)
    return per_label, count > 0
