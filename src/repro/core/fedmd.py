"""FedMD-style baseline (Li & Wang, 2019 [19]) — the paper's Table 2
comparison: *centralized* distillation via consensus logits.

Each round: every client scores the public batch; the server averages the
class scores into a consensus; clients take gradient steps matching the
consensus (digest) and then train on their private data (revisit). Unlike
MHD there is no confidence gating, no aux-head chain, and a central
aggregator is required.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import BatchIterator, PublicPool
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


def train_fedmd(
    bundles: Sequence[ModelBundle],
    optimizer: Optimizer,
    arrays: Dict[str, np.ndarray],
    client_indices: Sequence[np.ndarray],
    public_indices: np.ndarray,
    steps: int,
    batch_size: int,
    public_batch_size: int = 64,
    digest_weight: float = 1.0,
    seed: int = 0,
) -> List[Any]:
    K = len(bundles)
    key = jax.random.PRNGKey(seed)
    params = []
    opt_states = []
    for i, b in enumerate(bundles):
        key, sub = jax.random.split(key)
        p = b.init(sub)
        params.append(p)
        opt_states.append(optimizer.init(p))
    iters = [BatchIterator(arrays, idx, batch_size, seed=seed + 7 * i)
             for i, idx in enumerate(client_indices)]
    public = PublicPool(arrays, public_indices, public_batch_size, seed=seed)

    score_fns = {}
    update_fns = {}
    for b in bundles:
        if b.name not in score_fns:
            score_fns[b.name] = jax.jit(
                lambda p, batch, _b=b: _b.apply(p, batch)["logits"])

            def update(p, s, private_batch, public_batch, consensus, step,
                       _b=b):
                def loss_fn(p_):
                    out_priv = _b.apply(p_, private_batch)
                    lg = out_priv["logits"].astype(jnp.float32)
                    logz = jax.nn.logsumexp(lg, axis=-1)
                    ll = jnp.take_along_axis(
                        lg, private_batch["labels"][:, None], axis=-1)[:, 0]
                    ce = jnp.mean(logz - ll)
                    out_pub = _b.apply(p_, public_batch)
                    logp = jax.nn.log_softmax(
                        out_pub["logits"].astype(jnp.float32), axis=-1)
                    digest = -jnp.mean(jnp.sum(consensus * logp, axis=-1))
                    return ce + digest_weight * digest

                loss, grads = jax.value_and_grad(loss_fn)(p)
                p, s = optimizer.update(grads, s, p, step)
                return p, s, loss

            update_fns[b.name] = jax.jit(update)

    for t in range(steps):
        public_batch = {k: jnp.asarray(v) for k, v in public.sample(t).items()}
        # server: consensus class scores (mean softmax)
        probs = [jax.nn.softmax(score_fns[bundles[i].name](
            params[i], public_batch).astype(jnp.float32), -1) for i in range(K)]
        consensus = jax.lax.stop_gradient(
            jnp.mean(jnp.stack(probs, 0), axis=0))
        for i in range(K):
            private_batch = {k: jnp.asarray(v)
                             for k, v in iters[i].next().items()}
            params[i], opt_states[i], _ = update_fns[bundles[i].name](
                params[i], opt_states[i], private_batch, public_batch,
                consensus, jnp.asarray(t))
    return params
