"""FedMD-style baseline (Li & Wang, 2019 [19]) — the paper's Table 2
comparison: *centralized* distillation via consensus logits.

Each round: every client scores the public batch; the server averages the
class scores into a consensus; clients take gradient steps matching the
consensus (digest) and then train on their private data (revisit). Unlike
MHD there is no confidence gating, no aux-head chain, and a central
aggregator is required.

`FedMDTrainer` exposes the runtime surface the `repro.exp` Algorithm
protocol expects — per-step metrics, the shared β_sh/β_priv evaluator,
per-client checkpointing — while `train_fedmd` remains the original
one-call convenience wrapper. Private-batch rng streams come from
`client_stream_seed`, the stream every algorithm shares.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.evaluation import (
    fleet_beta_metrics,
    label_histogram,
    per_label_head_accuracy,
)
from repro.data.pipeline import BatchIterator, PublicPool, client_stream_seed
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


class FedMDTrainer:
    """Stepwise FedMD: heterogeneous clients + a central consensus server."""

    def __init__(
        self,
        bundles: Sequence[ModelBundle],
        optimizer: Optimizer,
        arrays: Dict[str, np.ndarray],
        client_indices: Sequence[np.ndarray],
        public_indices: np.ndarray,
        num_labels: Optional[int] = None,
        batch_size: int = 32,
        public_batch_size: int = 64,
        digest_weight: float = 1.0,
        seed: int = 0,
        eval_batch_size: int = 256,
    ):
        self.bundles = list(bundles)
        self.optimizer = optimizer
        if num_labels is None:
            num_labels = int(arrays["labels"].max()) + 1
        self.num_labels = num_labels
        self.digest_weight = digest_weight
        self.eval_batch_size = eval_batch_size
        K = len(self.bundles)
        key = jax.random.PRNGKey(seed)
        self.params: List[Any] = []
        self.opt_states: List[Any] = []
        for b in self.bundles:
            key, sub = jax.random.split(key)
            p = b.init(sub)
            self.params.append(p)
            self.opt_states.append(optimizer.init(p))
        self.iters = [BatchIterator(arrays, idx, batch_size,
                                    seed=client_stream_seed(seed, i))
                      for i, idx in enumerate(client_indices)]
        self.public = PublicPool(arrays, public_indices, public_batch_size,
                                 seed=seed)
        self.label_hists = [label_histogram(arrays["labels"], idx, num_labels)
                            for idx in client_indices]

        self._score_fns: Dict[str, Any] = {}
        self._update_fns: Dict[str, Any] = {}
        self._apply_fns: Dict[str, Any] = {}  # eval cache: jit once per arch
        for b in self.bundles:
            if b.name in self._score_fns:
                continue
            self._apply_fns[b.name] = jax.jit(b.apply)
            self._score_fns[b.name] = jax.jit(
                lambda p, batch, _b=b: _b.apply(p, batch)["logits"])

            def update(p, s, private_batch, public_batch, consensus, step,
                       _b=b):
                def loss_fn(p_):
                    out_priv = _b.apply(p_, private_batch)
                    lg = out_priv["logits"].astype(jnp.float32)
                    logz = jax.nn.logsumexp(lg, axis=-1)
                    ll = jnp.take_along_axis(
                        lg, private_batch["labels"][:, None], axis=-1)[:, 0]
                    ce = jnp.mean(logz - ll)
                    out_pub = _b.apply(p_, public_batch)
                    logp = jax.nn.log_softmax(
                        out_pub["logits"].astype(jnp.float32), axis=-1)
                    digest = -jnp.mean(jnp.sum(consensus * logp, axis=-1))
                    loss = ce + self.digest_weight * digest
                    return loss, {"ce": ce, "digest": digest}

                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(p)
                p, s = self.optimizer.update(grads, s, p, step)
                metrics["loss"] = loss
                return p, s, metrics

            self._update_fns[b.name] = jax.jit(update)

    @property
    def num_clients(self) -> int:
        return len(self.bundles)

    def step(self, t: int) -> Dict[str, float]:
        """One round: server consensus on the step-t public batch, then one
        digest+revisit gradient step per client."""
        K = self.num_clients
        public_batch = {k: jnp.asarray(v)
                        for k, v in self.public.sample(t).items()}
        probs = [jax.nn.softmax(self._score_fns[self.bundles[i].name](
            self.params[i], public_batch).astype(jnp.float32), -1)
            for i in range(K)]
        consensus = jax.lax.stop_gradient(
            jnp.mean(jnp.stack(probs, 0), axis=0))
        out: Dict[str, float] = {}
        for i in range(K):
            private_batch = {k: jnp.asarray(v)
                             for k, v in self.iters[i].next().items()}
            self.params[i], self.opt_states[i], metrics = \
                self._update_fns[self.bundles[i].name](
                    self.params[i], self.opt_states[i], private_batch,
                    public_batch, consensus, jnp.asarray(t))
            out.update({f"c{i}/{k}": float(v) for k, v in metrics.items()})
        return out

    def evaluate(self, arrays: Dict[str, np.ndarray]) -> Dict[str, float]:
        per_client = []
        for i, b in enumerate(self.bundles):
            per_label, present = per_label_head_accuracy(
                self._apply_fns[b.name], self.params[i], arrays,
                self.num_labels, num_aux_heads=0,
                batch_size=self.eval_batch_size)
            per_client.append((i, per_label, present, self.label_hists[i]))
        return fleet_beta_metrics(per_client, num_aux_heads=0)

    def save(self, directory: str, step: int) -> None:
        from repro.checkpoint.io import save_client_states

        save_client_states(directory, step,
                           zip(self.params, self.opt_states))

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        from repro.checkpoint.io import restore_client_states

        restored, states = restore_client_states(
            directory, zip(self.params, self.opt_states), step)
        self.params = [p for p, _ in states]
        self.opt_states = [s for _, s in states]
        return restored


def train_fedmd(
    bundles: Sequence[ModelBundle],
    optimizer: Optimizer,
    arrays: Dict[str, np.ndarray],
    client_indices: Sequence[np.ndarray],
    public_indices: np.ndarray,
    steps: int,
    batch_size: int,
    public_batch_size: int = 64,
    digest_weight: float = 1.0,
    seed: int = 0,
) -> List[Any]:
    """One-call wrapper: run ``steps`` rounds, return final params."""
    trainer = FedMDTrainer(bundles, optimizer, arrays, client_indices,
                           public_indices,
                           batch_size=batch_size,
                           public_batch_size=public_batch_size,
                           digest_weight=digest_weight, seed=seed)
    for t in range(steps):
        trainer.step(t)
    return trainer.params
