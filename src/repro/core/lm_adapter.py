"""Adapter applying MHD to language-model clients (beyond-paper extension,
DESIGN.md §7.4).

For an LM client the MHD "sample" is a *token position* on the public text
pool: the prediction is the next-token distribution, the embedding ξ_i is the
final hidden state at that position. This adapter reshapes LM bundle outputs
into the (B', C) / (m, B', C) layout that core/mhd.py expects, with
B' = batch · (T−1) next-token positions.

Every assigned architecture works through this adapter (the MHD math never
looks inside the backbone — see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.zoo import ModelBundle


def lm_mhd_outputs(bundle: ModelBundle, params, batch: Dict[str, Any],
                   max_positions: int = 0,
                   position_seed: Optional[int] = None) -> Dict[str, Any]:
    """Run an LM and flatten to MHD client outputs.

    Returns {"embedding": (B', D), "logits": (B', V), "aux_logits": (m, B', V),
             "labels": (B',), "sample_rows": (B',)} where labels are the next
    tokens (used as the private CE target) and sample_rows maps each
    position back to its source sequence (per-domain eval aggregation).

    ``max_positions`` bounds B'. With ``position_seed=None`` the kept
    positions are the batch-head prefix (the historical behavior — a
    *biased* subset: early positions of early sequences only). With a
    seed they are a fixed random subset: ``permutation(PRNGKey(seed),
    B·(T−1))[:max_positions]``, constant-folded under jit and identical
    for every client/teacher sharing the seed — which a fleet must,
    since distillation aligns teachers and students row-by-row.
    """
    from repro.common.sharding import maybe_shard

    out = bundle.apply(params, batch)
    tokens = batch["tokens"]
    hidden = out["hidden"][:, :-1]  # (B, T-1, D)
    logits = out["logits"][:, :-1].astype(jnp.bfloat16)
    labels = tokens[:, 1:]
    B, Tm1, D = hidden.shape
    V = logits.shape[-1]
    # reshapes that merge a sharded batch dim with time lose their sharding
    # (XLA replicates) — re-constrain the flattened position dim
    emb = maybe_shard(hidden.reshape(B * Tm1, D), "batch", "none")
    lg = maybe_shard(logits.reshape(B * Tm1, V), "batch", "model")
    aux = out["aux_heads"]
    aux_flat = None
    if aux is not None:
        aux_flat = maybe_shard(
            aux[:, :, :-1].astype(jnp.bfloat16).reshape(aux.shape[0],
                                                        B * Tm1, V),
            "none", "batch", "model")
    lab = labels.reshape(B * Tm1)
    rows = jnp.repeat(jnp.arange(B, dtype=jnp.int32), Tm1)
    if max_positions and B * Tm1 > max_positions:
        if position_seed is None:
            emb = emb[:max_positions]
            lg = lg[:max_positions]
            lab = lab[:max_positions]
            rows = rows[:max_positions]
            if aux_flat is not None:
                aux_flat = aux_flat[:, :max_positions]
        else:
            keep = jax.random.permutation(
                jax.random.PRNGKey(position_seed),
                B * Tm1)[:max_positions]
            emb = emb[keep]
            lg = lg[keep]
            lab = lab[keep]
            rows = rows[keep]
            if aux_flat is not None:
                aux_flat = aux_flat[:, keep]
    return {"embedding": emb, "logits": lg, "aux_logits": aux_flat,
            "labels": lab, "sample_rows": rows,
            "aux_loss": out["aux_loss"]}


def lm_mhd_loss(bundle: ModelBundle, params, private_batch, public_batch,
                teacher_outs, mhd_cfg, rng=None):
    """Eq. (1) for an LM client: private next-token CE + public distillation."""
    from repro.core.mhd import mhd_total_loss

    priv = lm_mhd_outputs(bundle, params, private_batch)
    pub = lm_mhd_outputs(bundle, params, public_batch)
    loss, metrics = mhd_total_loss(priv, priv["labels"], pub, teacher_outs,
                                   mhd_cfg, rng)
    loss = loss + priv["aux_loss"]  # MoE router aux, if any
    return loss, metrics
