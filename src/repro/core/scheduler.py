"""Dependency-scoreboard fleet scheduler: out-of-order issue over the
trainer's per-client op primitives, with lockstep as the degenerate policy.

The paper's agents communicate over an arbitrary graph with no global
synchronization barrier. Earlier revisions of this module removed the
barrier with a lock-step wall-tick loop: one integer clock, every due
client stepped per tick. That keeps a slow client from *computing* every
tick, but the loop itself is still a barrier — nothing later than tick T
can start until everything at tick T finished, so one paced straggler
stalls clients whose inputs (fresh-enough neighbor mailboxes) are already
sitting in their mailboxes.

This module decomposes each client's progress into explicit *operations*
and dispatches them when their dependencies are satisfied, scoreboard
style (cf. the issue-queue/scoreboard schedulers in hardware: an op
issues when its operands are ready, not when a global clock says so):

  ``LocalStep(c, n)``   client c's n-th local optimization step, at wall
                        tick ``n * rates[c]``. Dispatched with
                        ``step_client(defer=True)`` so device compute
                        overlaps the communication ops that follow.
  ``Publish(c, s)``     encode + publish c's prediction window at its
                        pool boundary ``s`` (every ``rates[c] * S_P``
                        wall ticks).
  ``Pull(c, s)``        draw one in-neighbor (shared rng) and insert its
                        mailbox window into c's pool.
  ``Resolve(c, n)``     block on the deferred step's metrics (the
                        compute/comm overlap join point).
  ``Pump(s)``           the global transport drain at wall tick ``s``
                        (deliver in-flight mail, complete late pulls).

Each op carries a total-order key ``(wall, phase, client)`` with phases
``Publish < Pump < Pull < Resolve < LocalStep`` — exactly the synchronous
loop's operation order. Per client, ops execute in program order (its own
previous op is an implicit dependency); *across* clients the two shipped
policies differ only in what a not-ready op does to the rest of the
fleet:

  lockstep (`AsyncScheduler`)     strict key order, one wall tick per
                                  ``tick()``. A gated op blocks the tick
                                  — the global-barrier policy, bitwise
                                  identical to the previous revision.
  scoreboard (`ScoreboardScheduler`)  the lowest-keyed *ready* op issues;
                                  gated ops are overtaken. A fast client
                                  runs many local steps and pool rounds
                                  while a 4x-paced straggler completes
                                  one.

Dependencies (the gates, scoreboard policy only):

  run-ahead credit   a ``LocalStep`` at wall ``w`` needs
                     ``w <= min(in-neighbor progress) + runahead``.
                     A client that outruns its slowest in-neighbor by
                     more than the window *waits* (backpressure,
                     ``sched/backpressure`` spans) instead of training
                     against ever-staler teachers or dropping mail.
                     ``runahead=None`` = unbounded (no gate).
  pacing             ``pace_s[c]`` seconds minimum between c's local
                     steps (wall-clock heterogeneity: the benchmark's
                     simulated straggler, the gossip child's real one).
                     Under lockstep the slowest due pace bounds every
                     tick — the measured global stall; under scoreboard
                     only the paced client's own ops wait.

Clock model (unchanged)
  ``rates[i] = r`` wall ticks per local step of client i. Public batches
  are indexed by wall tick (`PublicPool` is deterministic in the step);
  a client's optimizer/LR schedule advances with its *local* step count,
  its distillation rng with the wall tick. Pool cadence: every
  ``r * S_P`` wall ticks. The bounded-staleness gate stays in the
  trainer (``RunConfig.max_staleness`` in ``_stack_teachers``): stale
  mail never teaches, a fully-stale client falls back to supervised.

Lockstep equivalence (the bitwise anchor)
  With equal rates, a lossless zero-latency transport, unbounded
  staleness and unbounded run-ahead, key order *is* the synchronous
  loop's operation sequence — same shared-rng draws, same publish /
  deliver / pull order, same LIFO metric resolves. Both policies are
  then *bitwise* equal to ``DecentralizedTrainer.step()``, asserted in
  tests/test_scheduler.py.

Snapshots (`repro.fleet`)
  ``state_dict()`` captures the clocks *and* the per-client issue
  cursors + pump position, so a fleet snapshot taken mid-pool-cadence
  under rate skew resumes bitwise — for either policy.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.runtime import DecentralizedTrainer
from repro.obs import tracer as trace

# "argument not passed" sentinel: freshness_report must distinguish an
# explicit max_staleness=None (unbounded view) from no argument at all
# (fall back to the trainer's configured bound)
_UNSET = object()

# op phase ranks within one wall tick: comm ops at wall s run between the
# local steps of tick s-1 and those of tick s (the synchronous loop's
# publish -> deliver -> pull -> resolve-metrics -> step ordering)
_PH_PUBLISH, _PH_PUMP, _PH_PULL, _PH_RESOLVE, _PH_STEP = range(5)

_OP_NAMES = {_PH_PUBLISH: "publish", _PH_PUMP: "pump", _PH_PULL: "pull",
             _PH_RESOLVE: "resolve", _PH_STEP: "step"}


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Per-client step rates plus the scoreboard policy knobs.

    ``rates[i]``: wall ticks per local step of client i (1 = steps every
    tick; 4 = a 4x slower client). ``runahead``: bounded run-ahead window
    in wall ticks (scoreboard policy; None = unbounded). ``pace_s[i]``:
    minimum real seconds between client i's local steps (None = no
    pacing; lockstep turns the slowest due pace into a global stall,
    scoreboard into a per-client one)."""

    rates: Tuple[int, ...]
    runahead: Optional[int] = None
    pace_s: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if not self.rates:
            raise ValueError("ScheduleConfig needs at least one client")
        if any(int(r) < 1 or int(r) != r for r in self.rates):
            raise ValueError(f"rates must be integers >= 1: {self.rates}")
        if self.runahead is not None and int(self.runahead) < 0:
            raise ValueError(f"runahead must be >= 0: {self.runahead}")
        if self.pace_s is not None:
            if len(self.pace_s) != len(self.rates):
                raise ValueError(
                    f"{len(self.pace_s)} pace entries for "
                    f"{len(self.rates)} rates")
            if any(p < 0 for p in self.pace_s):
                raise ValueError(f"pace_s must be >= 0: {self.pace_s}")

    @classmethod
    def uniform(cls, num_clients: int, rate: int = 1,
                **kw) -> "ScheduleConfig":
        return cls(tuple([rate] * num_clients), **kw)

    @classmethod
    def skewed(cls, num_clients: int, slow_rate: int,
               num_slow: int = 1, **kw) -> "ScheduleConfig":
        """The benchmark's fast/slow split: the last ``num_slow`` clients
        step ``slow_rate``x slower than the rest."""
        fast = num_clients - num_slow
        if fast < 0:
            raise ValueError("num_slow exceeds num_clients")
        return cls(tuple([1] * fast + [slow_rate] * num_slow), **kw)

    @property
    def max_rate(self) -> int:
        return max(self.rates)


class _Cursor:
    """One client's two in-order op streams.

    The *step* stream alternates LocalStep (at ``step_wall``) and Resolve
    (the deferred metrics join, keyed one tick later). The *comm* stream
    walks the client's pool boundaries: Publish then Pull at every
    ``rate * S_P`` wall ticks (Pull only, in the legacy params mode).
    A client's head op is the lower-keyed of the two stream heads, which
    preserves per-client program order while letting clients interleave.
    """

    __slots__ = ("step_wall", "resolving", "comm_wall", "pulling")

    def __init__(self, step_wall: int, comm_wall: int):
        self.step_wall = step_wall  # wall tick of the next LocalStep
        self.resolving = False  # a dispatched step awaits Resolve
        self.comm_wall = comm_wall  # next pool boundary (wall tick)
        self.pulling = False  # boundary's Publish done, Pull pending

    def to_state(self) -> Dict[str, Any]:
        return {"step_wall": int(self.step_wall),
                "resolving": bool(self.resolving),
                "comm_wall": int(self.comm_wall),
                "pulling": bool(self.pulling)}

    @classmethod
    def from_state(cls, d: Dict[str, Any]) -> "_Cursor":
        c = cls(int(d["step_wall"]), int(d["comm_wall"]))
        c.resolving = bool(d.get("resolving", False))
        c.pulling = bool(d.get("pulling", False))
        return c


class Scoreboard:
    """The shared op engine: per-client issue cursors over a
    `DecentralizedTrainer`'s op-granular primitives, a global transport
    pump, and the gate/stat machinery. Subclasses pick the dispatch
    policy (`AsyncScheduler` = lockstep windows, `ScoreboardScheduler` =
    out-of-order issue). The trainer must be freshly constructed (the
    scheduler owns time from wall tick 0; construction-time pool seeding
    is shared with the synchronous path)."""

    mode = "scoreboard"

    def __init__(self, trainer: DecentralizedTrainer,
                 schedule: Optional[ScheduleConfig] = None):
        self.trainer = trainer
        k = len(trainer.clients)
        self.schedule = schedule or ScheduleConfig.uniform(k)
        if len(self.schedule.rates) != k:
            raise ValueError(
                f"{len(self.schedule.rates)} rates for {k} clients")
        self.rates = [int(r) for r in self.schedule.rates]
        self.runahead = self.schedule.runahead
        self.pace_s = list(self.schedule.pace_s or [])
        self.wall = 0
        self.local_steps = [0] * k  # completed local steps per client
        sp = trainer.mhd_cfg.pool_update_every
        self._cadence = [r * sp for r in self.rates]
        self._cursors = [_Cursor(0, self._cadence[i]) for i in range(k)]
        self._pump_wall = 1  # next wall tick the transport pump drains
        self._inflight: Dict[int, Callable[[], Dict[str, float]]] = {}
        self._metrics: Dict[str, float] = {}
        self._public_cache: Tuple[Optional[int], Any] = (None, None)
        self._adj_cache: Tuple[Optional[int], Any] = (None, None)
        self._pace_deadline = [0.0] * k
        self._gate_since: Dict[int, float] = {}
        # perf_counter stamp of each client's latest resolved step — how
        # the skew benchmark reads "when did the fast clients finish"
        # without waiting out the straggler's tail
        self.resolved_at = [0.0] * k
        self.stats = {"issued": 0, "steps": 0, "overtakes": 0,
                      "backpressure_events": 0, "backpressure_s": 0.0,
                      "wait_s": 0.0}
        if trainer.exchange != "params":
            need = self.schedule.max_rate * sp
            if trainer.horizon < need:
                warnings.warn(
                    f"prediction horizon {trainer.horizon} < slowest "
                    f"client's publish gap {need} wall ticks: its windows "
                    f"will expire between publishes and students will fall "
                    f"back to supervised-only for the gap (set "
                    f"CommConfig.horizon >= max_rate * S_P to cover it)",
                    stacklevel=2)

    # -- cadence predicates (kept from the tick-loop API) ------------------

    def due(self, client_id: int, wall: int) -> bool:
        """Does this client take a local step at this wall tick?"""
        return wall % self.rates[client_id] == 0

    def pool_due(self, client_id: int, s: int) -> bool:
        """Is wall tick ``s`` this client's pool-refresh boundary (every
        S_P local steps = rate*S_P wall ticks)?"""
        return s % self._cadence[client_id] == 0

    # -- op heads and keys -------------------------------------------------

    def _active_ids(self) -> List[int]:
        return [c.client_id for c in self.trainer.local]

    def _step_head(self, cid: int) -> Optional[Tuple[int, int, int]]:
        cur = self._cursors[cid]
        if cur.resolving:
            k = len(self.trainer.clients)
            return (cur.step_wall + 1, _PH_RESOLVE, k - cid)
        return (cur.step_wall, _PH_STEP, cid)

    def _comm_head(self, cid: int) -> Tuple[int, int, int]:
        cur = self._cursors[cid]
        if cur.pulling or self.trainer.exchange == "params":
            return (cur.comm_wall, _PH_PULL, cid)
        return (cur.comm_wall, _PH_PUBLISH, cid)

    def _head(self, cid: int,
              step_limit: Optional[int] = None
              ) -> Optional[Tuple[Tuple[int, int, int], int]]:
        """Client cid's program head: ``(key, phase)``. ``step_limit``
        freezes the step stream once the client has completed that many
        local steps (run_until_steps); in-flight resolves and comm ops
        still drain."""
        step = self._step_head(cid)
        if step is not None and step[1] == _PH_STEP and \
                step_limit is not None and \
                self.local_steps[cid] >= step_limit:
            step = None
            # a client at its step limit quiesces: boundaries past its
            # final step stay queued (a live client's comm head likewise
            # never outruns its step stream — program order)
            if self._cursors[cid].comm_wall > self._cursors[cid].step_wall:
                return None
        comm = self._comm_head(cid)
        heads = [h for h in (step, comm) if h is not None]
        if not heads:
            return None
        key = min(heads)
        return key, key[1]

    def _candidates(self, limits: Optional[Sequence[Optional[int]]] = None
                    ) -> List[Tuple[Tuple[int, int, int], int, int]]:
        """All issueable op heads as ``(key, phase, client)``, sorted by
        key: one head per active client plus the transport pump (bounded
        by the furthest client head so it never outruns the fleet)."""
        out = []
        max_wall = 0
        for cid in self._active_ids():
            h = self._head(cid, None if limits is None else limits[cid])
            if h is None:
                continue
            key, phase = h
            max_wall = max(max_wall, key[0])
            out.append((key, phase, cid))
        if self.trainer.exchange != "params" and out and \
                self._pump_wall <= max_wall:
            out.append(((self._pump_wall, _PH_PUMP, -1), _PH_PUMP, -1))
        out.sort()
        return out

    # -- gates -------------------------------------------------------------

    def _gate(self, phase: int, cid: int, wall: int) -> Optional[str]:
        """Why this op cannot issue yet, or None if ready. Only
        ``LocalStep`` ops carry cross-client dependencies; everything
        else is ready the moment it is the client's program head."""
        if phase != _PH_STEP:
            return None
        if self.runahead is not None:
            nbrs = self._adj(wall)[cid]
            active = set(self._active_ids())
            progress = [self._cursors[j].step_wall
                        for j in nbrs if j in active and j != cid]
            if progress and wall > min(progress) + self.runahead:
                return "runahead"
        if self.pace_s and self.pace_s[cid] > 0 and \
                time.perf_counter() < self._pace_deadline[cid]:
            return "pace"
        return None

    def _pace_wait(self, cid: int) -> None:
        """Lockstep policy: a paced op blocks the window — sleep out the
        remaining pace (the global stall the scoreboard policy removes)."""
        delay = self._pace_deadline[cid] - time.perf_counter()
        if delay > 0:
            t0 = trace.now()
            time.sleep(delay)
            self.stats["wait_s"] += delay
            trace.complete("sched/wait", t0, client=cid, reason="pace")

    # -- op execution ------------------------------------------------------

    def _public_batch(self, wall: int):
        cached_wall, batch = self._public_cache
        if cached_wall != wall:
            public_np = self.trainer.public.sample(wall)
            batch = {k: jnp.asarray(v) for k, v in public_np.items()}
            self._public_cache = (wall, batch)
        return batch

    def _adj(self, wall: int):
        cached_wall, adj = self._adj_cache
        if cached_wall != wall:
            adj = self.trainer.graph_fn(wall)
            self._adj_cache = (wall, adj)
        return adj

    def _exec(self, phase: int, cid: int, wall: int,
              limits: Optional[Sequence[Optional[int]]] = None) -> None:
        """Issue one op. The caller has checked gates and program order;
        this is pure execution + cursor advance."""
        tr = self.trainer
        self.stats["issued"] += 1
        if cid in self._gate_since:
            t0 = self._gate_since.pop(cid)
            waited = trace.now() - t0
            self.stats["backpressure_events"] += 1
            self.stats["backpressure_s"] += waited
            trace.complete("sched/backpressure", t0, client=cid,
                           wall=wall, op=_OP_NAMES[phase])
        if phase == _PH_STEP:
            c = tr.clients[cid]
            resolve = tr.step_client(
                c, self._public_batch(wall), wall,
                opt_step=self.local_steps[cid], defer=True)
            self.local_steps[cid] += 1
            self.stats["steps"] += 1
            self._inflight[cid] = resolve
            self._cursors[cid].resolving = True
            if self.pace_s and self.pace_s[cid] > 0:
                self._pace_deadline[cid] = \
                    time.perf_counter() + self.pace_s[cid]
            trace.instant("sched/issue", op="step", client=cid, wall=wall)
        elif phase == _PH_RESOLVE:
            resolve = self._inflight.pop(cid, None)
            if resolve is not None:
                m = resolve()
                m[f"c{cid}/local_step"] = float(self.local_steps[cid])
                self._metrics.update(m)
            cur = self._cursors[cid]
            cur.resolving = False
            cur.step_wall += self.rates[cid]
            self.resolved_at[cid] = time.perf_counter()
        elif phase == _PH_PUBLISH:
            self._exec_publish(wall, limits)
        elif phase == _PH_PULL:
            adj = self._adj(wall)
            tr.pull_client(cid, wall, adj)
            trace.instant("sched/issue", op="pull", client=cid, wall=wall)
            cur = self._cursors[cid]
            cur.pulling = False
            cur.comm_wall += self._cadence[cid]
        elif phase == _PH_PUMP:
            tr.comm_pump(wall)
            self._pump_wall = wall + 1

    def _exec_publish(self, wall: int,
                      limits: Optional[Sequence[Optional[int]]] = None
                      ) -> None:
        """Issue every active publish head at this wall tick as one
        grouped call (the window encode shares the public batches — and
        in the degenerate case this is exactly the synchronous round's
        single ``_publish_clients`` call)."""
        ids = [cid for cid in self._active_ids()
               if self._head(cid, None if limits is None else limits[cid])
               == ((wall, _PH_PUBLISH, cid), _PH_PUBLISH)]
        trace.instant("sched/pool_round", wall=wall, clients=ids)
        self.trainer.publish_clients(ids, wall)
        for cid in ids:
            self._cursors[cid].pulling = True

    # -- dispatch ----------------------------------------------------------

    def _issue_lockstep_window(self) -> None:
        """Strict key order through one wall tick: every op with key
        below ``(wall+1, STEP)`` issues; a paced op stalls the window
        (the lockstep barrier)."""
        limit = (self.wall + 1, _PH_STEP, -(1 << 30))
        while True:
            cands = self._candidates()
            if not cands or cands[0][0] >= limit:
                return
            key, phase, cid = cands[0]
            # pacing is the only gate the barrier honors: in strict key
            # order the run-ahead credit can never bind (no client gets
            # ahead of the window), so it is vacuously satisfied
            if self._gate(phase, cid, key[0]) == "pace":
                self._pace_wait(cid)
            self._exec(phase, cid, key[0])

    def _issue_one(self, limits: Optional[Sequence[Optional[int]]] = None
                   ) -> bool:
        """Scoreboard policy: issue the lowest-keyed *ready* op, letting
        ready ops overtake gated ones. When every candidate is gated,
        sleep until the earliest pace deadline (``sched/wait``); pure
        run-ahead stalls with no pace pending mean no op can ever become
        ready without external progress — return False."""
        while True:
            cands = self._candidates(limits)
            if not cands:
                return False
            best_gated = None
            for i, (key, phase, cid) in enumerate(cands):
                reason = self._gate(phase, cid, key[0])
                if reason is None:
                    if i > 0:
                        self.stats["overtakes"] += 1
                    self._exec(phase, cid, key[0], limits)
                    return True
                if cid >= 0 and cid not in self._gate_since and \
                        reason == "runahead":
                    self._gate_since[cid] = trace.now()
                if reason == "pace" and (
                        best_gated is None or self._pace_deadline[cid] <
                        self._pace_deadline[best_gated]):
                    best_gated = cid
            if best_gated is None:
                return False  # all run-ahead gated: stalled
            self._pace_wait(best_gated)

    def quiesce(self) -> None:
        """Join every in-flight deferred step so the scheduler is at a
        clean issue boundary (the state `state_dict` snapshots). Only
        ops that precede a pending Resolve in some client's program
        order execute — comm rounds not yet due stay queued in the
        cursors, which the snapshot captures."""
        while any(cur.resolving for cur in self._cursors):
            heads = []
            for cid in self._active_ids():
                if self._cursors[cid].resolving:
                    h = self._head(cid)
                    if h is not None:
                        heads.append((h[0], h[1], cid))
            if not heads:
                # a resolving client left the fleet: drop its join
                for cid, cur in enumerate(self._cursors):
                    if cur.resolving and cid not in self._active_ids():
                        self._inflight.pop(cid, None)
                        cur.resolving = False
                        cur.step_wall += self.rates[cid]
                continue
            heads.sort()
            key, phase, cid = heads[0]
            if self.trainer.exchange != "params" and (
                    self._pump_wall < key[0] or
                    (self._pump_wall == key[0] and phase > _PH_PUMP)):
                self._exec(_PH_PUMP, -1, self._pump_wall)
                continue
            self._exec(phase, cid, key[0])

    def _pop_metrics(self) -> Dict[str, float]:
        m = self._metrics
        self._metrics = {}
        return m

    # -- snapshot/restore (repro.fleet) ------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        """The scheduler's clocks and issue cursors: wall tick, per-client
        local step counts, each client's step/comm stream positions and
        the transport pump — what a fleet snapshot needs to resume the
        loop bitwise mid-pool-cadence (`repro.fleet.snapshot`). Must be
        taken at an issue boundary (no in-flight deferred steps):
        ``quiesce()`` first if driving out of order."""
        if self._inflight:
            raise RuntimeError(
                f"state_dict with {len(self._inflight)} unresolved "
                "deferred steps; call quiesce() first")
        return {"wall": int(self.wall),
                "local_steps": [int(s) for s in self.local_steps],
                "mode": self.mode,
                "pump_wall": int(self._pump_wall),
                "cursors": [c.to_state() for c in self._cursors]}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.wall = int(state["wall"])
        steps = [int(s) for s in state["local_steps"]]
        if len(steps) != len(self.local_steps):
            raise ValueError(
                f"{len(steps)} local_steps for "
                f"{len(self.local_steps)} clients")
        self.local_steps = steps
        if "cursors" in state:
            self._cursors = [_Cursor.from_state(d)
                             for d in state["cursors"]]
            self._pump_wall = int(state["pump_wall"])
        else:
            # legacy clock-only snapshot: reconstruct the cursors from
            # the wall/step counts (exact for churn-free runs — a
            # client's n-th step sits at n*rate, its next boundary at
            # the first cadence multiple past the wall)
            for cid, cur in enumerate(self._cursors):
                cur.step_wall = steps[cid] * self.rates[cid]
                cur.resolving = False
                cad = self._cadence[cid]
                cur.comm_wall = ((self.wall // cad) + 1) * cad
                cur.pulling = False
            self._pump_wall = self.wall + 1
        self._inflight = {}
        self._gate_since = {}
        self._public_cache = (None, None)
        self._adj_cache = (None, None)

    # -- telemetry ---------------------------------------------------------

    def freshness_report(self, max_staleness: Any = _UNSET
                         ) -> Dict[int, Dict[str, float]]:
        """Per-client view of mailbox freshness against each client's own
        clock (prediction modes only): total mailbox size, how much of it
        passes the staleness bound, and the bus-clock reading.

        ``max_staleness`` defaults to the trainer's configured
        ``run_cfg.max_staleness``; passing ``None`` explicitly requests
        the *unbounded* view (the whole mailbox counts as fresh) rather
        than falling back to the configured bound."""
        tr = self.trainer
        if tr.exchange == "params":
            return {}
        ms = tr.run_cfg.max_staleness if max_staleness is _UNSET \
            else max_staleness
        out: Dict[int, Dict[str, float]] = {}
        for c in tr.local:
            cid = c.client_id
            box = tr.bus.mailbox(cid)
            fresh = tr.bus.poll_fresh(cid, ms)
            out[cid] = {
                "clock": float(tr.bus.clock(cid)),
                "mailbox": float(len(box)),
                "fresh": float(len(fresh)),
                "local_steps": float(self.local_steps[cid]),
            }
        return out

    # -- driving loop (shared) ---------------------------------------------

    def run(self, wall_ticks: int,
            eval_arrays: Optional[Dict[str, np.ndarray]] = None,
            eval_every: int = 0,
            log_every: int = 0) -> List[Tuple[int, Dict[str, float]]]:
        """Run ``wall_ticks`` ticks; optionally evaluate every
        ``eval_every`` ticks. Returns the (tick, eval-metrics) history."""
        history: List[Tuple[int, Dict[str, float]]] = []
        for _ in range(wall_ticks):
            metrics = self.tick()
            t = self.wall - 1
            if log_every and t % log_every == 0 and metrics:
                losses = [v for k, v in metrics.items()
                          if k.endswith("/loss")]
                print(f"tick {t}: mean stepped-client loss "
                      f"{float(np.mean(losses)):.4f}")
            if eval_arrays is not None and eval_every and \
                    (t + 1) % eval_every == 0:
                history.append((t + 1, self.trainer.evaluate(eval_arrays)))
        return history

    def tick(self) -> Dict[str, float]:
        raise NotImplementedError


class AsyncScheduler(Scoreboard):
    """The lockstep policy: `tick()` advances the wall clock by one tick,
    issuing every op in strict key order — step every due client (in
    client-id order, against the tick's shared public batch), then the
    communication phase, then the LIFO metric resolves. With pacing
    configured, the slowest due client's pace bounds the whole tick (the
    global stall the scoreboard policy removes). Returns the due
    clients' step metrics."""

    mode = "lockstep"

    def tick(self) -> Dict[str, float]:
        wall = self.wall
        n_due = sum(1 for c in self.trainer.local
                    if self.due(c.client_id, wall))
        with trace.span("sched/tick", wall=wall, due=n_due):
            self._issue_lockstep_window()
        self.wall = wall + 1
        trace.counter("sched/wall", self.wall)
        return self._pop_metrics()


class ScoreboardScheduler(Scoreboard):
    """The out-of-order policy: ready ops issue the moment their
    dependencies (program order, run-ahead credit, pace) are satisfied,
    overtaking gated ones. ``tick()`` keeps the wall-tick driving surface
    (one tick's worth of progress per call, for `Experiment.run` parity);
    ``run_until_steps`` is the free-running driver the benchmark and the
    straggler demos use."""

    mode = "scoreboard"

    def tick(self) -> Dict[str, float]:
        """Advance one wall tick: issue ready ops until every active
        client's step stream has moved past the current tick. Identical
        to the lockstep window when nothing is gated; under gates, ops of
        *later* ticks may issue early rather than stall the fleet."""
        wall = self.wall
        with trace.span("sched/tick", wall=wall, mode="scoreboard"):
            while any(self._cursors[cid].step_wall <= wall
                      or self._cursors[cid].resolving
                      for cid in self._active_ids()):
                if not self._issue_one():
                    break  # fully stalled on run-ahead credit
        self.wall = wall + 1
        trace.counter("sched/wall", self.wall)
        return self._pop_metrics()

    def run_until_steps(self, targets: Sequence[int],
                        max_ops: int = 1 << 22
                        ) -> List[Tuple[int, Dict[str, float]]]:
        """Free-run until every active client has completed its target
        local step count (a frozen client still resolves and
        communicates, but issues no further steps). Stops early when
        every remaining op is run-ahead gated — the bounded window's
        backpressure, observable in ``stats``. Returns per-issue metric
        snapshots for the ticks that produced any."""
        limits = [int(t) for t in targets]
        if len(limits) != len(self.local_steps):
            raise ValueError(
                f"{len(limits)} targets for "
                f"{len(self.local_steps)} clients")
        history: List[Tuple[int, Dict[str, float]]] = []
        ops = 0
        while any(self.local_steps[cid] < limits[cid]
                  for cid in self._active_ids()):
            if not self._issue_one(limits):
                break
            ops += 1
            if ops >= max_ops:
                break
            if self._metrics:
                history.append((ops, self._pop_metrics()))
        self.quiesce()
        if self._metrics:
            history.append((ops, self._pop_metrics()))
        self.wall = max((c.step_wall for c in self._cursors),
                        default=self.wall)
        return history


def run_async(trainer: DecentralizedTrainer, wall_ticks: int,
              rates: Optional[Sequence[int]] = None,
              **run_kw) -> AsyncScheduler:
    """Convenience: wrap a trainer in a lockstep scheduler and run it."""
    sched = AsyncScheduler(
        trainer,
        ScheduleConfig(tuple(int(r) for r in rates)) if rates else None)
    sched.run(wall_ticks, **run_kw)
    return sched


class GossipPacer:
    """The scoreboard policy for a one-client-per-process gossip fleet
    (`launch/gossip.py`): the child's training loop *is* its LocalStep
    stream, so the scheduler reduces to the two gates — wall-clock
    pacing (replacing the launcher's post-step throttle sleep) and the
    run-ahead credit against the freshest inbound mail per in-neighbor.
    A child that outruns its slowest in-neighbor by more than
    ``runahead`` local steps waits, pumping the transport while it does
    (backpressure instead of racing ahead against ever-staler teachers);
    ``escape_s`` caps any single wait so a dead peer degrades to the
    staleness gate rather than a hang."""

    def __init__(self, trainer: DecentralizedTrainer, client_id: int,
                 runahead: Optional[int] = None, pace_s: float = 0.0,
                 escape_s: float = 20.0):
        self.trainer = trainer
        self.client_id = int(client_id)
        self.runahead = None if runahead is None else int(runahead)
        self.pace_s = float(pace_s)
        self.escape_s = float(escape_s)
        self._deadline = 0.0
        self.stats = {"backpressure_events": 0, "backpressure_s": 0.0,
                      "pace_s": 0.0, "escapes": 0}

    def _neighbor_progress(self, t: int) -> Optional[int]:
        """The slowest in-neighbor's freshest published step, from this
        rank's mailbox (no mail yet = position 0)."""
        nbrs = self.trainer.graph_fn(t)[self.client_id]
        if not nbrs:
            return None
        box = self.trainer.bus.mailbox(self.client_id)
        positions = []
        for j in nbrs:
            mail = box.get(j)
            positions.append(0 if mail is None else int(mail.sent_step))
        return min(positions)

    def gate(self, t: int) -> None:
        """Block until step ``t`` may issue: pace first, then run-ahead
        credit, draining the transport while waiting."""
        if self.pace_s > 0:
            delay = self._deadline - time.perf_counter()
            if delay > 0:
                t0 = trace.now()
                time.sleep(delay)
                self.stats["pace_s"] += delay
                trace.complete("sched/wait", t0, client=self.client_id,
                               reason="pace", step=t)
            self._deadline = time.perf_counter() + self.pace_s
        if self.runahead is None:
            return
        progress = self._neighbor_progress(t)
        if progress is None or t <= progress + self.runahead:
            return
        t0 = trace.now()  # 0.0 when tracing is off — span bookkeeping only
        w0 = time.perf_counter()
        deadline = time.monotonic() + self.escape_s
        while t > (progress or 0) + self.runahead:
            if time.monotonic() >= deadline:
                self.stats["escapes"] += 1
                break
            self.trainer.bus.deliver(t)
            time.sleep(0.002)
            progress = self._neighbor_progress(t)
        self.stats["backpressure_events"] += 1
        self.stats["backpressure_s"] += time.perf_counter() - w0
        trace.complete("sched/backpressure", t0, client=self.client_id,
                       step=t, op="step")

    # -- snapshot/restore (repro.fleet) ------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        return {"gossip_pacer": True, "client_id": self.client_id,
                "stats": {k: float(v) for k, v in self.stats.items()}}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        for k, v in state.get("stats", {}).items():
            if k in self.stats:
                self.stats[k] = type(self.stats[k])(v)
