"""Async decentralized scheduler: per-client logical clocks over a wall
clock, with bounded-staleness distillation.

The paper's agents communicate over an arbitrary graph with no global
synchronization barrier, but `DecentralizedTrainer.step` steps every
client in lockstep. This module removes the barrier while keeping the
trainer's per-client primitives intact:

Clock model
  One integer *wall clock* advances in ticks (real time). Client i has a
  step-rate ``rates[i] = r`` (wall ticks per local step, r ≥ 1): it takes
  its n-th local step at wall tick n·r — a 1× client steps every tick, a
  4× client every fourth. All communication quantities (transport latency
  and bandwidth, mail timestamps, window horizons, ``max_staleness``) are
  measured in wall ticks, so a fixed-latency link costs a fast client
  more local steps of staleness than a slow one.

  Public batches are indexed by wall tick (`PublicPool` is deterministic
  in the step), so co-stepping clients still score the same samples —
  the paper's setup — while a slow client simply participates in fewer
  of them. A client's optimizer/LR schedule advances with its *local*
  step count, its distillation rng with the wall tick.

Pool cadence
  The synchronous trainer refreshes pools every S_P global steps; here
  every client publishes its prediction window and pulls one neighbor
  entry every S_P *local* steps, i.e. every ``r·S_P`` wall ticks. Between
  rounds, in-flight mail is drained every tick.

Staleness
  The bounded-staleness gate lives in the trainer
  (``RunConfig.max_staleness``, enforced per-teacher at assembly time in
  ``_stack_teachers``): mail or params older than the bound never teach;
  a fully-stale client falls back to a supervised-only step rather than
  crash or block. The bus's per-client clocks (``bus.advance`` /
  ``bus.poll_fresh``) expose the same freshness view to telemetry.

Lockstep equivalence
  With equal rates, a lossless zero-latency transport, and
  ``max_staleness=None``, every tick executes exactly the synchronous
  loop's operation sequence (same shared-rng draws, same publish/deliver/
  pull order) — ``AsyncScheduler.tick()`` is then *bitwise* equal to
  ``DecentralizedTrainer.step()``, which tests/test_scheduler.py asserts.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.runtime import DecentralizedTrainer
from repro.obs import tracer as trace

# "argument not passed" sentinel: freshness_report must distinguish an
# explicit max_staleness=None (unbounded view) from no argument at all
# (fall back to the trainer's configured bound)
_UNSET = object()


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Per-client step rates: ``rates[i]`` wall ticks per local step of
    client i (1 = steps every tick; 4 = a 4× slower client)."""

    rates: Tuple[int, ...]

    def __post_init__(self):
        if not self.rates:
            raise ValueError("ScheduleConfig needs at least one client")
        if any(int(r) < 1 or int(r) != r for r in self.rates):
            raise ValueError(f"rates must be integers >= 1: {self.rates}")

    @classmethod
    def uniform(cls, num_clients: int, rate: int = 1) -> "ScheduleConfig":
        return cls(tuple([rate] * num_clients))

    @classmethod
    def skewed(cls, num_clients: int, slow_rate: int,
               num_slow: int = 1) -> "ScheduleConfig":
        """The benchmark's fast/slow split: the last ``num_slow`` clients
        step ``slow_rate``× slower than the rest."""
        fast = num_clients - num_slow
        if fast < 0:
            raise ValueError("num_slow exceeds num_clients")
        return cls(tuple([1] * fast + [slow_rate] * num_slow))

    @property
    def max_rate(self) -> int:
        return max(self.rates)


class AsyncScheduler:
    """Drives a `DecentralizedTrainer` tick by tick with per-client
    clocks. The trainer must be freshly constructed (the scheduler owns
    time from wall tick 0; construction-time pool seeding is shared with
    the synchronous path)."""

    def __init__(self, trainer: DecentralizedTrainer,
                 schedule: Optional[ScheduleConfig] = None):
        self.trainer = trainer
        k = len(trainer.clients)
        self.schedule = schedule or ScheduleConfig.uniform(k)
        if len(self.schedule.rates) != k:
            raise ValueError(
                f"{len(self.schedule.rates)} rates for {k} clients")
        self.rates = [int(r) for r in self.schedule.rates]
        self.wall = 0
        self.local_steps = [0] * k  # completed local steps per client
        if trainer.exchange != "params":
            need = self.schedule.max_rate * \
                trainer.mhd_cfg.pool_update_every
            if trainer.horizon < need:
                warnings.warn(
                    f"prediction horizon {trainer.horizon} < slowest "
                    f"client's publish gap {need} wall ticks: its windows "
                    f"will expire between publishes and students will fall "
                    f"back to supervised-only for the gap (set "
                    f"CommConfig.horizon >= max_rate * S_P to cover it)",
                    stacklevel=2)

    # -- cadence predicates ------------------------------------------------

    def due(self, client_id: int, wall: int) -> bool:
        """Does this client take a local step at this wall tick?"""
        return wall % self.rates[client_id] == 0

    def pool_due(self, client_id: int, s: int) -> bool:
        """Is wall tick ``s`` this client's pool-refresh boundary (every
        S_P local steps = rate·S_P wall ticks)?"""
        cadence = self.rates[client_id] * \
            self.trainer.mhd_cfg.pool_update_every
        return s % cadence == 0

    # -- one wall tick -----------------------------------------------------

    def tick(self) -> Dict[str, float]:
        """Advance the wall clock by one tick: step every due client (in
        client-id order, against the tick's shared public batch), then run
        the communication phase. Returns the due clients' step metrics."""
        tr = self.trainer
        wall = self.wall
        due = [c for c in tr.local if self.due(c.client_id, wall)]
        metrics: Dict[str, float] = {}
        with trace.span("sched/tick", wall=wall, due=len(due)):
            # dispatch every due client's update first (defer=True), run
            # the communication phase while the device computes, then
            # block on the metrics — LIFO so retro-emitted spans nest
            pending = []
            if due:
                public_np = tr.public.sample(wall)
                public_batch = {k: jnp.asarray(v)
                                for k, v in public_np.items()}
                for c in due:
                    cid = c.client_id
                    resolve = tr.step_client(
                        c, public_batch, wall,
                        opt_step=self.local_steps[cid], defer=True)
                    self.local_steps[cid] += 1
                    pending.append((cid, resolve))
            self._comm_phase(wall + 1)
            for cid, resolve in reversed(pending):
                m = resolve()
                m[f"c{cid}/local_step"] = float(self.local_steps[cid])
                metrics.update(m)
        self.wall = wall + 1
        trace.counter("sched/wall", self.wall)
        return metrics

    def _comm_phase(self, s: int) -> None:
        """Mirror of the synchronous `_maybe_update_pools(s)`, restricted
        to the clients whose own pool cadence fires at wall tick ``s``."""
        tr = self.trainer
        pool_due = [c for c in tr.local if self.pool_due(c.client_id, s)]
        if not pool_due:
            tr._comm_tick(s)
            return
        trace.instant("sched/pool_round", wall=s,
                      clients=[c.client_id for c in pool_due])
        if tr.exchange != "params":
            tr._publish_clients([c.client_id for c in pool_due], s)
            tr.bus.deliver(s)  # unconditional: latency mail flows every tick
            tr._resolve_pending(s)
        adj = tr.graph_fn(s)
        for c in pool_due:
            tr._pull_client(c, s, adj)

    # -- snapshot/restore (repro.fleet) ------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        """The scheduler's clocks: the wall tick and every client's local
        step count — what a fleet snapshot needs to resume the async loop
        bitwise (`repro.fleet.snapshot`)."""
        return {"wall": int(self.wall),
                "local_steps": [int(s) for s in self.local_steps]}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.wall = int(state["wall"])
        steps = [int(s) for s in state["local_steps"]]
        if len(steps) != len(self.local_steps):
            raise ValueError(
                f"{len(steps)} local_steps for "
                f"{len(self.local_steps)} clients")
        self.local_steps = steps

    # -- driving loops -----------------------------------------------------

    def run(self, wall_ticks: int,
            eval_arrays: Optional[Dict[str, np.ndarray]] = None,
            eval_every: int = 0,
            log_every: int = 0) -> List[Tuple[int, Dict[str, float]]]:
        """Run ``wall_ticks`` ticks; optionally evaluate every
        ``eval_every`` ticks. Returns the (tick, eval-metrics) history."""
        history: List[Tuple[int, Dict[str, float]]] = []
        for _ in range(wall_ticks):
            metrics = self.tick()
            t = self.wall - 1
            if log_every and t % log_every == 0 and metrics:
                losses = [v for k, v in metrics.items()
                          if k.endswith("/loss")]
                print(f"tick {t}: mean stepped-client loss "
                      f"{float(np.mean(losses)):.4f}")
            if eval_arrays is not None and eval_every and \
                    (t + 1) % eval_every == 0:
                history.append((t + 1, self.trainer.evaluate(eval_arrays)))
        return history

    # -- telemetry ---------------------------------------------------------

    def freshness_report(self, max_staleness: Any = _UNSET
                         ) -> Dict[int, Dict[str, float]]:
        """Per-client view of mailbox freshness against each client's own
        clock (prediction modes only): total mailbox size, how much of it
        passes the staleness bound, and the bus-clock reading.

        ``max_staleness`` defaults to the trainer's configured
        ``run_cfg.max_staleness``; passing ``None`` explicitly requests
        the *unbounded* view (the whole mailbox counts as fresh) rather
        than falling back to the configured bound."""
        tr = self.trainer
        if tr.exchange == "params":
            return {}
        ms = tr.run_cfg.max_staleness if max_staleness is _UNSET \
            else max_staleness
        out: Dict[int, Dict[str, float]] = {}
        for c in tr.local:
            cid = c.client_id
            box = tr.bus.mailbox(cid)
            fresh = tr.bus.poll_fresh(cid, ms)
            out[cid] = {
                "clock": float(tr.bus.clock(cid)),
                "mailbox": float(len(box)),
                "fresh": float(len(fresh)),
                "local_steps": float(self.local_steps[cid]),
            }
        return out


def run_async(trainer: DecentralizedTrainer, wall_ticks: int,
              rates: Optional[Sequence[int]] = None,
              **run_kw) -> AsyncScheduler:
    """Convenience: wrap a trainer in a scheduler and run it."""
    sched = AsyncScheduler(
        trainer,
        ScheduleConfig(tuple(int(r) for r in rates)) if rates else None)
    sched.run(wall_ticks, **run_kw)
    return sched
