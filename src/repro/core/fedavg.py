"""FedAvg baseline (McMahan et al., 2017) — the paper's Table 1 comparison.

K clients with *identical* architectures train locally; every ``u`` steps
parameters are averaged (weight aggregation). In the multi-pod deployment the
average is a pmean over the client axis; here (single host) it is an exact
leafwise mean — the math the paper compares against (FA, u=200 / u=1000).

`FedAvgTrainer` exposes the runtime surface the `repro.exp` Algorithm
protocol expects (per-step metrics, shared β_sh/β_priv evaluator,
checkpointing); `train_fedavg` remains the one-call wrapper. Private
batches come from the `client_stream_seed` streams shared by every
algorithm.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_mean
from repro.core.evaluation import (
    fleet_beta_metrics,
    label_histogram,
    per_label_head_accuracy,
)
from repro.core.supervised import make_train_step
from repro.data.pipeline import BatchIterator, client_stream_seed
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


class FedAvgTrainer:
    """Stepwise FedAvg: local SGD + periodic exact parameter averaging."""

    def __init__(
        self,
        bundle: ModelBundle,
        optimizer: Optimizer,
        arrays: Dict[str, np.ndarray],
        client_indices: Sequence[np.ndarray],
        num_labels: Optional[int] = None,
        batch_size: int = 32,
        average_every: int = 200,  # the paper's u
        seed: int = 0,
        eval_batch_size: int = 256,
    ):
        self.bundle = bundle
        self.optimizer = optimizer
        if num_labels is None:
            num_labels = int(arrays["labels"].max()) + 1
        self.num_labels = num_labels
        self.average_every = average_every
        self.eval_batch_size = eval_batch_size
        K = len(client_indices)
        params = bundle.init(jax.random.PRNGKey(seed))  # common init
        self.client_params: List[Any] = [params for _ in range(K)]
        self.opt_states: List[Any] = [optimizer.init(params)
                                      for _ in range(K)]
        self.iters = [BatchIterator(arrays, idx, batch_size,
                                    seed=client_stream_seed(seed, i))
                      for i, idx in enumerate(client_indices)]
        self.label_hists = [label_histogram(arrays["labels"], idx, num_labels)
                            for idx in client_indices]
        self._train_step = make_train_step(bundle, optimizer)
        self._apply_fn = jax.jit(bundle.apply)  # eval cache: jit once

    @property
    def num_clients(self) -> int:
        return len(self.client_params)

    @property
    def averaged_params(self) -> Any:
        """The current global model (exact leafwise mean)."""
        return tree_mean(self.client_params)

    def step(self, t: int) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for i in range(self.num_clients):
            batch = {k: jnp.asarray(v)
                     for k, v in self.iters[i].next().items()}
            self.client_params[i], self.opt_states[i], metrics = \
                self._train_step(self.client_params[i], self.opt_states[i],
                                 batch, jnp.asarray(t))
            out.update({f"c{i}/{k}": float(v) for k, v in metrics.items()})
        if (t + 1) % self.average_every == 0:
            avg = self.averaged_params
            self.client_params = [avg for _ in range(self.num_clients)]
            # momentum is client-local state; FedAvg resets it on aggregation
            self.opt_states = [self.optimizer.init(avg)
                               for _ in range(self.num_clients)]
            out["fedavg/averaged"] = 1.0
        return out

    def evaluate(self, arrays: Dict[str, np.ndarray]) -> Dict[str, float]:
        """Evaluate the *global* (averaged) model; per-client rows weight
        its per-label accuracy by each client's private histogram."""
        per_label, present = per_label_head_accuracy(
            self._apply_fn, self.averaged_params, arrays,
            self.num_labels, num_aux_heads=0,
            batch_size=self.eval_batch_size)
        per_client = [(i, per_label, present, self.label_hists[i])
                      for i in range(self.num_clients)]
        return fleet_beta_metrics(per_client, num_aux_heads=0)

    def save(self, directory: str, step: int) -> None:
        from repro.checkpoint.io import save_client_states

        save_client_states(directory, step,
                           zip(self.client_params, self.opt_states))

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        from repro.checkpoint.io import restore_client_states

        restored, states = restore_client_states(
            directory, zip(self.client_params, self.opt_states), step)
        self.client_params = [p for p, _ in states]
        self.opt_states = [s for _, s in states]
        return restored


def train_fedavg(
    bundle: ModelBundle,
    optimizer: Optimizer,
    arrays: Dict[str, np.ndarray],
    client_indices: Sequence[np.ndarray],
    steps: int,
    batch_size: int,
    average_every: int = 200,
    seed: int = 0,
) -> Any:
    """One-call wrapper: run ``steps`` rounds, return the averaged params."""
    trainer = FedAvgTrainer(bundle, optimizer, arrays, client_indices,
                            batch_size=batch_size,
                            average_every=average_every, seed=seed)
    for t in range(steps):
        trainer.step(t)
    return trainer.averaged_params
