"""FedAvg baseline (McMahan et al., 2017) — the paper's Table 1 comparison.

K clients with *identical* architectures train locally; every ``u`` steps
parameters are averaged (weight aggregation). In the multi-pod deployment the
average is a pmean over the client axis; here (single host) it is an exact
leafwise mean — the math the paper compares against (FA, u=200 / u=1000).
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_mean
from repro.core.supervised import make_train_step
from repro.data.pipeline import BatchIterator
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


def train_fedavg(
    bundle: ModelBundle,
    optimizer: Optimizer,
    arrays: Dict[str, np.ndarray],
    client_indices: Sequence[np.ndarray],
    steps: int,
    batch_size: int,
    average_every: int = 200,  # the paper's u
    seed: int = 0,
) -> Any:
    """Returns the final averaged parameters."""
    K = len(client_indices)
    key = jax.random.PRNGKey(seed)
    params = bundle.init(key)  # common init, as in FedAvg
    client_params = [params for _ in range(K)]
    opt_states = [optimizer.init(params) for _ in range(K)]
    iters = [BatchIterator(arrays, idx, batch_size, seed=seed + 7 * i)
             for i, idx in enumerate(client_indices)]
    train_step = make_train_step(bundle, optimizer)

    for t in range(steps):
        for i in range(K):
            batch = {k: jnp.asarray(v) for k, v in iters[i].next().items()}
            client_params[i], opt_states[i], _ = train_step(
                client_params[i], opt_states[i], batch, jnp.asarray(t))
        if (t + 1) % average_every == 0:
            avg = tree_mean(client_params)
            client_params = [avg for _ in range(K)]
            # momentum is client-local state; FedAvg resets it on aggregation
            opt_states = [optimizer.init(avg) for _ in range(K)]
    return tree_mean(client_params)
