"""Multi-Headed Distillation — the paper's core technique (§3.2, Eqs. 1-5).

Pure-JAX loss functions. All teacher quantities are stop-gradiented; the
student optimizes

    L_i = L_CE(private) + ν_emb · Σ_j ρ(||ψ̂_i − φ̂_j||)          (Eq. 2)
        + ν_aux · Σ_k L_dist[aux_k ← gated source at level k−1]   (Eqs. 4, 5)

Head levels: level 0 is the main head; aux head k (1-indexed) distills from
level k−1 sources — the teachers' and (optionally) its own client's — with
the *most confident* candidate selected per sample (Λ = max softmax prob,
Q = one-hot argmax, Eq. 4). Variants reproduced from the paper:
  * ``confidence="random"``  — ablation: random target choice (§4.2.2)
  * ``use_same_level`` (SL)  — add level-k teacher heads (App. B.1, Fig. 9)
  * ``use_self`` (SF)        — add the distilled head itself; if it wins, the
                               sample is skipped (App. B.1)
  * ``skip_when_student_confident`` — the single-head "ignore poor targets"
                               rule (§4.2.2)

Shapes: ``B`` below is a generic example axis — image batch for CNN clients,
flattened (batch·positions) for LM clients (adapter in core/lm_adapter.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MHDConfig:
    nu_emb: float = 1.0
    nu_aux: float = 3.0
    num_aux_heads: int = 4
    delta: int = 1  # Δ distillation targets per step
    confidence: str = "max"  # "max" | "entropy" | "margin" | "random"
    use_self: bool = False  # SF
    use_same_level: bool = False  # SL
    skip_when_student_confident: bool = False  # §4.2.2 single-head variant
    # runtime (paper §4.1)
    pool_size: int = 8  # N_P
    pool_update_every: int = 200  # S_P
    label_smooth_teacher: float = 0.0


def normalized(x, eps: float = 1e-8):
    """ψ^norm of §3.2 — embedding-norm drift protection."""
    n = jnp.linalg.norm(x.astype(jnp.float32), axis=-1, keepdims=True)
    return x.astype(jnp.float32) / (n + eps)


def embedding_distillation_loss(student_emb, teacher_embs, nu_emb: float):
    """Eq. (2) with ρ(x) = x² on normalized embeddings.

    student_emb: (B, E); teacher_embs: (Δ, B, E) — already stop-gradiented.
    """
    if nu_emb == 0.0:
        return jnp.zeros((), jnp.float32)
    s = normalized(student_emb)
    t = normalized(teacher_embs)
    d = jnp.sum(jnp.square(s[None] - t), axis=-1)  # (Δ, B)
    return nu_emb * jnp.mean(jnp.sum(d, axis=0))


def _confidence(logits, measure: str = "max"):
    """Λ(h) — the paper uses max softmax prob (§3.2) and explicitly flags
    its unreliability for out-of-distribution samples (App. A.2). Beyond-
    paper alternatives (benchmarked in confidence_ablation):
      * "entropy": negative predictive entropy (calibration-friendlier)
      * "margin":  top-1 − top-2 probability gap
    All return "higher = more confident" scores comparable across heads.
    """
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if measure == "max":
        return jnp.max(p, axis=-1)
    if measure == "entropy":
        return jnp.sum(p * jnp.log(p + 1e-20), axis=-1)  # = −H, higher better
    if measure == "margin":
        v2 = jax.lax.top_k(p, 2)[0]
        return v2[..., 0] - v2[..., 1]
    raise ValueError(measure)


def _xent_to_target(student_logits, target_probs):
    """−Σ target · log softmax(student); per-sample (B,)."""
    logp = jax.nn.log_softmax(student_logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(target_probs * logp, axis=-1)


def _head_at_level(outs: Dict[str, Any], level: int):
    """Level 0 = main head; level k≥1 = aux head k. outs values: (..., B, C)."""
    if level == 0:
        return outs["logits"]
    return outs["aux_logits"][level - 1]


def multi_head_distillation_loss(
    student_out: Dict[str, Any],
    teacher_outs: Dict[str, Any],
    cfg: MHDConfig,
    rng: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Eqs. (4)+(5): the chained, confidence-gated aux-head loss.

    student_out: {"embedding": (B,E), "logits": (B,C), "aux_logits": (m,B,C)}
    teacher_outs: same but with a leading Δ axis (stacked sampled teachers),
                  already stop-gradiented.
    Returns (loss, metrics).
    """
    m = cfg.num_aux_heads
    assert student_out["aux_logits"].shape[0] == m
    teachers_main = teacher_outs["logits"]  # (Δ, B, C)
    total = jnp.zeros((), jnp.float32)
    metrics: Dict[str, jnp.ndarray] = {}

    for k in range(1, m + 1):
        student_head = student_out["aux_logits"][k - 1]  # (B, C)

        # candidate sources at level k-1 (teachers ∪ self, Eq. 4)
        if k == 1:
            teacher_src = teachers_main
            self_src = student_out["logits"][None]
        else:
            teacher_src = teacher_outs["aux_logits"][:, k - 2]
            self_src = student_out["aux_logits"][k - 2][None]
        candidates = [teacher_src, self_src]
        if cfg.use_same_level:  # SL: teachers' level-k heads
            candidates.append(teacher_outs["aux_logits"][:, k - 1])
        n_before_self = sum(c.shape[0] for c in candidates)
        if cfg.use_self:  # SF: the distilled head itself
            candidates.append(jax.lax.stop_gradient(student_head)[None])
        cand = jnp.concatenate(candidates, axis=0)  # (n_cand, B, C)
        cand = jax.lax.stop_gradient(cand)

        if cfg.confidence == "random":
            assert rng is not None, "random confidence needs rng"
            rng, sub = jax.random.split(rng)
            conf = _confidence(cand)  # still reported in metrics paths
            winner = jax.random.randint(sub, conf.shape[1:], 0, cand.shape[0])
        else:
            conf = _confidence(cand, cfg.confidence)  # (n_cand, B)
            winner = jnp.argmax(conf, axis=0)  # (B,)

        sel = jnp.take_along_axis(
            cand, winner[None, :, None], axis=0)[0]  # (B, C)
        target = jax.nn.softmax(sel.astype(jnp.float32), axis=-1)
        if cfg.label_smooth_teacher:
            C = target.shape[-1]
            target = (1 - cfg.label_smooth_teacher) * target + \
                cfg.label_smooth_teacher / C

        per_sample = _xent_to_target(student_head, target)  # (B,)

        keep = jnp.ones_like(per_sample)
        if cfg.use_self:  # SF: skip samples where the head itself won
            keep = keep * (winner < n_before_self).astype(jnp.float32)
        if cfg.skip_when_student_confident:
            measure = cfg.confidence if cfg.confidence != "random" else "max"
            own = _confidence(jax.lax.stop_gradient(student_head), measure)
            win_conf = jnp.take_along_axis(conf, winner[None], axis=0)[0]
            keep = keep * (own <= win_conf).astype(jnp.float32)

        loss_k = jnp.sum(per_sample * keep) / jnp.maximum(jnp.sum(keep), 1.0)
        total = total + loss_k
        metrics[f"aux{k}_dist_loss"] = loss_k
        metrics[f"aux{k}_keep_frac"] = jnp.mean(keep)
        metrics[f"aux{k}_teacher_frac"] = jnp.mean(
            (winner < teacher_src.shape[0]).astype(jnp.float32))

    return cfg.nu_aux * total, metrics


def mhd_total_loss(
    student_out_private: Dict[str, Any],
    private_labels: jnp.ndarray,
    student_out_public: Dict[str, Any],
    teacher_outs_public: Dict[str, Any],
    cfg: MHDConfig,
    rng: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """The full client objective, Eq. (1)."""
    logits = student_out_private["logits"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, private_labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - ll)

    # teachers may arrive without embeddings (a wire format that ships
    # predictions only — repro.comm emb_encoding="none"): Eq. 2 drops out
    teacher_emb = teacher_outs_public.get("embedding")
    if teacher_emb is None:
        emb = jnp.zeros((), jnp.float32)
    else:
        emb = embedding_distillation_loss(
            student_out_public["embedding"],
            jax.lax.stop_gradient(teacher_emb),
            cfg.nu_emb)
    aux, metrics = multi_head_distillation_loss(
        student_out_public, teacher_outs_public, cfg, rng)

    loss = ce + emb + aux
    metrics.update({"ce": ce, "emb_dist": emb, "aux_dist_total": aux})
    return loss, metrics
