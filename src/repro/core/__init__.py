"""The paper's primary contribution: Multi-Headed Distillation for
decentralized learning, plus its baselines (FedAvg, FedMD, supervised)."""
from repro.core.mhd import (
    MHDConfig,
    embedding_distillation_loss,
    multi_head_distillation_loss,
    mhd_total_loss,
    normalized,
)
from repro.core.graph import (
    complete_graph,
    cycle_graph,
    chain_graph,
    islands_graph,
    isolated_graph,
    graph_distance_matrix,
)
from repro.core.runtime import DecentralizedTrainer, RunConfig
from repro.core.scheduler import AsyncScheduler, ScheduleConfig, run_async

__all__ = [
    "MHDConfig",
    "embedding_distillation_loss",
    "multi_head_distillation_loss",
    "mhd_total_loss",
    "normalized",
    "complete_graph",
    "cycle_graph",
    "chain_graph",
    "islands_graph",
    "isolated_graph",
    "graph_distance_matrix",
    "DecentralizedTrainer",
    "RunConfig",
    "AsyncScheduler",
    "ScheduleConfig",
    "run_async",
]
