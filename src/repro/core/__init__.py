"""The paper's primary contribution: Multi-Headed Distillation for
decentralized learning, plus its baselines (FedAvg, FedMD, supervised)."""
from repro.core.mhd import (
    MHDConfig,
    embedding_distillation_loss,
    multi_head_distillation_loss,
    mhd_total_loss,
    normalized,
)
from repro.core.graph import (
    complete_graph,
    cycle_graph,
    chain_graph,
    islands_graph,
    isolated_graph,
    graph_distance_matrix,
)
from repro.core.runtime import DecentralizedTrainer, RunConfig
from repro.core.scheduler import (
    AsyncScheduler,
    GossipPacer,
    ScheduleConfig,
    Scoreboard,
    ScoreboardScheduler,
    run_async,
)
from repro.core.evaluation import (
    fleet_beta_metrics,
    label_histogram,
    per_label_head_accuracy,
)
from repro.core.fedavg import FedAvgTrainer, train_fedavg
from repro.core.fedmd import FedMDTrainer, train_fedmd
from repro.core.supervised import SupervisedTrainer, train_supervised

__all__ = [
    "MHDConfig",
    "embedding_distillation_loss",
    "multi_head_distillation_loss",
    "mhd_total_loss",
    "normalized",
    "complete_graph",
    "cycle_graph",
    "chain_graph",
    "islands_graph",
    "isolated_graph",
    "graph_distance_matrix",
    "DecentralizedTrainer",
    "RunConfig",
    "AsyncScheduler",
    "GossipPacer",
    "ScheduleConfig",
    "Scoreboard",
    "ScoreboardScheduler",
    "run_async",
    "fleet_beta_metrics",
    "label_histogram",
    "per_label_head_accuracy",
    "FedAvgTrainer",
    "train_fedavg",
    "FedMDTrainer",
    "train_fedmd",
    "SupervisedTrainer",
    "train_supervised",
]
