"""Shared per-label evaluator (β_priv / β_sh, paper §4.2.1).

One evaluation path for every algorithm: per-label accuracy of each head
on a uniform test set, reduced to

  * ``beta_sh``   — uniform mean over the labels present in the test set,
  * ``beta_priv`` — mean weighted by the client's private label histogram,

under the unified metric namespace ``c{i}/{head}/beta_*`` plus the
ensemble means ``mean/{head}/beta_*`` (what the paper's figures report).
`DecentralizedTrainer.evaluate` delegates here, and the FedMD / FedAvg /
supervised baselines report through the same functions — so Table 1/2
comparisons read the *same* metric computed the same way.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def label_histogram(labels: np.ndarray, indices: np.ndarray,
                    num_labels: int) -> np.ndarray:
    """A client's normalized private-label distribution (for β_priv)."""
    hist = np.bincount(labels[indices], minlength=num_labels).astype(np.float64)
    return hist / max(hist.sum(), 1.0)


def per_label_head_accuracy(
    apply_fn: Callable[[Any, Dict[str, Any]], Dict[str, Any]],
    params: Any,
    arrays: Dict[str, np.ndarray],
    num_labels: int,
    num_aux_heads: int = 0,
    batch_size: int = 256,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-label accuracy of the main head and each aux head.

    Returns ``(per_label, present)``: per_label has shape
    ``(num_aux_heads + 1, num_labels)`` (row 0 = main head), present is the
    bool mask of labels that occur in the test set.
    """
    labels = arrays["labels"]
    correct = np.zeros((num_aux_heads + 1, num_labels))
    count = np.zeros(num_labels)
    for s in range(0, labels.shape[0], batch_size):
        batch = {k: jnp.asarray(v[s:s + batch_size])
                 for k, v in arrays.items() if k != "labels"}
        o = apply_fn(params, batch)
        lab = labels[s:s + batch_size]
        if "labels" in o:
            # positions-as-samples outputs (repro.lm): the prediction
            # target is the model-carried next token; the aggregation
            # bucket stays the data's label (domain), looked up through
            # the position → sequence map
            targets = np.asarray(o["labels"])
            lab = lab[np.asarray(o["sample_rows"])]
        else:
            targets = lab
        preds = [np.asarray(jnp.argmax(o["logits"], -1))]
        for h in range(num_aux_heads):
            preds.append(np.asarray(jnp.argmax(o["aux_logits"][h], -1)))
        np.add.at(count, lab, 1)
        for hi, p in enumerate(preds):
            np.add.at(correct[hi], lab[p == targets], 1)
    per_label = correct / np.maximum(count, 1)[None]
    return per_label, count > 0


def head_names(num_aux_heads: int) -> List[str]:
    return ["main"] + [f"aux{h + 1}" for h in range(num_aux_heads)]


def fleet_beta_metrics(
    per_client: Sequence[Tuple[int, np.ndarray, np.ndarray, np.ndarray]],
    num_aux_heads: int = 0,
) -> Dict[str, float]:
    """Reduce per-client per-label accuracies to the unified namespace.

    ``per_client`` entries are ``(client_id, per_label, present,
    label_hist)`` as produced by `per_label_head_accuracy` +
    `label_histogram`.
    """
    out: Dict[str, float] = {}
    names = head_names(num_aux_heads)
    ids = []
    for cid, per_label, present, hist in per_client:
        ids.append(cid)
        w_priv = hist * present
        w_priv = w_priv / max(w_priv.sum(), 1e-9)
        for hi, nm in enumerate(names):
            out[f"c{cid}/{nm}/beta_sh"] = float(per_label[hi][present].mean())
            out[f"c{cid}/{nm}/beta_priv"] = float(
                (per_label[hi] * w_priv).sum())
    for nm in names:
        for metric in ("beta_sh", "beta_priv"):
            vals = [out[f"c{cid}/{nm}/{metric}"] for cid in ids]
            out[f"mean/{nm}/{metric}"] = float(np.mean(vals))
    return out
