"""Normalized embedding-distillation loss Pallas TPU kernel (Eq. 2).

Fuses both L2 normalizations and the squared distance in one VMEM pass per
row block — the jnp path materializes two normalized (B, E) tensors in HBM.
Embeddings fit a single block along E (E ≤ 8192 for every assigned arch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _emb_dist_kernel(s_ref, t_ref, o_ref, *, eps: float):
    s = s_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    sn = s / (jnp.sqrt(jnp.sum(s * s, axis=-1, keepdims=True)) + eps)
    tn = t / (jnp.sqrt(jnp.sum(t * t, axis=-1, keepdims=True)) + eps)
    d = sn - tn
    o_ref[...] = jnp.sum(d * d, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def emb_dist(student_emb, teacher_emb, *, block_rows: int = 256,
             eps: float = 1e-8, interpret: bool = False):
    """(B, E) × (B, E) -> per-row squared normalized distance (B,)."""
    B, E = student_emb.shape
    rows = min(block_rows, B)
    pad = (-B) % rows
    if pad:
        # pad rows with ones: harmless (outputs sliced off), avoids 0/0
        student_emb = jnp.pad(student_emb, ((0, pad), (0, 0)),
                              constant_values=1)
        teacher_emb = jnp.pad(teacher_emb, ((0, pad), (0, 0)),
                              constant_values=1)
    Bp = B + pad
    out = pl.pallas_call(
        functools.partial(_emb_dist_kernel, eps=eps),
        grid=(Bp // rows,),
        in_specs=[pl.BlockSpec((rows, E), lambda i: (i, 0)),
                  pl.BlockSpec((rows, E), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        interpret=interpret,
    )(student_emb, teacher_emb)
    return out[:B]
