"""Pure-jnp oracles for every Pallas kernel.

These are the *definitions of correctness*: kernel tests sweep shapes/dtypes
and assert_allclose against these functions. They are also the CPU execution
path of ops.py (the kernels are TPU-targeted; interpret=True validates the
kernel bodies themselves on CPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# distillation cross-entropy (the MHD hot spot for 262k vocabs)
# ---------------------------------------------------------------------------

def dist_ce_ref(student_logits, teacher_logits):
    """Per-row distillation CE + confidences.

    student_logits, teacher_logits: (B, V) float.
    Returns (ce (B,), teacher_conf (B,), student_conf (B,)):
        ce_b     = -Σ_v softmax(t)_v · log softmax(s)_v
        *_conf_b = max_v softmax(·)_v      (Λ of Eq. 4)
    """
    t = teacher_logits.astype(jnp.float32)
    s = student_logits.astype(jnp.float32)
    p_t = jax.nn.softmax(t, axis=-1)
    logp_s = jax.nn.log_softmax(s, axis=-1)
    ce = -jnp.sum(p_t * logp_s, axis=-1)
    t_conf = jnp.max(p_t, axis=-1)
    s_conf = jnp.max(jax.nn.softmax(s, axis=-1), axis=-1)
    return ce, t_conf, s_conf


# ---------------------------------------------------------------------------
# flash attention (causal / sliding window, GQA)
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, T, H, d); k, v: (B, S, KV, d); GQA via head grouping.

    window > 0 restricts key j to (i - window, i] (sliding window attention).
    Returns (B, T, H, d).
    """
    B, T, H, d = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, d)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, T, H, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan
# ---------------------------------------------------------------------------

def ssd_scan_ref(x, dt, A, B, C, D):
    """Sequential SSD recurrence (same math as models/ssm.ssd_reference).

    x: (Bt, T, H, P); dt: (Bt, T, H); A: (H,); B, C: (Bt, T, N); D: (H,).
    Returns (y (Bt, T, H, P), final_state (Bt, H, P, N)).
    """
    from repro.models.ssm import ssd_reference

    return ssd_reference(x, dt, A, B, C, D)


# ---------------------------------------------------------------------------
# top-k wire-format packing (MHD exchange)
# ---------------------------------------------------------------------------

def topk_wire_ref(logits, k: int = 32):
    """(B, V) -> (vals (B,k) f32, idx (B,k) i32, lse (B,) f32)."""
    x = logits.astype(jnp.float32)
    vals, idx = jax.lax.top_k(x, k)
    lse = jax.nn.logsumexp(x, axis=-1)
    return vals, idx.astype(jnp.int32), lse


# ---------------------------------------------------------------------------
# normalized embedding distillation (Eq. 2)
# ---------------------------------------------------------------------------

def emb_dist_ref(student_emb, teacher_emb, eps: float = 1e-8):
    """Per-row squared distance of L2-normalized embeddings. (B, E) -> (B,)."""
    s = student_emb.astype(jnp.float32)
    t = teacher_emb.astype(jnp.float32)
    s = s / (jnp.linalg.norm(s, axis=-1, keepdims=True) + eps)
    t = t / (jnp.linalg.norm(t, axis=-1, keepdims=True) + eps)
    return jnp.sum(jnp.square(s - t), axis=-1)
