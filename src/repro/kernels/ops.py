"""Jit'd dispatch wrappers over the Pallas kernels.

``use_pallas`` resolution:
  * explicit argument wins;
  * else kernels are used when the default backend is TPU (compile target),
    and the pure-jnp reference path is used on CPU (tests / experiments).
Set ``REPRO_FORCE_PALLAS_INTERPRET=1`` to exercise the kernel bodies on CPU
via interpret mode (slow; the kernel test-suite does this per-kernel).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.dist_ce import dist_ce as _dist_ce_kernel
from repro.kernels.emb_dist import emb_dist as _emb_dist_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def _default_use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"):
        return True
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _interpret() -> bool:
    return bool(os.environ.get("REPRO_FORCE_PALLAS_INTERPRET")) or \
        jax.default_backend() != "tpu"


def dist_ce(student_logits, teacher_logits, use_pallas: bool | None = None):
    """Fused distillation CE + confidences. Returns (ce, t_conf, s_conf)."""
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _dist_ce_kernel(student_logits, teacher_logits,
                               interpret=_interpret())
    return REF.dist_ce_ref(student_logits, teacher_logits)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool | None = None):
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _flash_kernel(q, k, v, causal=causal, window=window,
                             interpret=_interpret())
    return REF.flash_attention_ref(q, k, v, causal=causal, window=window)


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128,
             use_pallas: bool | None = None):
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _ssd_kernel(x, dt, A, B, C, D, chunk=chunk,
                           interpret=_interpret())
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dt, A, B, C, D, chunk_size=chunk)


def topk_wire(logits, k: int = 32, use_pallas: bool | None = None):
    """MHD exchange wire format: (top-k vals, idx, logsumexp)."""
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        from repro.kernels.topk_wire import topk_wire as _kernel

        return _kernel(logits, k, interpret=_interpret())
    return REF.topk_wire_ref(logits, k)


@functools.partial(
    jax.jit,
    static_argnames=("k", "val_dtype", "idx_dtype", "emb_int8", "use",
                     "interpret"))
def _topk_wire_frame_jit(heads, emb, d127, *, k: int, val_dtype, idx_dtype,
                         emb_int8: bool, use: bool, interpret: bool):
    W, H, B, C = heads.shape
    flat = heads.astype(jnp.float32).reshape(W * H * B, C)
    if use:
        from repro.kernels.topk_wire import topk_wire as _kernel

        vals, idx, lse = _kernel(flat, k, interpret=interpret)
    else:
        vals, idx, lse = REF.topk_wire_ref(flat, k)
    wire_vals = vals.reshape(W, H, B, k).astype(val_dtype)
    arrays = {
        "vals": wire_vals,
        "idx": idx.reshape(W, H, B, k).astype(idx_dtype),
        "lse": lse.reshape(W, H, B).astype(jnp.float32),
    }
    # finiteness of the inputs AND the wire cast (a finite f32 logit
    # beyond ±65504 overflows to inf in f16) — the host raises
    # NonFiniteError when this flag comes back false
    finite = jnp.all(jnp.isfinite(heads)) & \
        jnp.all(jnp.isfinite(wire_vals.astype(jnp.float32)))
    if emb is not None:
        emb32 = emb.astype(jnp.float32)
        finite = finite & jnp.all(jnp.isfinite(emb32))
        if emb_int8:
            # bit-for-bit twin of wire.quantize_emb_int8: np.rint and
            # jnp.round both round half-to-even, and dividing by the
            # *traced* d127 (not the literal 127.0) forces XLA to emit a
            # true IEEE division — a constant divisor gets rewritten to
            # multiply-by-reciprocal, 1 ulp off numpy's quotient
            amax = jnp.max(jnp.abs(emb32), axis=-1)
            scale = (amax / d127 + 1e-30).astype(jnp.float32)
            arrays["emb_q"] = jnp.clip(
                jnp.round(emb32 / scale[..., None]),
                -127, 127).astype(jnp.int8)
            arrays["emb_scale"] = scale
        else:
            arrays["embedding"] = emb32
    return arrays, finite


def topk_wire_frame(heads, emb, k: int, *, val_dtype: str = "float16",
                    idx_dtype: str = "uint16", emb_encoding: str = "int8",
                    use_pallas: bool | None = None):
    """Fused wire-frame encode: one jitted graph from stacked head logits
    (W, H, B, C) straight to wire-dtype arrays — top-k select, f16 value
    cast, u16/u32 index narrowing, f32 logsumexp, int8 embedding
    quantization and the codec's finiteness checks all on device. Returns
    (arrays, finite_flag); only the small wire-dtype arrays ever cross to
    the host, replacing the dense f32 round-trip through the python
    serializer hop. ``emb=None`` skips the embedding lane."""
    use = _default_use_pallas() if use_pallas is None else use_pallas
    return _topk_wire_frame_jit(
        heads, emb, jnp.float32(127.0), k=k,
        val_dtype=jnp.float16 if val_dtype == "float16" else jnp.float32,
        idx_dtype=jnp.uint16 if idx_dtype == "uint16" else jnp.uint32,
        emb_int8=(emb_encoding == "int8"), use=use,
        interpret=_interpret())


@functools.partial(
    jax.jit,
    static_argnames=("k", "k_min", "budget_bytes_per_token", "entry_bytes",
                     "val_dtype", "idx_dtype", "emb_int8", "use",
                     "interpret"))
def _adaptive_topk_wire_frame_jit(heads, emb, d127, *, k: int, k_min: int,
                                  budget_bytes_per_token: int,
                                  entry_bytes: int, val_dtype, idx_dtype,
                                  emb_int8: bool, use: bool,
                                  interpret: bool):
    W, H, B, C = heads.shape
    flat = heads.astype(jnp.float32).reshape(W * H * B, C)
    if use:
        from repro.kernels.topk_wire import topk_wire as _kernel

        vals, idx, lse = _kernel(flat, k, interpret=interpret)
    else:
        vals, idx, lse = REF.topk_wire_ref(flat, k)
    wire_vals = vals.reshape(W, H, B, k).astype(val_dtype)
    lse3 = lse.reshape(W, H, B).astype(jnp.float32)

    # per-token entropy of the *main* head's distribution: the signal the
    # byte budget is spent against. H(p) = lse - sum(softmax(x) * x), all
    # f32 — both codec paths run this same jitted graph, so the
    # allocation is bitwise-shared by construction.
    main = heads[:, 0].astype(jnp.float32)  # (W, B, C)
    xs = main - lse3[:, 0][..., None]
    ent = -jnp.sum(jnp.exp(xs) * xs, axis=-1)  # (W, B), nats

    # integer budget: total retained (val, idx) entries across the window,
    # shared across a token's H heads. Static python arithmetic — the
    # budget is a compile-time constant of the frame shape.
    N = W * B
    K_total = (budget_bytes_per_token * N) // (H * entry_bytes)
    R = max(K_total - N * k_min, 0)
    ent_flat = jnp.clip(ent.reshape(N), 0.0, None)
    if R == 0:
        # budget exhausted (or exactly the floor): every token still gets
        # k_min — never less than the top-1 prediction
        k_tok = jnp.full((N,), k_min, jnp.int32)
    else:
        s = jnp.sum(ent_flat)
        w = jnp.where(s > 0, ent_flat, jnp.ones_like(ent_flat))
        sw = jnp.where(s > 0, s, jnp.float32(N))
        quota_f = jnp.float32(R) * w / sw
        quota = jnp.floor(quota_f).astype(jnp.int32)
        # leftover entries go one-each to the largest fractional parts
        # (stable argsort: ties break by token order, deterministically)
        rem = jnp.maximum(jnp.int32(R) - jnp.sum(quota), 0)
        order = jnp.argsort(-(quota_f - jnp.floor(quota_f)))
        rank = jnp.zeros((N,), jnp.int32).at[order].set(
            jnp.arange(N, dtype=jnp.int32))
        bonus = (rank < rem).astype(jnp.int32)
        # clip to [k_min, k]: surplus beyond k is left unspent, so
        # sum(k_tok) <= K_total holds by construction
        k_tok = jnp.clip(k_min + quota + bonus, k_min, k)
    arrays = {
        "vals": wire_vals,
        "idx": idx.reshape(W, H, B, k).astype(idx_dtype),
        "lse": lse3,
        "k_per_token": k_tok.reshape(W, B).astype(jnp.uint16),
    }
    # finiteness of the inputs AND the wire cast, over the full k-rectangle
    # (entries beyond a token's k_tok never travel, but they are the same
    # logits — a non-finite teacher is rejected wholesale, like the fixed
    # codecs)
    finite = jnp.all(jnp.isfinite(heads)) & \
        jnp.all(jnp.isfinite(wire_vals.astype(jnp.float32)))
    if emb is not None:
        emb32 = emb.astype(jnp.float32)
        finite = finite & jnp.all(jnp.isfinite(emb32))
        if emb_int8:
            amax = jnp.max(jnp.abs(emb32), axis=-1)
            scale = (amax / d127 + 1e-30).astype(jnp.float32)
            arrays["emb_q"] = jnp.clip(
                jnp.round(emb32 / scale[..., None]),
                -127, 127).astype(jnp.int8)
            arrays["emb_scale"] = scale
        else:
            arrays["embedding"] = emb32
    return arrays, finite


def adaptive_topk_wire_frame(heads, emb, k: int, *, k_min: int = 1,
                             budget_bytes_per_token: int = 0,
                             entry_bytes: int = 6,
                             val_dtype: str = "float16",
                             idx_dtype: str = "uint16",
                             emb_encoding: str = "int8",
                             use_pallas: bool | None = None):
    """Entropy-adaptive wire-frame encode (`repro.lm.adaptive_wire`).

    One jitted graph from stacked head logits (W, H, B, C) to a
    *rectangular* top-k frame at the codec's k ceiling plus the per-token
    retention plan: top-k select (the same `topk_wire` kernel as the
    fixed codec), main-head entropy, and the integer byte-budget
    allocation ``k_per_token`` (W, B) — how many of the k entries each
    token actually puts on the wire, entropy-weighted under
    ``budget_bytes_per_token`` with a ``k_min`` floor. The host-side
    ragged gather that drops the unspent tail is plain numpy shared by
    the codec's numpy and device paths, so both are byte-identical by
    construction. Returns (arrays, finite_flag)."""
    use = _default_use_pallas() if use_pallas is None else use_pallas
    return _adaptive_topk_wire_frame_jit(
        heads, emb, jnp.float32(127.0), k=k, k_min=k_min,
        budget_bytes_per_token=budget_bytes_per_token,
        entry_bytes=entry_bytes,
        val_dtype=jnp.float16 if val_dtype == "float16" else jnp.float32,
        idx_dtype=jnp.uint16 if idx_dtype == "uint16" else jnp.uint32,
        emb_int8=(emb_encoding == "int8"), use=use,
        interpret=_interpret())


def emb_dist(student_emb, teacher_emb, use_pallas: bool | None = None):
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _emb_dist_kernel(student_emb, teacher_emb,
                                interpret=_interpret())
    return REF.emb_dist_ref(student_emb, teacher_emb)
