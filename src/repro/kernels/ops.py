"""Jit'd dispatch wrappers over the Pallas kernels.

``use_pallas`` resolution:
  * explicit argument wins;
  * else kernels are used when the default backend is TPU (compile target),
    and the pure-jnp reference path is used on CPU (tests / experiments).
Set ``REPRO_FORCE_PALLAS_INTERPRET=1`` to exercise the kernel bodies on CPU
via interpret mode (slow; the kernel test-suite does this per-kernel).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.dist_ce import dist_ce as _dist_ce_kernel
from repro.kernels.emb_dist import emb_dist as _emb_dist_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def _default_use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"):
        return True
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _interpret() -> bool:
    return bool(os.environ.get("REPRO_FORCE_PALLAS_INTERPRET")) or \
        jax.default_backend() != "tpu"


def dist_ce(student_logits, teacher_logits, use_pallas: bool | None = None):
    """Fused distillation CE + confidences. Returns (ce, t_conf, s_conf)."""
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _dist_ce_kernel(student_logits, teacher_logits,
                               interpret=_interpret())
    return REF.dist_ce_ref(student_logits, teacher_logits)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool | None = None):
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _flash_kernel(q, k, v, causal=causal, window=window,
                             interpret=_interpret())
    return REF.flash_attention_ref(q, k, v, causal=causal, window=window)


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128,
             use_pallas: bool | None = None):
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _ssd_kernel(x, dt, A, B, C, D, chunk=chunk,
                           interpret=_interpret())
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dt, A, B, C, D, chunk_size=chunk)


def topk_wire(logits, k: int = 32, use_pallas: bool | None = None):
    """MHD exchange wire format: (top-k vals, idx, logsumexp)."""
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        from repro.kernels.topk_wire import topk_wire as _kernel

        return _kernel(logits, k, interpret=_interpret())
    return REF.topk_wire_ref(logits, k)


def emb_dist(student_emb, teacher_emb, use_pallas: bool | None = None):
    use = _default_use_pallas() if use_pallas is None else use_pallas
    if use:
        return _emb_dist_kernel(student_emb, teacher_emb,
                                interpret=_interpret())
    return REF.emb_dist_ref(student_emb, teacher_emb)
