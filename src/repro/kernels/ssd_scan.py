"""Mamba2 SSD chunked-scan Pallas TPU kernel.

One (batch, head) stream is processed chunk-by-chunk; the inter-chunk SSM
state (P × N) lives in VMEM scratch and is carried across the sequential
innermost grid dimension (TPU grids execute in order — the canonical Pallas
recurrence pattern). Intra-chunk interactions are dense (L × L) matmuls on
the MXU; default L=128, so per-(b,h) working set is
x(L·P) + B,C(L·N) + M(L·L) + state(P·N) ≈ 200 KB fp32 — comfortably VMEM.

Validated in interpret mode against the sequential-scan oracle
(kernels/ref.ssd_scan_ref); models/ssm.ssd_chunked is the jnp twin used on
the CPU execution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
                h_ref, *, L: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, 0, 0, :, 0]  # (L,)
    A = a_ref[0]  # scalar
    B = b_ref[0, 0].astype(jnp.float32)  # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)  # (L, N)
    D = d_ref[0]

    a = dt * A  # (L,) log-decay increments (A < 0)
    s = jnp.cumsum(a)
    total = s[-1]

    # intra-chunk (dual / quadratic form)
    seg = s[:, None] - s[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    gate = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
    CB = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    M = CB * gate * dt[None, :]
    y = jnp.dot(M, x, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    h = h_ref[...]  # (P, N)
    y += jnp.dot(C * jnp.exp(s)[:, None], h.T,
                 preferred_element_type=jnp.float32)

    # state update: h' = exp(total)·h + Σ_u exp(total - s_u)·dt_u·x_u B_uᵀ
    w = jnp.exp(total - s) * dt  # (L,)
    G = jnp.dot(x.T, B * w[:, None], preferred_element_type=jnp.float32)
    h_ref[...] = h * jnp.exp(total) + G

    y_ref[0, 0, 0] = (y + x * D).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        state_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, interpret: bool = False):
    """x: (Bt, T, H, P); dt: (Bt, T, H); A, D: (H,); B, C: (Bt, T, N).

    Returns (y (Bt, T, H, P), final_state (Bt, H, P, N)). T must be a
    multiple of ``chunk``.
    """
    Bt, T, H, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0, f"T={T} not divisible by chunk={chunk}"
    nc = T // chunk

    xr = x.reshape(Bt, nc, chunk, H, P).transpose(0, 3, 1, 2, 4)  # (Bt,H,nc,L,P)
    dtr = dt.reshape(Bt, nc, chunk, H).transpose(0, 3, 1, 2)[..., None]
    Br = B.reshape(Bt, nc, chunk, N)
    Cr = C.reshape(Bt, nc, chunk, N)

    grid = (Bt, H, nc)
    kernel = functools.partial(_ssd_kernel, L=chunk, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, A, Br, Cr, D)
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bt, T, H, P)
    return y, state
