"""Top-k wire-format packing Pallas TPU kernel (§Perf Pair C).

Packing teacher predictions into (top-k values, indices, logsumexp) is the
MHD exchange wire format. XLA's `lax.top_k` lowers to a full-vocab variadic
sort whose batch dims the SPMD partitioner refuses to shard (measured:
~990 GB of replicated sort buffers at MHD batch sizes — EXPERIMENTS.md
§Perf C1/C2). The jnp fallback is k argmax+mask rounds; this kernel fuses
those rounds in VMEM: one HBM read of the logits row-block, k VPU
max-reductions, and a fused logsumexp — no sort, no second pass.

Row block 8 × vocab ≤ 262144 f32 = 8 MB VMEM working set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _topk_wire_kernel(x_ref, vals_ref, idx_ref, lse_ref, *, k: int,
                      v_total: int):
    x = x_ref[...].astype(jnp.float32)  # (rows, V)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < v_total, x, _NEG)

    # fused logsumexp (one pass, before masking rounds)
    m = jnp.max(x, axis=-1)
    lse_ref[...] = m + jnp.log(jnp.sum(jnp.exp(x - m[:, None]), axis=-1))

    def round_fn(i, carry):
        cur = carry
        vmax = jnp.max(cur, axis=-1)  # (rows,)
        hit = cur == vmax[:, None]
        # first index achieving the max
        imax = jnp.min(jnp.where(hit, col, v_total), axis=-1)
        vals_ref[:, i] = vmax
        idx_ref[:, i] = imax
        cur = jnp.where(col == imax[:, None], _NEG, cur)
        return cur

    jax.lax.fori_loop(0, k, round_fn, x)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_wire(logits, k: int = 32, *, block_rows: int = 8,
              interpret: bool = False):
    """(B, V) -> (vals (B, k) f32, idx (B, k) i32, lse (B,) f32)."""
    B, V = logits.shape
    rows = min(block_rows, B)
    pad = (-B) % rows
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
    Bp = B + pad
    kernel = functools.partial(_topk_wire_kernel, k=k, v_total=V)
    vals, idx, lse = pl.pallas_call(
        kernel,
        grid=(Bp // rows,),
        in_specs=[pl.BlockSpec((rows, V), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, k), lambda i: (i, 0)),
            pl.BlockSpec((rows, k), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
    return vals[:B], idx[:B], lse[:B]
