"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
with ops.py as the jit'd dispatch wrapper and ref.py as the pure-jnp oracle
(see kernels/EXAMPLE.md for the repo convention).
"""
