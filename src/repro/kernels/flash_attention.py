"""Blockwise (flash) attention Pallas TPU kernel — causal + sliding window.

Used by the gemma3 5:1 local:global stack (window=1024) and by full-attention
prefill. Online-softmax accumulation over key blocks; GQA is expressed in the
BlockSpec index maps (query head h reads kv head h // G — no KV duplication
in HBM). Block shapes default to (128, 128): MXU-aligned, and the working
set q(128·d) + k/v(128·d) + acc(128·d) fits VMEM for d ≤ 256.

TPU is the compile target; correctness is validated on CPU in interpret mode
against kernels/ref.flash_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_t: int, block_s: int, ns_blocks: int, t_total: int,
                  s_total: int, causal: bool, window: int, scale: float):
    si = pl.program_id(3)
    ti = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (block_t, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_s, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = ti * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos < s_total) & (qpos < t_total)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == ns_blocks - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_t",
                                             "block_s", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_t: int = 128, block_s: int = 128,
                    interpret: bool = False):
    """q: (B, T, H, d); k, v: (B, S, KV, d) -> (B, T, H, d)."""
    B, T, H, d = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(d)

    qt = q.swapaxes(1, 2)  # (B, H, T, d)
    kt = k.swapaxes(1, 2)  # (B, KV, S, d)
    vt = v.swapaxes(1, 2)

    bt = min(block_t, max(T, 8))
    bs = min(block_s, max(S, 8))
    pad_t = (-T) % bt
    pad_s = (-S) % bs
    if pad_t:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    Tp, Sp = T + pad_t, S + pad_s
    nt, ns = Tp // bt, Sp // bs

    grid = (B, H, nt, ns)
    kernel = functools.partial(
        _flash_kernel, block_t=bt, block_s=bs, ns_blocks=ns, t_total=T,
        s_total=S, causal=causal, window=window, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bt, d), lambda b, h, t, s: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b, h, t, s, _G=G: (b, h // _G, s, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b, h, t, s, _G=G: (b, h // _G, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bt, d), lambda b, h, t, s: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :T].swapaxes(1, 2)
