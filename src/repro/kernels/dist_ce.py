"""Fused distillation cross-entropy Pallas TPU kernel.

The MHD hot spot on LLM clients: for every public token the student must
compute CE against a teacher distribution over V ≤ 262k classes, plus both
sides' confidences (Λ of Eq. 4). Materializing softmax(teacher) and
log_softmax(student) costs 2·B·V fp32 HBM round-trips; this kernel streams
both logit tensors once, block-by-block along V, keeping only running
(max, sumexp, weighted-sum) accumulators in VMEM.

Math (per row b):
    Z_t' = Σ_v exp(t_v − m_t),   a = Σ_v exp(t_v − m_t)·s_v
    CE_b = (m_s + log Z_s') − a / Z_t'
    conf_t = 1 / Z_t',  conf_s = 1 / Z_s'      (softmax max prob)

Block shapes: rows ≤ 256, vocab block 512 (both multiples of MXU/VPU lanes;
V is padded to the block with −inf semantics handled via masking).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_V = 512
_NEG = -1e30


def _dist_ce_kernel(s_ref, t_ref, ce_ref, tconf_ref, sconf_ref,
                    mt_ref, zt_ref, a_ref, ms_ref, zs_ref, *, nv_blocks: int,
                    v_total: int, block_v: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        mt_ref[...] = jnp.full_like(mt_ref, _NEG)
        zt_ref[...] = jnp.zeros_like(zt_ref)
        a_ref[...] = jnp.zeros_like(a_ref)
        ms_ref[...] = jnp.full_like(ms_ref, _NEG)
        zs_ref[...] = jnp.zeros_like(zs_ref)

    s = s_ref[...].astype(jnp.float32)  # (rows, block_v)
    t = t_ref[...].astype(jnp.float32)
    # mask vocab padding in the final block
    base = vi * block_v
    col = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = col < v_total
    s = jnp.where(valid, s, _NEG)
    t = jnp.where(valid, t, _NEG)

    # teacher online softmax + weighted sum of student logits
    m_prev = mt_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(t, axis=-1))
    scale = jnp.exp(m_prev - m_new)
    e_t = jnp.exp(t - m_new[:, None])
    zt_ref[...] = zt_ref[...] * scale + jnp.sum(e_t, axis=-1)
    a_ref[...] = a_ref[...] * scale + jnp.sum(
        e_t * jnp.where(valid, s, 0.0), axis=-1)
    mt_ref[...] = m_new

    # student online logsumexp
    ms_prev = ms_ref[...]
    ms_new = jnp.maximum(ms_prev, jnp.max(s, axis=-1))
    zs_ref[...] = zs_ref[...] * jnp.exp(ms_prev - ms_new) + jnp.sum(
        jnp.exp(s - ms_new[:, None]), axis=-1)
    ms_ref[...] = ms_new

    @pl.when(vi == nv_blocks - 1)
    def _final():
        logzs = ms_ref[...] + jnp.log(zs_ref[...])
        ce_ref[...] = logzs - a_ref[...] / zt_ref[...]
        tconf_ref[...] = 1.0 / zt_ref[...]
        sconf_ref[...] = 1.0 / zs_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_v",
                                             "interpret"))
def dist_ce(student_logits, teacher_logits, *,
            block_rows: int = DEFAULT_BLOCK_ROWS,
            block_v: int = DEFAULT_BLOCK_V,
            interpret: bool = False):
    """(B, V) × (B, V) -> (ce (B,), teacher_conf (B,), student_conf (B,))."""
    B, V = student_logits.shape
    rows = min(block_rows, B)
    pad_b = (-B) % rows
    if pad_b:
        student_logits = jnp.pad(student_logits, ((0, pad_b), (0, 0)))
        teacher_logits = jnp.pad(teacher_logits, ((0, pad_b), (0, 0)))
    Bp = B + pad_b
    nv_blocks = -(-V // block_v)
    pad_v = nv_blocks * block_v - V
    if pad_v:
        student_logits = jnp.pad(student_logits, ((0, 0), (0, pad_v)))
        teacher_logits = jnp.pad(teacher_logits, ((0, 0), (0, pad_v)))

    grid = (Bp // rows, nv_blocks)
    kernel = functools.partial(_dist_ce_kernel, nv_blocks=nv_blocks,
                               v_total=V, block_v=block_v)
    out_shape = [jax.ShapeDtypeStruct((Bp,), jnp.float32)] * 3
    in_spec = pl.BlockSpec((rows, block_v), lambda i, j: (i, j))
    out_spec = pl.BlockSpec((rows,), lambda i, j: (i,))
    vmem = pltpu.VMEM((rows,), jnp.float32)
    ce, tconf, sconf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        scratch_shapes=[vmem, vmem, vmem, vmem, vmem],  # m_t z_t a m_s z_s
        interpret=interpret,
    )(student_logits, teacher_logits)
    return ce[:B], tconf[:B], sconf[:B]
