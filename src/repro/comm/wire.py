"""Wire codecs for teacher predictions (paper §3.2 "Communication
efficiency").

The exchange unit is a *prediction message*: one client's outputs on a
window of upcoming public batches (which are deterministic in the global
step — `PublicPool`), identified per sample by an 8-byte hash. Three
payload layouts:

  * dense    — full-vocab f32/f16 logits per head (+ embedding): the naive
               baseline layout.
  * topk     — per head only the top-k (values, indices, logsumexp), the
               paper's "several highest-confidence predictions per sample"
               turned into bytes. Values can travel as f16, indices shrink
               to u16 when the class count fits, and the retained logsumexp
               keeps teacher probabilities exact over the retained ids.
  * int8 embeddings — per-sample symmetric quantization (scale = max|x|/127)
               of the Eq. 2 embedding vector.

`serialize`/`deserialize` are byte-exact inverses over the quantized
arrays: decode(encode(msg)) reproduces every wire array bit-for-bit. The
format is raw little-endian arrays behind a fixed header — no pickle, so a
message is decodable by any client regardless of its model architecture.

In-graph helpers (`topk_pack_outputs`, `sparse_xent_and_conf`,
`densify_topk`, ...) are the canonical home of the logic previously
private to `core/mhd_distributed.py`; that module now imports from here.
Host-side packing dispatches through `kernels.ops.topk_wire`, i.e. the
Pallas top-k wire kernel on TPU and the `lax.top_k` reference on CPU.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import tracer as trace

_MAGIC = b"MHDW"
_VERSION = 1

# dtype codes used in the array header (wire is always little-endian)
_DTYPES = {
    0: np.dtype("<f4"),
    1: np.dtype("<f2"),
    2: np.dtype("<i4"),
    3: np.dtype("<u2"),
    4: np.dtype("<i1"),
    5: np.dtype("<u8"),
    6: np.dtype("<u4"),
    7: np.dtype("<u1"),
}
_DTYPE_CODES = {v: k for k, v in _DTYPES.items()}


class NonFiniteError(ValueError):
    """Raised when a codec refuses to put NaN/±inf on the wire."""


def _check_finite(name: str, arr: np.ndarray) -> None:
    """Codecs refuse to put NaN/±inf on the wire: a diverged teacher's
    predictions would poison every student that decodes them, so the
    failure surfaces at the *publisher* (the runtime skips that publish
    and meters it) instead of corrupting remote losses. Checked on the
    *wire-dtype* arrays as well as the inputs — a finite f32 logit
    beyond ±65504 overflows to inf in an f16 cast."""
    if not np.all(np.isfinite(arr)):
        raise NonFiniteError(
            f"non-finite values in {name!r}: refusing to encode")


# ---------------------------------------------------------------------------
# in-graph packing / sparse losses (shared with core/mhd_distributed.py)
# ---------------------------------------------------------------------------

def topk_iterative(logits, k: int):
    """Top-k as k argmax+mask rounds — reduces and selects only.

    XLA's TopK lowers to a full variadic (values, iota) sort whose batch
    dims the SPMD partitioner refuses to shard at MHD shapes (measured:
    ~990 GB of replicated f32/s32 sort buffers). k rounds of argmax keep
    everything elementwise/reduce-shaped, which shards cleanly; compute is
    k·V per row — fine for k=32 on a distillation batch.
    """
    neg = jnp.asarray(-1e30, logits.dtype)

    def round_fn(carry, _):
        cur = carry
        idx = jnp.argmax(cur, axis=-1)
        val = jnp.take_along_axis(cur, idx[..., None], axis=-1)[..., 0]
        cur = jnp.where(
            jax.nn.one_hot(idx, cur.shape[-1], dtype=jnp.bool_), neg, cur)
        return cur, (val, idx)

    _, (vals, idxs) = jax.lax.scan(round_fn, logits, None, length=k)
    # (k, ...) -> (..., k)
    vals = jnp.moveaxis(vals, 0, -1)
    idxs = jnp.moveaxis(idxs, 0, -1)
    return vals, idxs


def topk_pack_outputs(outs: Dict[str, Any], k: int) -> Dict[str, Any]:
    """Compress prediction tensors to (values, indices, logsumexp)."""
    def pack(logits):
        vals, idx = topk_iterative(logits, k)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        return {"vals": vals, "idx": idx, "lse": lse}

    return {
        "embedding": outs["embedding"],
        "logits": pack(outs["logits"]),
        "aux_logits": pack(outs["aux_logits"]),
    }


def sparse_xent_and_conf(student_logits, packed):
    """CE(student, sparse teacher) + exact teacher confidence.

    teacher p over retained ids: exp(vals - lse); mass beyond k is dropped
    (an upper-truncated distribution — the approximation of the wire
    format). Student log-probs gathered at the retained ids.
    """
    logp = jax.nn.log_softmax(student_logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(packed["vals"].astype(jnp.float32) - packed["lse"][..., None])
    logp_at = jnp.take_along_axis(logp, packed["idx"], axis=-1)
    ce = -jnp.sum(p * logp_at, axis=-1)
    conf = p[..., 0]  # top-1 prob = Λ (exact)
    return ce, conf


def dense_xent_and_conf(student_logits, teacher_logits):
    t = teacher_logits.astype(jnp.float32)
    p = jax.nn.softmax(t, axis=-1)
    logp = jax.nn.log_softmax(student_logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(p * logp, axis=-1), jnp.max(p, axis=-1)


def densify_topk(vals: np.ndarray, idx: np.ndarray, lse: np.ndarray,
                 num_classes: int, tail: str = "uniform") -> np.ndarray:
    """Reconstruct dense logits from a (vals, idx, lse) pack.

    tail="uniform": the truncated probability mass exp(lse)−Σexp(vals) is
    spread uniformly over the non-retained classes, so logsumexp(recon) ==
    lse and the top-1 confidence Λ stays exact. tail="drop": non-retained
    classes get −inf (renormalized truncated distribution). With k ==
    num_classes both are exact reconstructions.
    """
    vals = np.asarray(vals, np.float32)
    idx = np.asarray(idx, np.int64)
    lse = np.asarray(lse, np.float32)
    k = vals.shape[-1]
    lead = vals.shape[:-1]
    if tail == "drop" or k >= num_classes:
        fill = np.full(lead + (1,), -1e30, np.float32)
    else:
        # log of per-class tail mass, in logit space (shift by lse cancels)
        retained = np.exp(vals - lse[..., None]).sum(axis=-1)
        tail_mass = np.clip(1.0 - retained, 1e-30, None)
        fill = (lse + np.log(tail_mass / (num_classes - k)))[..., None]
    out = np.broadcast_to(fill, lead + (num_classes,)).copy()
    np.put_along_axis(out, idx, vals, axis=-1)
    return out


# ---------------------------------------------------------------------------
# embedding quantization
# ---------------------------------------------------------------------------

def quantize_emb_int8(emb: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-vector int8: q = round(x·127/max|x|). Returns (q, scale)
    with scale shaped like emb without its last axis."""
    emb = np.asarray(emb, np.float32)
    amax = np.max(np.abs(emb), axis=-1)
    scale = (amax / 127.0 + 1e-30).astype(np.float32)
    q = np.clip(np.rint(emb / scale[..., None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize_emb_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * np.asarray(scale, np.float32)[..., None]


# ---------------------------------------------------------------------------
# message + raw-array serialization
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PredictionMessage:
    """One client's predictions for public steps [t0, t0 + W).

    arrays (layouts by codec; W = window, H = 1 + num aux heads):
      sample_ids (W, B) u64      — per-sample hashes of the public batch
      plus either packed {vals/idx/lse} or dense head logits, and an
      optional (possibly quantized) embedding.
    """
    src: int
    sent_step: int
    t0: int
    num_classes: int
    arrays: Dict[str, np.ndarray]

    @property
    def window(self) -> int:
        return int(self.arrays["sample_ids"].shape[0])


def _serialize(msg: PredictionMessage, codec_id: int) -> bytes:
    """Write the message into one preallocated buffer (byte-identical to
    the historical parts-list + join layout, minus its per-array
    ``tobytes`` copies): headers via ``pack_into``, array payloads copied
    once, dtype-converted in place, through a ``frombuffer`` view."""
    t0 = trace.now()
    pending = []
    total = 4 + 4 + 32  # magic + <BBH> + <qqqq>
    for name, arr in msg.arrays.items():
        arr = np.ascontiguousarray(arr)
        dt = np.dtype(arr.dtype.newbyteorder("<"))
        nm = name.encode()
        total += 1 + len(nm) + 2 + 8 * arr.ndim + arr.size * dt.itemsize
        pending.append((nm, arr, dt))
    buf = bytearray(total)
    buf[0:4] = _MAGIC
    struct.pack_into("<BBH", buf, 4, _VERSION, codec_id, len(pending))
    struct.pack_into("<qqqq", buf, 8, msg.src, msg.sent_step, msg.t0,
                     msg.num_classes)
    off = 40
    for nm, arr, dt in pending:
        struct.pack_into("<B", buf, off, len(nm))
        off += 1
        buf[off:off + len(nm)] = nm
        off += len(nm)
        struct.pack_into("<BB", buf, off, _DTYPE_CODES[dt], arr.ndim)
        off += 2
        struct.pack_into(f"<{arr.ndim}q", buf, off, *arr.shape)
        off += 8 * arr.ndim
        nbytes = arr.size * dt.itemsize
        np.frombuffer(buf, dtype=np.uint8, count=nbytes, offset=off)[:] = \
            arr.astype(dt, copy=False).reshape(-1).view(np.uint8)
        off += nbytes
    payload = bytes(buf)
    trace.complete("wire/serialize", t0, src=msg.src,
                   nbytes=len(payload))
    return payload


def _deserialize(payload: bytes) -> Tuple[PredictionMessage, int]:
    t_start = trace.now()
    if payload[:4] != _MAGIC:
        raise ValueError("not a MHDW prediction message")
    ver, codec_id, n_arrays = struct.unpack_from("<BBH", payload, 4)
    if ver != _VERSION:
        raise ValueError(f"wire version {ver} != {_VERSION}")
    off = 8
    src, sent_step, t0, num_classes = struct.unpack_from("<qqqq", payload,
                                                         off)
    off += 32
    arrays: Dict[str, np.ndarray] = {}
    for _ in range(n_arrays):
        (nlen,) = struct.unpack_from("<B", payload, off)
        off += 1
        name = payload[off:off + nlen].decode()
        off += nlen
        code, ndim = struct.unpack_from("<BB", payload, off)
        off += 2
        shape = struct.unpack_from(f"<{ndim}q", payload, off)
        off += 8 * ndim
        dt = _DTYPES[code]
        nbytes = int(np.prod(shape)) * dt.itemsize
        arrays[name] = np.frombuffer(
            payload, dtype=dt, count=int(np.prod(shape)),
            offset=off).reshape(shape)
        off += nbytes
    trace.complete("wire/deserialize", t_start, src=int(src),
                   nbytes=len(payload))
    return PredictionMessage(int(src), int(sent_step), int(t0),
                             int(num_classes), arrays), codec_id


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

def _stack_heads(outs: Dict[str, np.ndarray]) -> np.ndarray:
    """{"logits": (W,B,C), "aux_logits": (W,m,B,C)} -> (W,H,B,C), H=m+1."""
    main = np.asarray(outs["logits"], np.float32)[:, None]
    aux = np.asarray(outs["aux_logits"], np.float32)
    return np.concatenate([main, aux], axis=1)


def _split_heads(heads: np.ndarray) -> Dict[str, np.ndarray]:
    return {"logits": heads[:, 0], "aux_logits": heads[:, 1:]}


class Codec:
    """encode: dense window outputs -> bytes; decode: bytes -> message;
    densify: message -> dense window outputs (the student-side view)."""

    codec_id: int = 0

    def encode(self, src: int, sent_step: int, t0: int,
               sample_ids: np.ndarray, outs: Dict[str, np.ndarray]) -> bytes:
        raise NotImplementedError

    def decode(self, payload: bytes) -> PredictionMessage:
        msg, codec_id = _deserialize(payload)
        if codec_id != self.codec_id:
            raise ValueError(
                f"payload codec id {codec_id} != {self.codec_id}")
        return msg

    def densify(self, msg: PredictionMessage) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    # -- shared embedding handling --------------------------------------

    def _encode_emb(self, arrays: Dict[str, np.ndarray],
                    outs: Dict[str, np.ndarray]) -> None:
        if self.emb_encoding == "none" or "embedding" not in outs:
            return
        emb = np.asarray(outs["embedding"], np.float32)
        _check_finite("embedding", emb)
        if self.emb_encoding == "int8":
            q, scale = quantize_emb_int8(emb)
            arrays["emb_q"] = q
            arrays["emb_scale"] = scale
        else:
            arrays["embedding"] = emb

    def _decode_emb(self, msg: PredictionMessage) -> Optional[np.ndarray]:
        if "embedding" in msg.arrays:
            return np.asarray(msg.arrays["embedding"], np.float32)
        if "emb_q" in msg.arrays:
            return dequantize_emb_int8(msg.arrays["emb_q"],
                                       msg.arrays["emb_scale"])
        return None


class DenseCodec(Codec):
    """Full-vocab logits per head — the naive wire layout."""

    codec_id = 1

    def __init__(self, logit_dtype: str = "float32",
                 emb_encoding: str = "float32"):
        self.logit_dtype = np.dtype("<f2" if logit_dtype == "float16"
                                    else "<f4")
        self.emb_encoding = emb_encoding

    def encode(self, src, sent_step, t0, sample_ids, outs) -> bytes:
        arrays: Dict[str, np.ndarray] = {
            "sample_ids": np.asarray(sample_ids, np.uint64)}
        heads = _stack_heads(outs)
        _check_finite("logits", heads)
        with np.errstate(over="ignore"):  # _check_finite reports overflow
            arrays["heads"] = heads.astype(self.logit_dtype)
        if arrays["heads"].dtype.itemsize < 4:  # f16: catch overflow → inf
            _check_finite("logits (f16 wire cast)", arrays["heads"])
        self._encode_emb(arrays, outs)
        C = int(outs["logits"].shape[-1])
        return _serialize(PredictionMessage(src, sent_step, t0, C, arrays),
                          self.codec_id)

    def densify(self, msg: PredictionMessage) -> Dict[str, np.ndarray]:
        out = _split_heads(np.asarray(msg.arrays["heads"], np.float32))
        emb = self._decode_emb(msg)
        if emb is not None:
            out["embedding"] = emb
        return out


class TopKCodec(Codec):
    """Top-k packed heads: (vals, idx, lse) per head per sample.

    idx travels as u16 whenever the class count fits (vocab ≤ 65535),
    else u32; vals as f16 or f32. Densify spreads the truncated tail mass
    uniformly so confidence stays exact (see `densify_topk`).
    """

    codec_id = 2

    def __init__(self, k: int, val_dtype: str = "float16",
                 emb_encoding: str = "int8", tail: str = "uniform",
                 use_pallas: Optional[bool] = None):
        self.k = int(k)
        self.val_dtype = np.dtype("<f2" if val_dtype == "float16"
                                  else "<f4")
        self.emb_encoding = emb_encoding
        self.tail = tail
        self.use_pallas = use_pallas

    def _pack(self, heads: np.ndarray) -> Dict[str, np.ndarray]:
        from repro.kernels import ops

        W, H, B, C = heads.shape
        k = min(self.k, C)
        vals, idx, lse = ops.topk_wire(
            jnp.asarray(heads.reshape(W * H * B, C)), k,
            use_pallas=self.use_pallas)
        # u16 while the vocab fits, u32 beyond (vocab ≥ 2**16 — LLM heads)
        idx_dt = np.dtype("<u2") if C <= 0xFFFF else np.dtype("<u4")
        with np.errstate(over="ignore"):  # _check_finite reports overflow
            wire_vals = np.asarray(vals).reshape(W, H, B, k) \
                .astype(self.val_dtype)
        if wire_vals.dtype.itemsize < 4:  # f16: catch overflow → inf
            _check_finite("vals (f16 wire cast)", wire_vals)
        return {
            "vals": wire_vals,
            "idx": np.asarray(idx).reshape(W, H, B, k).astype(idx_dt),
            "lse": np.asarray(lse, np.float32).reshape(W, H, B),
        }

    def encode(self, src, sent_step, t0, sample_ids, outs) -> bytes:
        if isinstance(outs.get("logits"), jax.Array):
            return self._encode_device(src, sent_step, t0, sample_ids, outs)
        arrays: Dict[str, np.ndarray] = {
            "sample_ids": np.asarray(sample_ids, np.uint64)}
        heads = _stack_heads(outs)
        _check_finite("logits", heads)
        arrays.update(self._pack(heads))
        self._encode_emb(arrays, outs)
        C = int(outs["logits"].shape[-1])
        return _serialize(PredictionMessage(src, sent_step, t0, C, arrays),
                          self.codec_id)

    def _encode_device(self, src, sent_step, t0, sample_ids, outs) -> bytes:
        """Fused encode for device-resident outputs: one jitted graph
        (`kernels.ops.topk_wire_frame`) does head stacking, top-k, wire
        casts, int8 embedding quantization and the finiteness checks
        entirely on device — byte-identical payloads to the numpy path,
        but only the small wire-dtype arrays ever reach the host."""
        from repro.kernels import ops

        main = outs["logits"].astype(jnp.float32)[:, None]
        heads = jnp.concatenate(
            [main, outs["aux_logits"].astype(jnp.float32)], axis=1)
        C = int(heads.shape[-1])
        k = min(self.k, C)
        emb = outs.get("embedding") if self.emb_encoding != "none" else None
        dev, finite = ops.topk_wire_frame(
            heads, emb, k,
            val_dtype="float16" if self.val_dtype.itemsize == 2
            else "float32",
            idx_dtype="uint16" if C <= 0xFFFF else "uint32",
            emb_encoding=self.emb_encoding, use_pallas=self.use_pallas)
        if not bool(finite):
            raise NonFiniteError(
                "non-finite values in prediction outputs (or their f16 "
                "wire cast): refusing to encode")
        # host copies of wire-dtype arrays only; insertion order matches
        # the numpy path (sample_ids, vals, idx, lse, emb_q, emb_scale)
        # so payloads stay byte-identical
        arrays: Dict[str, np.ndarray] = {
            "sample_ids": np.asarray(sample_ids, np.uint64)}
        for name in ("vals", "idx", "lse", "emb_q", "emb_scale",
                     "embedding"):
            if name in dev:
                arrays[name] = np.asarray(dev[name])
        return _serialize(PredictionMessage(src, sent_step, t0, C, arrays),
                          self.codec_id)

    def densify(self, msg: PredictionMessage) -> Dict[str, np.ndarray]:
        heads = densify_topk(msg.arrays["vals"],
                             msg.arrays["idx"].astype(np.int64),
                             msg.arrays["lse"], msg.num_classes,
                             tail=self.tail)
        out = _split_heads(heads)
        emb = self._decode_emb(msg)
        if emb is not None:
            out["embedding"] = emb
        return out


# ---------------------------------------------------------------------------
# byte accounting (shared with benchmarks/comm_efficiency.py and metering
# tests — the paper's §3.2 numbers fall out of the defaults)
# ---------------------------------------------------------------------------

def topk_frame_nbytes(batch: int, k: int, num_heads: int = 1,
                      emb_dim: int = 0, val_bytes: int = 2,
                      idx_bytes: int = 4, lse_bytes: int = 0,
                      emb_bytes_per_dim: int = 1,
                      emb_scale_bytes: int = 4,
                      hash_bytes: int = 8) -> int:
    """Payload bytes of ONE top-k prediction frame (one public batch).

    Defaults (one head, no embedding, f16 vals + i32 idx + 8-byte hash)
    reproduce the paper's §3.2 accounting exactly; pass the run's real
    head count / embedding dim / dtypes for measured-format accounting.
    """
    per_sample = num_heads * (k * (val_bytes + idx_bytes) + lse_bytes)
    if emb_dim:
        per_sample += emb_dim * emb_bytes_per_dim + emb_scale_bytes
    per_sample += hash_bytes
    return batch * per_sample


def dense_frame_nbytes(batch: int, num_classes: int, num_heads: int = 1,
                       logit_bytes: int = 4, emb_dim: int = 0,
                       emb_bytes_per_dim: int = 4,
                       hash_bytes: int = 8) -> int:
    """Payload bytes of one dense (full-vocab) prediction frame."""
    per_sample = num_heads * num_classes * logit_bytes
    per_sample += emb_dim * emb_bytes_per_dim + hash_bytes
    return batch * per_sample
