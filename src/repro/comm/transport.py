"""Transports: how encoded prediction messages move between clients.

A transport is addressed by directed edges (src, dst) and measures time in
*global training steps* (the runtime's clock). Two implementations:

  * ``LoopbackTransport`` — in-process, lossless, zero latency: a message
    sent at step t is deliverable at step t. This is the reference
    transport under which prediction exchange must reproduce the
    param-pool trainer exactly.
  * ``SimulatedNetwork`` — store-and-forward edges with per-edge latency
    (steps), bandwidth caps (bytes per step; messages serialize FIFO on
    the edge, so a saturated edge delays later messages) and i.i.d. drop
    probability. Deterministic given its seed.

Both are deliberately synchronous-polling: the runtime calls ``poll(dst,
step)`` at step boundaries, mirroring how a real deployment would drain a
message queue between optimization steps.

Async-runtime clock convention: when the trainer is driven by
`core/scheduler.AsyncScheduler`, the ``step`` arguments are *wall ticks*
(real time), not any client's local step count. Latency and bandwidth are
therefore wall-tick quantities: a fixed 2-tick propagation delay spans two
local steps of a 1× client but only half a local step of a 4× (slow)
client — heterogeneity changes how much *training progress* a message
misses, not how long the wire holds it. ``client_rates`` adds the
sender-side half of that interaction: a client that steps r× slower is
modeled with an r× slower uplink (its transmissions occupy the edge r×
as many wall ticks), so slow clients both publish rarely *and* ship
slowly.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

Edge = Tuple[int, int]  # (src, dst)


@dataclasses.dataclass
class Delivery:
    src: int
    dst: int
    payload: bytes
    sent_step: int
    recv_step: int


class Transport:
    def send(self, src: int, dst: int, payload: bytes, step: int) -> None:
        raise NotImplementedError

    def poll(self, dst: int, step: int) -> List[Delivery]:
        """Messages for ``dst`` that have arrived by ``step`` (FIFO)."""
        raise NotImplementedError

    def close(self) -> None:
        """Release held resources. In-process transports hold none; the
        socket transport overrides this to close real listeners."""

    # -- snapshot/restore (repro.fleet) ----------------------------------

    def state_dict(self) -> Optional[Dict]:
        """In-flight state for a fleet snapshot, or ``None`` when the
        transport's wire state cannot be captured (real sockets: frames on
        the kernel's wire are simply lost on restore — the staleness
        machinery absorbs the gap). In-process transports override."""
        return None

    def load_state_dict(self, state: Dict) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not support state restore")


class LoopbackTransport(Transport):
    """Lossless, zero-latency, infinite-bandwidth in-process queues."""

    def __init__(self):
        self._queues: Dict[int, List[Delivery]] = defaultdict(list)

    def send(self, src, dst, payload, step) -> None:
        self._queues[dst].append(Delivery(src, dst, payload, step, step))

    def poll(self, dst, step) -> List[Delivery]:
        out = [d for d in self._queues[dst] if d.sent_step <= step]
        self._queues[dst] = [d for d in self._queues[dst]
                             if d.sent_step > step]
        for d in out:
            d.recv_step = step
        return out

    def state_dict(self) -> Dict:
        return {"queues": {
            int(dst): [(d.src, d.payload, d.sent_step, d.recv_step)
                       for d in q]
            for dst, q in self._queues.items() if q}}

    def load_state_dict(self, state: Dict) -> None:
        self._queues = defaultdict(list)
        for dst, q in state["queues"].items():
            dst = int(dst)
            self._queues[dst] = [
                Delivery(int(src), dst, bytes(payload), int(sent), int(recv))
                for src, payload, sent, recv in q]


@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    """Per-edge link model. ``bandwidth`` is bytes per training step
    (None = unlimited); ``latency`` is propagation delay in steps."""
    latency: int = 0
    bandwidth: Optional[int] = None
    drop_prob: float = 0.0


@dataclasses.dataclass
class _InFlight:
    payload: bytes
    sent_step: int
    arrival_step: int


class SimulatedNetwork(Transport):
    """Store-and-forward network simulation.

    Each edge transmits FIFO at ``bandwidth`` bytes/step: a message sent
    at t starts transmitting when the edge frees up, takes
    ceil(len/bandwidth) steps on the wire, then ``latency`` steps of
    propagation. Drops are decided at send time (the message simply never
    arrives — the bus's staleness stamps surface the gap).
    """

    def __init__(self, latency: int = 0, bandwidth: Optional[int] = None,
                 drop_prob: float = 0.0, seed: int = 0,
                 per_edge: Optional[Dict[Edge, EdgeSpec]] = None,
                 client_rates: Optional[Dict[int, int]] = None):
        self.default = EdgeSpec(latency, bandwidth, drop_prob)
        self.per_edge = dict(per_edge or {})
        # wall ticks per local step of each client (1 = full speed); a
        # slow sender's uplink serializes r× slower in wall-tick terms
        self.client_rates = {int(c): int(r)
                             for c, r in (client_rates or {}).items()}
        self.rng = np.random.default_rng(seed)
        self._inflight: Dict[Edge, List[_InFlight]] = defaultdict(list)
        self._edge_free_at: Dict[Edge, int] = defaultdict(int)
        self.sent_count = 0
        self.dropped_count = 0

    def spec(self, edge: Edge) -> EdgeSpec:
        return self.per_edge.get(edge, self.default)

    def rate(self, client: int) -> int:
        return max(self.client_rates.get(client, 1), 1)

    def send(self, src, dst, payload, step) -> None:
        edge = (src, dst)
        spec = self.spec(edge)
        self.sent_count += 1
        dropped = spec.drop_prob > 0.0 and self.rng.random() < spec.drop_prob
        start = max(step, self._edge_free_at[edge])
        # effective uplink of a rate-r sender is bandwidth/r bytes per
        # wall tick; propagation latency is a link property and doesn't
        # scale with the sender's compute
        tx_steps = 0 if not spec.bandwidth else \
            int(math.ceil(len(payload) * self.rate(src) / spec.bandwidth))
        finish = start + tx_steps
        # the uplink is occupied for dropped messages too: on a real wire
        # the sender spends the transmit time either way (the loss happens
        # downstream), so a drop still delays the edge's later messages
        self._edge_free_at[edge] = finish
        if dropped:
            self.dropped_count += 1
            return
        self._inflight[edge].append(
            _InFlight(payload, step, finish + spec.latency))

    def poll(self, dst, step) -> List[Delivery]:
        out: List[Delivery] = []
        for (src, d), msgs in list(self._inflight.items()):
            if d != dst:
                continue
            ready = [m for m in msgs if m.arrival_step <= step]
            self._inflight[(src, d)] = [m for m in msgs
                                        if m.arrival_step > step]
            for m in ready:
                out.append(Delivery(src, dst, m.payload, m.sent_step, step))
        out.sort(key=lambda m: (m.sent_step, m.src))
        return out

    def state_dict(self) -> Dict:
        return {
            "inflight": {
                f"{s}-{d}": [(m.payload, m.sent_step, m.arrival_step)
                             for m in msgs]
                for (s, d), msgs in self._inflight.items() if msgs},
            "edge_free_at": {f"{s}-{d}": int(v)
                             for (s, d), v in self._edge_free_at.items()},
            "rng": self.rng.bit_generator.state,
            "sent_count": self.sent_count,
            "dropped_count": self.dropped_count,
        }

    def load_state_dict(self, state: Dict) -> None:
        def edge(key: str) -> Edge:
            s, d = key.split("-")
            return (int(s), int(d))

        self._inflight = defaultdict(list)
        for key, msgs in state["inflight"].items():
            self._inflight[edge(key)] = [
                _InFlight(bytes(p), int(sent), int(arr))
                for p, sent, arr in msgs]
        self._edge_free_at = defaultdict(int)
        for key, v in state["edge_free_at"].items():
            self._edge_free_at[edge(key)] = int(v)
        self.rng.bit_generator.state = state["rng"]
        self.sent_count = int(state["sent_count"])
        self.dropped_count = int(state["dropped_count"])
