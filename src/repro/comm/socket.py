"""TCP-on-localhost transport: the prediction exchange over a real wire.

The paper's agents are independent processes that exchange predictions
over a network with no shared memory; `LoopbackTransport` and
`SimulatedNetwork` both live inside one Python process. `SocketTransport`
implements the same ``send(src, dst, payload, step)`` / ``poll(dst,
step)`` interface over real TCP connections on one host, so the
decentralized runtime can be split across OS processes (one per client —
see `launch/gossip.py` and `scripts/run_gossip_procs.py`) with
heterogeneous step rates that are *wall-clock* speed differences, not
simulation ticks.

Topology of sockets
  Each transport instance *hosts* a subset of the clients
  (``clients=``; default all — the in-process configuration). Every
  hosted client owns one listening TCP server socket on a known port
  (``ports[cid]``; port 0 = OS-assigned, read back from ``.ports``).
  A directed edge (src, dst) of the communication graph maps to one
  client connection from src's process to dst's listener — created
  eagerly by ``connect_edges(adjacency)`` (with retries, so processes
  can start in any order) or lazily on the first ``send``. TCP's
  in-order byte stream gives FIFO delivery per edge for free.

Frame protocol
  One message = one length-prefixed frame carrying the byte-exact wire
  codec payload (`wire.py` — the frame never inspects it):

      <4s q q q I : magic b"MHDF", src, dst, sent_step, payload_nbytes>
      <payload_nbytes bytes : codec payload>

  Fixed 32-byte little-endian header; ``sent_step`` travels with the
  frame so the receiver's staleness stamps don't depend on clock
  agreement between processes.

Poll semantics
  ``poll(dst, step)`` performs a *non-blocking* drain: accept pending
  connections, read whatever bytes the kernel has, parse complete
  frames, and return the deliveries whose ``sent_step <= step`` (the
  transport contract: no delivery before the caller's tick — frames
  "from the future" of a faster peer stay queued until the local clock
  catches up). Polling a client this instance does not host returns [].

  ``wait_inflight=True`` (the default when one instance hosts every
  client) additionally blocks until all *locally sent* frames destined
  to ``dst`` have been parsed — in-process, localhost TCP is then
  deterministic and a socket run reproduces the loopback teacher
  schedule exactly (tests/test_transport_contract.py). Multi-process
  instances must leave it off: a receiver cannot know what a remote
  sender still has in flight.
"""
from __future__ import annotations

import contextlib
import socket
import struct
import time
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Sequence

from repro.comm.transport import Delivery, Edge, Transport
from repro.obs import tracer as trace
from repro.obs.tracer import flow_id

_FRAME_MAGIC = b"MHDF"
_HEADER = struct.Struct("<4sqqqI")  # magic, src, dst, sent_step, nbytes

FRAME_HEADER_BYTES = _HEADER.size  # 32


def pack_frame(src: int, dst: int, sent_step: int, payload: bytes) -> bytes:
    return _HEADER.pack(_FRAME_MAGIC, src, dst, sent_step,
                        len(payload)) + payload


def allocate_ports(num_clients: int,
                   host: str = "127.0.0.1") -> Dict[int, int]:
    """Reserve one free TCP port per client by binding throwaway sockets.

    Convenience for single-launcher setups; the gap between releasing a
    port here and the client binding it is a (tiny, localhost-only)
    race. The multi-process launcher avoids it entirely by having each
    child bind port 0 itself and report back (`launch/gossip.py`)."""
    socks = []
    try:
        for _ in range(num_clients):
            s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            s.bind((host, 0))
            socks.append(s)
        return {cid: s.getsockname()[1] for cid, s in enumerate(socks)}
    finally:
        for s in socks:
            s.close()


class SocketTransport(Transport):
    """TCP transport hosting ``clients`` (default: all) of a fleet.

    ``ports`` maps client id -> listening port. Hosted clients missing
    from the map bind an OS-assigned port (read ``.ports`` afterwards);
    remote clients' ports may be filled in later via ``set_ports`` —
    they are only needed by the first send on an edge toward them.
    """

    def __init__(self, num_clients: int,
                 clients: Optional[Iterable[int]] = None,
                 ports: Optional[Dict[int, int]] = None,
                 host: str = "127.0.0.1",
                 connect_timeout: float = 20.0,
                 drain_timeout: float = 20.0,
                 send_hard_timeout: Optional[float] = None,
                 wait_inflight: Optional[bool] = None):
        self.num_clients = int(num_clients)
        self.host = host
        self.connect_timeout = float(connect_timeout)
        self.drain_timeout = float(drain_timeout)
        # a send gives up (failed_sends) only after this long; each
        # expired drain_timeout window in between is a metered stall, not
        # a lost frame. Default: 10 stall windows. The gossip launcher
        # passes its own hard run timeout so a send is never the first
        # thing to give up on a slow-but-alive peer (e.g. a rank stalled
        # in jit compilation for longer than drain_timeout).
        self.send_hard_timeout = (10.0 * self.drain_timeout
                                  if send_hard_timeout is None
                                  else float(send_hard_timeout))
        local = range(num_clients) if clients is None else clients
        self.local_clients = sorted({int(c) for c in local})
        if any(c < 0 or c >= num_clients for c in self.local_clients):
            raise ValueError(f"hosted clients {self.local_clients} out of "
                             f"range for {num_clients} clients")
        self.wait_inflight = (
            len(self.local_clients) == self.num_clients
            if wait_inflight is None else bool(wait_inflight))
        self.ports: Dict[int, int] = {int(c): int(p)
                                      for c, p in (ports or {}).items()}

        self._listeners: Dict[int, socket.socket] = {}
        for cid in self.local_clients:
            srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            srv.bind((host, self.ports.get(cid, 0)))
            srv.listen(max(self.num_clients, 8))
            srv.setblocking(False)
            self._listeners[cid] = srv
            self.ports[cid] = srv.getsockname()[1]

        self._out: Dict[Edge, socket.socket] = {}  # edge -> sender conn
        self._dead_edges: set = set()  # peer gone: drop, don't reconnect
        self._in: Dict[int, List[socket.socket]] = {
            cid: [] for cid in self.local_clients}
        self._buffers: Dict[socket.socket, bytearray] = {}
        self._queues: Dict[int, List[Delivery]] = defaultdict(list)
        self._outstanding: Dict[int, int] = defaultdict(int)
        self._closed = False
        self.sent_count = 0
        self.recv_count = 0
        self.sent_bytes = 0
        self.recv_bytes = 0
        self.failed_sends = 0  # peer gone mid-run: the message is lost
        self.corrupt_connections = 0  # non-protocol bytes: conn dropped
        self.drain_stalls = 0  # drain_timeout windows a send sat blocked
        self.undrained_bytes = 0  # partial-frame bytes left at quiesce
        # frames fully written per destination — what the gossip finish
        # barrier's expected-inbound counts are built from
        self.sent_to: Dict[int, int] = defaultdict(int)

    # -- wiring ----------------------------------------------------------

    def set_ports(self, ports: Dict[int, int]) -> None:
        """Fill in (remote) ports learned after construction. A hosted
        client's bound port cannot be changed."""
        for cid, port in ports.items():
            cid, port = int(cid), int(port)
            if cid in self._listeners and self.ports[cid] != port:
                raise ValueError(
                    f"client {cid} is hosted here on port "
                    f"{self.ports[cid]}; cannot remap to {port}")
            self.ports[cid] = port

    def connect_edges(self, adjacency: Sequence[Sequence[int]]) -> None:
        """Eagerly open the per-edge connections this instance sends on:
        every graph edge (src, dst) with a hosted src. Retries until the
        peer's listener is up (``connect_timeout``), so cooperating
        processes may start in any order."""
        for dst, nbrs in enumerate(adjacency):
            for src in nbrs:
                if int(src) in self._listeners:
                    self._connect((int(src), int(dst)))

    def _connect(self, edge: Edge) -> socket.socket:
        src, dst = edge
        port = self.ports.get(dst)
        if port is None:
            raise ValueError(
                f"no port known for client {dst}; pass ports= or call "
                "set_ports() before sending on edge "
                f"({src}, {dst})")
        deadline = time.monotonic() + self.connect_timeout
        with trace.span("socket/connect", src=src, dst=dst, port=port):
            while True:
                try:
                    conn = socket.create_connection(
                        (self.host, port), timeout=self.connect_timeout)
                    break
                except OSError:
                    if time.monotonic() >= deadline:
                        raise
                    time.sleep(0.05)
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._out[edge] = conn
        return conn

    # -- Transport interface ---------------------------------------------

    def send(self, src: int, dst: int, payload: bytes, step: int) -> None:
        if self._closed:
            raise RuntimeError("transport is closed")
        edge = (src, dst)
        if edge in self._dead_edges:
            self.failed_sends += 1
            return
        t0 = trace.now()
        conn = self._out.get(edge)
        if conn is None:
            try:
                conn = self._connect(edge)
            except OSError:
                # unreachable after connect_timeout of retries: the peer
                # is gone for good — tombstone so later sends on a
                # time-varying graph don't re-pay the retry window
                self.failed_sends += 1
                self._dead_edges.add(edge)
                trace.complete("socket/send", t0, src=src, dst=dst,
                               step=step, ok=False)
                return
        frame = pack_frame(src, dst, step, payload)
        try:
            self._send_frame(conn, dst, frame)
        except OSError as e:
            # the frame may be partially written, so this connection's
            # byte stream is unrecoverable either way — drop it. A
            # timeout (slow-but-alive peer, kernel buffer full) permits
            # a fresh connection on the next send; a hard error (peer
            # process exited) tombstones the edge. Never fatal: on a
            # real wire the bytes are simply lost.
            self.failed_sends += 1
            if not isinstance(e, socket.timeout):
                self._dead_edges.add(edge)
            with contextlib.suppress(OSError):
                conn.close()
            self._out.pop(edge, None)
            trace.complete("socket/send", t0, src=src, dst=dst,
                           step=step, ok=False)
            return
        self.sent_count += 1
        self.sent_bytes += len(payload)
        self.sent_to[dst] += 1
        if self.wait_inflight and dst in self._listeners:
            self._outstanding[dst] += 1
        # flow start then the retro-emitted span: the "s" event's
        # timestamp falls inside the span, so Perfetto binds the arrow to
        # this send slice; the receiver emits the matching "f" from the
        # same (src, dst, step) frame-header triple (repro.comm.bus)
        trace.flow_start(flow_id(src, dst, step))
        trace.complete("socket/send", t0, src=src, dst=dst, step=step,
                       nbytes=len(payload))

    def _send_frame(self, conn: socket.socket, dst: int,
                    frame: bytes) -> None:
        """``sendall`` in short slices, draining our own hosted listeners
        between them.

        Two failure modes this neutralizes:

        * in-process (dst hosted here): a frame larger than the kernel's
          socket buffers cannot deadlock the one thread doing both ends —
          draining dst's receive path is interleaved with the write;
        * multi-process: a receiver that stops reading for a while (a
          rank stalled in jit compilation, a straggler) must not cost us
          the frame *or* deadlock a ring of mutual senders. We keep
          retrying — draining our own inbound edges so peers blocked on
          *us* make progress — and each expired ``drain_timeout`` window
          without a written byte is metered as a ``drain_stalls`` tick
          with exponential backoff, never an error. Only
          ``send_hard_timeout`` (the launcher's hard-timeout scale) makes
          the send give up, and even that surfaces as a failed send, not
          a fleet-killing raise."""
        view = memoryview(frame)
        hard_deadline = time.monotonic() + self.send_hard_timeout
        stall_deadline = time.monotonic() + self.drain_timeout
        backoff = 0.01
        conn.settimeout(0.05)
        try:
            while view:
                try:
                    sent = conn.send(view)
                except socket.timeout:
                    sent = 0
                if sent:
                    view = view[sent:]
                    stall_deadline = time.monotonic() + self.drain_timeout
                    backoff = 0.01
                    continue
                for hosted in self._listeners:
                    self._drain(hosted)
                now = time.monotonic()
                if now >= hard_deadline:
                    raise socket.timeout(
                        f"frame to client {dst} unsent after "
                        f"{self.send_hard_timeout:.0f}s (hard timeout)")
                if now >= stall_deadline:
                    self.drain_stalls += 1
                    trace.instant("socket/drain_stall", dst=dst,
                                  stalls=self.drain_stalls)
                    time.sleep(backoff)
                    backoff = min(backoff * 2.0, 1.0)
                    stall_deadline = time.monotonic() + self.drain_timeout
        finally:
            with contextlib.suppress(OSError):
                conn.settimeout(self.connect_timeout)

    def poll(self, dst: int, step: int) -> List[Delivery]:
        if dst not in self._listeners:
            return []
        self._drain(dst)
        if self.wait_inflight and self._outstanding[dst] > 0:
            t0 = trace.now()
            waiting = self._outstanding[dst]
            deadline = time.monotonic() + self.drain_timeout
            while self._outstanding[dst] > 0:
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"{self._outstanding[dst]} locally sent frame(s) "
                        f"for client {dst} never arrived within "
                        f"{self.drain_timeout}s")
                self._drain(dst, wait=0.005)
            trace.complete("socket/drain_wait", t0, dst=dst,
                           frames=waiting)
        queue = self._queues[dst]
        ready = [d for d in queue if d.sent_step <= step]
        self._queues[dst] = [d for d in queue if d.sent_step > step]
        ready.sort(key=lambda d: (d.sent_step, d.src))
        for d in ready:
            d.recv_step = step
        return ready

    # -- receive path ----------------------------------------------------

    def _drain(self, dst: int, wait: float = 0.0) -> None:
        """Accept pending connections and read whatever has arrived —
        never blocks beyond ``wait`` seconds."""
        srv = self._listeners[dst]
        t0 = trace.now()
        b0, f0 = self.recv_bytes, self.recv_count
        if wait:
            time.sleep(wait)
        while True:
            try:
                conn, _ = srv.accept()
            except (BlockingIOError, InterruptedError):
                break
            except OSError:
                break
            conn.setblocking(False)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._in[dst].append(conn)
            self._buffers[conn] = bytearray()
        for conn in list(self._in[dst]):
            buf = self._buffers[conn]
            closed = False
            while True:
                try:
                    chunk = conn.recv(1 << 16)
                except (BlockingIOError, InterruptedError):
                    break
                except OSError:
                    closed = True
                    break
                if not chunk:
                    closed = True
                    break
                buf += chunk
            ok = self._parse_frames(dst, buf)
            if closed or not ok:
                self._in[dst].remove(conn)
                self._buffers.pop(conn, None)
                with contextlib.suppress(OSError):
                    conn.close()
        # emitted only when bytes actually moved: barrier/idle loops call
        # _drain thousands of times and must not flood the ring buffer
        if self.recv_count != f0 or self.recv_bytes != b0:
            trace.complete("socket/drain", t0, dst=dst,
                           frames=self.recv_count - f0,
                           nbytes=self.recv_bytes - b0)

    def _parse_frames(self, dst: int, buf: bytearray) -> bool:
        """Parse complete frames out of ``buf``; returns False when the
        stream is corrupt (bad magic / mis-addressed frame — a stray
        localhost connection, not a peer), telling the caller to drop
        the connection. Receiving, like sending, is never fatal."""
        while len(buf) >= _HEADER.size:
            magic, src, fdst, sent_step, nbytes = _HEADER.unpack_from(buf, 0)
            if magic != _FRAME_MAGIC or fdst != dst:
                self.corrupt_connections += 1
                return False
            if len(buf) < _HEADER.size + nbytes:
                return True
            payload = bytes(buf[_HEADER.size:_HEADER.size + nbytes])
            del buf[:_HEADER.size + nbytes]
            self._queues[dst].append(
                Delivery(int(src), dst, payload, int(sent_step), -1))
            self.recv_count += 1
            self.recv_bytes += nbytes
            if self.wait_inflight and self._outstanding[dst] > 0:
                self._outstanding[dst] -= 1
        return True

    # -- quiesce + snapshot (repro.fleet) --------------------------------

    def quiesce(self, settle: float = 0.05, timeout: float = 5.0) -> int:
        """Pull everything the kernel has buffered into the parsed
        hold-back queues: drain every hosted listener until no new bytes
        arrive for ``settle`` seconds (bounded by ``timeout``). After a
        quiesce the only in-flight state a snapshot cannot capture is a
        frame a remote sender has not finished writing; bytes of such
        partial frames left in per-connection buffers are metered in
        ``undrained_bytes``. Returns that leftover byte count."""
        t0 = trace.now()
        deadline = time.monotonic() + timeout
        quiet_at = time.monotonic() + settle
        while time.monotonic() < min(deadline, quiet_at):
            before = self.recv_bytes
            for dst in self._listeners:
                self._drain(dst)
            if self.recv_bytes != before:
                quiet_at = time.monotonic() + settle
            else:
                time.sleep(0.005)
        leftover = sum(len(buf) for buf in self._buffers.values())
        self.undrained_bytes = leftover
        trace.complete("socket/quiesce", t0, leftover=leftover)
        return leftover

    def state_dict(self) -> Dict:
        """The capturable in-flight state: parsed frames held back by the
        no-delivery-before-tick rule, plus the wire counters. Call
        ``quiesce()`` first so kernel-buffered frames are parsed into the
        queues instead of becoming documented losses (`repro.fleet`
        does — see `snapshot.save_fleet`)."""
        return {
            "queues": {int(dst): [(int(d.src), bytes(d.payload),
                                   int(d.sent_step))
                                  for d in q]
                       for dst, q in self._queues.items() if q},
            "counters": {
                "sent_count": int(self.sent_count),
                "recv_count": int(self.recv_count),
                "sent_bytes": int(self.sent_bytes),
                "recv_bytes": int(self.recv_bytes),
                "failed_sends": int(self.failed_sends),
                "drain_stalls": int(self.drain_stalls),
                "undrained_bytes": int(self.undrained_bytes),
                "sent_to": {int(d): int(n)
                            for d, n in self.sent_to.items()},
            },
        }

    def load_state_dict(self, state: Dict) -> None:
        for dst, items in state.get("queues", {}).items():
            self._queues[int(dst)].extend(
                Delivery(int(src), int(dst), bytes(payload),
                         int(sent_step), -1)
                for src, payload, sent_step in items)
        c = state.get("counters", {})
        self.sent_count = int(c.get("sent_count", 0))
        self.recv_count = int(c.get("recv_count", 0))
        self.sent_bytes = int(c.get("sent_bytes", 0))
        self.recv_bytes = int(c.get("recv_bytes", 0))
        self.failed_sends = int(c.get("failed_sends", 0))
        self.drain_stalls = int(c.get("drain_stalls", 0))
        self.undrained_bytes = int(c.get("undrained_bytes", 0))
        for d, n in c.get("sent_to", {}).items():
            self.sent_to[int(d)] = int(n)

    # -- lifecycle -------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for conn in list(self._out.values()):
            with contextlib.suppress(OSError):
                conn.close()
        for conns in self._in.values():
            for conn in conns:
                with contextlib.suppress(OSError):
                    conn.close()
        for srv in self._listeners.values():
            with contextlib.suppress(OSError):
                srv.close()
        self._out.clear()
        self._buffers.clear()

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort: tests/examples that forget close()
        with contextlib.suppress(Exception):
            self.close()
