"""Prediction bus: per-edge mailboxes driven by the communication graph.

``publish(src, payload, step)`` fans one client's encoded prediction
message out along the current graph G_t: every client that lists ``src``
as an in-neighbor (``src ∈ adj[dst]`` — the same convention as
`core/graph.py`: adj[i] are the clients i *receives from*) gets a copy on
its (src, dst) edge. ``deliver(step)`` drains the transport into per-client
mailboxes; a mailbox keeps the *latest* message per sender together with
its staleness stamps (sent/received step).

The bus also keeps a *per-client logical clock* for the async runtime
(`core/scheduler.py`): ``advance(client, t)`` records the last wall tick
at which a client took a local step, and ``poll_fresh(client,
max_staleness)`` filters that client's mailbox down to mail whose age —
measured against the client's own clock, in wall ticks — is within the
staleness bound. Under the synchronous trainer every clock advances in
lockstep, so both APIs degenerate to the global-step behavior.

`PredictionPool` is the prediction-mode twin of the param
`CheckpointPool`: identical capacity / random-replacement / Δ-sampling
behavior (it *is* a subclass, sharing the rng stream), but entries hold
decoded prediction windows instead of parameters — so a lossless
zero-latency prediction run replays the param-pool run's teacher
schedule exactly, while params never leave their client.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint.pool import CheckpointPool, PoolEntry
from repro.core.graph import Adjacency, GraphFn, as_graph_fn
from repro.comm.metering import CommMeter
from repro.comm.transport import Delivery, Transport
from repro.obs import tracer as trace
from repro.obs.tracer import flow_id


@dataclasses.dataclass
class Mail:
    src: int
    payload: bytes
    sent_step: int
    recv_step: int

    def staleness(self, step: int) -> int:
        return step - self.sent_step


class PredictionBus:
    def __init__(self, transport: Transport, graph, num_clients: int,
                 meter: Optional[CommMeter] = None,
                 membership: Optional[Any] = None):
        # ``membership`` (repro.fleet.Membership, duck-typed via
        # ``is_alive(client, step)``) makes the bus churn-aware: a message
        # arriving for a dead client is dropped and metered as a
        # *tombstoned* loss — the sender offered it, the student never
        # sees it, delivered stays ≤ offered. None = everyone always
        # alive (the static-fleet behavior, unchanged).
        self.transport = transport
        self.graph_fn: GraphFn = as_graph_fn(graph)
        self.num_clients = num_clients
        self.meter = meter
        self.membership = membership
        self._mailboxes: Dict[int, Dict[int, Mail]] = {
            i: {} for i in range(num_clients)}
        self._clocks: Dict[int, int] = {i: 0 for i in range(num_clients)}

    def publish(self, src: int, payload: bytes, step: int) -> None:
        adj: Adjacency = self.graph_fn(step)
        for dst in range(self.num_clients):
            if dst == src or src not in adj[dst]:
                continue
            self.transport.send(src, dst, payload, step)
            if self.meter is not None:
                self.meter.record(step, src, dst, len(payload))

    def deliver(self, step: int) -> int:
        """Drain arrived messages into mailboxes; returns #deliveries.
        Each arrival is metered as *delivered* traffic — the receiver-side
        book, which excludes messages the transport dropped (those were
        metered as offered at ``publish`` time and nowhere else)."""
        t0 = trace.now()
        n = 0
        for dst in range(self.num_clients):
            for d in self.transport.poll(dst, step):
                if self.membership is not None and \
                        not self.membership.is_alive(dst, step):
                    # dead destination: the mail is a tombstoned loss —
                    # offered (metered at publish), never delivered
                    if self.meter is not None:
                        self.meter.record_tombstone(step, d.src, dst,
                                                    len(d.payload))
                    trace.instant("bus/tombstone", src=d.src, dst=dst,
                                  step=step, nbytes=len(d.payload))
                    continue
                cur = self._mailboxes[dst].get(d.src)
                if cur is None or d.sent_step >= cur.sent_step:
                    self._mailboxes[dst][d.src] = Mail(
                        d.src, d.payload, d.sent_step, d.recv_step)
                if self.meter is not None:
                    self.meter.record_delivery(step, d.src, dst,
                                               len(d.payload))
                trace.flow_end(flow_id(d.src, dst, d.sent_step))
                n += 1
        # emitted only when mail moved: the every-tick drain (and the
        # gossip finish barrier's busy loop) must not flood the buffer
        if n:
            trace.complete("bus/deliver", t0, step=step, delivered=n)
        return n

    def quiesce(self, step: int) -> int:
        """Flush the wire into mailboxes: ask the transport to drain any
        frames still sitting in kernel/parse buffers (transports without a
        ``quiesce`` hook — loopback, simulated — have nothing buried), then
        deliver what arrived. Used before fleet snapshots and at the gossip
        finish barrier so `delivered == offered` holds on a lossless wire.
        Returns the number of deliveries flushed."""
        q = getattr(self.transport, "quiesce", None)
        if q is not None:
            q()
        return self.deliver(step)

    def mailbox(self, dst: int) -> Dict[int, Mail]:
        return self._mailboxes[dst]

    def clear_mailbox(self, dst: int) -> None:
        """Wipe a client's mailbox — its mail dies with it (client churn:
        a killed process loses everything not in its snapshot)."""
        self._mailboxes[dst] = {}

    # -- per-client clocks (async runtime) -------------------------------

    def advance(self, client: int, t: int) -> None:
        """Record that ``client`` reached wall tick ``t``. Clocks are
        monotone: a stale advance (t below the recorded clock) is a no-op,
        so replays/retries can't move time backwards."""
        if t > self._clocks[client]:
            self._clocks[client] = t

    def clock(self, client: int) -> int:
        """The last wall tick ``client`` advanced to (0 before any step)."""
        return self._clocks[client]

    def poll_fresh(self, client: int,
                   max_staleness: Optional[int]) -> Dict[int, Mail]:
        """The subset of ``client``'s mailbox fresh enough to distill from,
        judged against the client's *own* clock: mail m survives iff
        ``clock(client) - m.sent_step <= max_staleness``. ``None`` means
        unbounded (the whole mailbox)."""
        box = self._mailboxes[client]
        if max_staleness is None:
            return dict(box)
        t = self._clocks[client]
        return {src: m for src, m in box.items()
                if m.staleness(t) <= max_staleness}

    # -- snapshot/restore (repro.fleet) ----------------------------------

    def client_state(self, dst: int) -> Dict[str, Any]:
        """One client's bus slice — mailbox + logical clock — the unit a
        per-process fleet snapshot captures."""
        return {
            "clock": int(self._clocks[dst]),
            "mail": {int(src): (m.payload, int(m.sent_step),
                                int(m.recv_step))
                     for src, m in self._mailboxes[dst].items()},
        }

    def load_client_state(self, dst: int, state: Dict[str, Any]) -> None:
        self._clocks[dst] = int(state["clock"])
        self._mailboxes[dst] = {
            int(src): Mail(int(src), bytes(payload), int(sent), int(recv))
            for src, (payload, sent, recv) in state["mail"].items()}

    EMPTY_STALENESS = -1.0  # sentinel: no mail has ever arrived

    def staleness(self, dst: int, step: int) -> float:
        """Mean staleness (steps) of dst's mailbox.

        Returns ``EMPTY_STALENESS`` (-1.0) when the mailbox is empty —
        callers reading this as a metric before any mail exists (e.g.
        `runtime.step()` on a chain's sink client) get a documented
        sentinel instead of a value indistinguishable from perfectly
        fresh mail."""
        box = self._mailboxes[dst]
        if not box:
            return self.EMPTY_STALENESS
        return float(np.mean([m.staleness(step) for m in box.values()]))


@dataclasses.dataclass
class PredictionWindow:
    """A decoded message: dense-view outputs for steps [t0, t0 + W)."""
    t0: int
    outs: Dict[str, np.ndarray]  # embedding? (W,B,E), logits (W,B,C), aux…

    @property
    def window(self) -> int:
        return int(self.outs["logits"].shape[0])

    def covers(self, t: int) -> bool:
        return self.t0 <= t < self.t0 + self.window

    def frame(self, t: int) -> Dict[str, np.ndarray]:
        w = t - self.t0
        return {k: v[w] for k, v in self.outs.items()}


class PredictionPool(CheckpointPool):
    """A `CheckpointPool` whose entries carry `PredictionWindow`s in the
    ``params`` slot. Same seed ⇒ same insert/replace/sample rng stream as
    the param pool, which is what makes the lossless-transport equivalence
    test exact."""

    def usable(self, entries: List[PoolEntry], t: int) -> List[PoolEntry]:
        """Entries whose window still covers step t (expired windows can't
        score the current public batch — predictions, unlike params, are
        sample-bound)."""
        return [e for e in entries if e.params.covers(t)]
