"""Bytes-per-edge-per-step ledger for the prediction exchange.

Every transport send is recorded as (step, src, dst, nbytes); the ledger
answers the paper's §3.2 accounting questions from *measured* traffic:
total bytes, per-edge totals, per-step totals, and amortized
bytes-per-client-step (publishes happen every S_P steps but cover S_P
public batches, so the amortized figure is the one comparable to
`benchmarks/comm_efficiency._mhd_bytes_per_step`).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

Edge = Tuple[int, int]


class CommMeter:
    def __init__(self):
        self.total_bytes = 0
        self.num_messages = 0
        self.by_edge: Dict[Edge, int] = defaultdict(int)
        self.by_step: Dict[int, int] = defaultdict(int)
        self.by_src: Dict[int, int] = defaultdict(int)
        self.by_dst: Dict[int, int] = defaultdict(int)

    def record(self, step: int, src: int, dst: int, nbytes: int) -> None:
        self.total_bytes += nbytes
        self.num_messages += 1
        self.by_edge[(src, dst)] += nbytes
        self.by_step[step] += nbytes
        self.by_src[src] += nbytes
        self.by_dst[dst] += nbytes

    def bytes_per_step(self, num_steps: int) -> float:
        """Total traffic amortized over the run length."""
        return self.total_bytes / max(num_steps, 1)

    def received_per_client_step(self, num_steps: int) -> Dict[int, float]:
        """Amortized inbound bytes per client — the per-student cost the
        paper compares against FedAvg's full-model transfer."""
        return {dst: b / max(num_steps, 1)
                for dst, b in sorted(self.by_dst.items())}

    def summary(self) -> Dict[str, float]:
        return {
            "total_bytes": float(self.total_bytes),
            "num_messages": float(self.num_messages),
            "num_edges": float(len(self.by_edge)),
            "max_edge_bytes": float(max(self.by_edge.values(), default=0)),
        }

    def format_table(self) -> str:
        lines = ["edge          bytes"]
        for (src, dst), b in sorted(self.by_edge.items()):
            lines.append(f"{src:>3} -> {dst:<3}  {b:>12,}")
        lines.append(f"total        {self.total_bytes:>12,} "
                     f"({self.num_messages} messages)")
        return "\n".join(lines)
