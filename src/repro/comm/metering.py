"""Bytes-per-edge-per-step ledger for the prediction exchange.

The ledger keeps two books:

  * **offered** traffic — every ``bus.publish`` send is recorded as
    (step, src, dst, nbytes) via ``record``: the sender-side cost, spent
    whether or not the network drops the message.
  * **delivered** traffic — every message that actually reaches a
    mailbox is recorded via ``record_delivery`` (called by
    ``bus.deliver``): the receiver-side §3.2 accounting. On a lossless
    transport the books agree; with drops, delivered ≤ offered and the
    gap is exactly the lost bytes.

It answers the paper's §3.2 accounting questions from *measured*
traffic: total bytes, per-edge totals, per-step totals, and amortized
bytes-per-client-step (publishes happen every S_P steps but cover S_P
public batches, so the amortized figure is the one comparable to
`benchmarks/comm_efficiency._mhd_bytes_per_step`).

The ledger also tracks the *bounded-staleness gate* of the async runtime
(`RunConfig.max_staleness`): every time a client assembles teachers,
``record_gate`` counts how many sampled pool entries were fresh enough to
distill from and how many were skipped as stale/expired — the per-client
freshness economy that `benchmarks/async_staleness.py` sweeps.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

Edge = Tuple[int, int]


class CommMeter:
    def __init__(self):
        self.total_bytes = 0
        self.num_messages = 0
        self.by_edge: Dict[Edge, int] = defaultdict(int)
        self.by_step: Dict[int, int] = defaultdict(int)
        self.by_src: Dict[int, int] = defaultdict(int)
        self.by_dst: Dict[int, int] = defaultdict(int)
        # delivered (receiver-side) book — see record_delivery
        self.delivered_bytes = 0
        self.delivered_messages = 0
        self.by_edge_delivered: Dict[Edge, int] = defaultdict(int)
        self.by_dst_delivered: Dict[int, int] = defaultdict(int)
        # bounded-staleness gate counters (async runtime)
        self.gate_fresh: Dict[int, int] = defaultdict(int)
        self.gate_stale: Dict[int, int] = defaultdict(int)
        self.rejected_publishes = 0  # non-finite payloads refused by codecs
        # tombstoned book (elastic fleets): messages addressed to a client
        # that was dead at delivery time — offered, never delivered; the
        # churn analogue of a transport drop (`repro.fleet.membership`)
        self.tombstoned_messages = 0
        self.tombstoned_bytes = 0
        self.by_dst_tombstoned: Dict[int, int] = defaultdict(int)
        self.by_edge_tombstoned: Dict[Edge, int] = defaultdict(int)

    def record(self, step: int, src: int, dst: int, nbytes: int) -> None:
        """One *offered* send (sender-side cost; drops included)."""
        self.total_bytes += nbytes
        self.num_messages += 1
        self.by_edge[(src, dst)] += nbytes
        self.by_step[step] += nbytes
        self.by_src[src] += nbytes
        self.by_dst[dst] += nbytes

    def record_delivery(self, step: int, src: int, dst: int,
                        nbytes: int) -> None:
        """One message that actually arrived in ``dst``'s mailbox —
        dropped/in-flight messages never reach this book, so receiver-side
        statistics exclude them."""
        self.delivered_bytes += nbytes
        self.delivered_messages += 1
        self.by_edge_delivered[(src, dst)] += nbytes
        self.by_dst_delivered[dst] += nbytes

    def record_tombstone(self, step: int, src: int, dst: int,
                         nbytes: int) -> None:
        """One message whose destination was dead when it arrived (client
        churn): the sender paid for it (offered book), the student never
        saw it. Keeps delivered ≤ offered with the gap attributable."""
        self.tombstoned_messages += 1
        self.tombstoned_bytes += nbytes
        self.by_dst_tombstoned[dst] += nbytes
        self.by_edge_tombstoned[(src, dst)] += nbytes

    def record_gate(self, client: int, fresh: int, stale: int) -> None:
        """One teacher-assembly event: ``fresh`` sampled pool entries
        passed the staleness gate, ``stale`` were skipped (expired window
        or older than ``max_staleness``)."""
        self.gate_fresh[client] += fresh
        self.gate_stale[client] += stale

    def stale_fraction(self, client: int) -> float:
        """Fraction of this client's sampled teachers skipped as stale
        (0.0 when the client never sampled any)."""
        total = self.gate_fresh[client] + self.gate_stale[client]
        return self.gate_stale[client] / total if total else 0.0

    def gate_summary(self) -> Dict[int, Dict[str, float]]:
        clients = sorted(set(self.gate_fresh) | set(self.gate_stale))
        return {c: {"fresh": float(self.gate_fresh[c]),
                    "stale": float(self.gate_stale[c]),
                    "stale_frac": self.stale_fraction(c)}
                for c in clients}

    def bytes_per_step(self, num_steps: int) -> float:
        """Total traffic amortized over the run length."""
        return self.total_bytes / max(num_steps, 1)

    def received_per_client_step(self, num_steps: int) -> Dict[int, float]:
        """Amortized *delivered* inbound bytes per client — the
        per-student cost the paper compares against FedAvg's full-model
        transfer. Counts the delivered book: a dropped message costs the
        sender (offered) but never the student."""
        return {dst: b / max(num_steps, 1)
                for dst, b in sorted(self.by_dst_delivered.items())}

    def summary(self) -> Dict[str, float]:
        return {
            "total_bytes": float(self.total_bytes),
            "num_messages": float(self.num_messages),
            "delivered_bytes": float(self.delivered_bytes),
            "delivered_messages": float(self.delivered_messages),
            "num_edges": float(len(self.by_edge)),
            "max_edge_bytes": float(max(self.by_edge.values(), default=0)),
            "stale_skips": float(sum(self.gate_stale.values())),
            "rejected_publishes": float(self.rejected_publishes),
            "tombstoned_messages": float(self.tombstoned_messages),
            "tombstoned_bytes": float(self.tombstoned_bytes),
        }

    # -- snapshot/restore (repro.fleet) ----------------------------------

    def state_dict(self) -> Dict[str, object]:
        """Both books + gate/tombstone counters, JSON-pure (edge-tuple
        keys become "src-dst" strings)."""
        def edges(d: Dict[Edge, int]) -> Dict[str, int]:
            return {f"{s}-{t}": int(v) for (s, t), v in d.items()}

        def ints(d: Dict[int, int]) -> Dict[str, int]:
            return {str(k): int(v) for k, v in d.items()}

        return {
            "total_bytes": self.total_bytes,
            "num_messages": self.num_messages,
            "by_edge": edges(self.by_edge),
            "by_step": ints(self.by_step),
            "by_src": ints(self.by_src),
            "by_dst": ints(self.by_dst),
            "delivered_bytes": self.delivered_bytes,
            "delivered_messages": self.delivered_messages,
            "by_edge_delivered": edges(self.by_edge_delivered),
            "by_dst_delivered": ints(self.by_dst_delivered),
            "gate_fresh": ints(self.gate_fresh),
            "gate_stale": ints(self.gate_stale),
            "rejected_publishes": self.rejected_publishes,
            "tombstoned_messages": self.tombstoned_messages,
            "tombstoned_bytes": self.tombstoned_bytes,
            "by_dst_tombstoned": ints(self.by_dst_tombstoned),
            "by_edge_tombstoned": edges(self.by_edge_tombstoned),
        }

    def load_state_dict(self, state: Dict[str, object]) -> None:
        def edges(d) -> Dict[Edge, int]:
            out: Dict[Edge, int] = defaultdict(int)
            for k, v in d.items():
                s, t = k.split("-")
                out[(int(s), int(t))] = int(v)
            return out

        def ints(d) -> Dict[int, int]:
            out: Dict[int, int] = defaultdict(int)
            for k, v in d.items():
                out[int(k)] = int(v)
            return out

        self.total_bytes = int(state["total_bytes"])
        self.num_messages = int(state["num_messages"])
        self.by_edge = edges(state["by_edge"])
        self.by_step = ints(state["by_step"])
        self.by_src = ints(state["by_src"])
        self.by_dst = ints(state["by_dst"])
        self.delivered_bytes = int(state["delivered_bytes"])
        self.delivered_messages = int(state["delivered_messages"])
        self.by_edge_delivered = edges(state["by_edge_delivered"])
        self.by_dst_delivered = ints(state["by_dst_delivered"])
        self.gate_fresh = ints(state["gate_fresh"])
        self.gate_stale = ints(state["gate_stale"])
        self.rejected_publishes = int(state["rejected_publishes"])
        self.tombstoned_messages = int(state["tombstoned_messages"])
        self.tombstoned_bytes = int(state["tombstoned_bytes"])
        self.by_dst_tombstoned = ints(state["by_dst_tombstoned"])
        # absent in SNAPSHOT_VERSION=1 fleet snapshots (pre-obs)
        self.by_edge_tombstoned = edges(state.get("by_edge_tombstoned", {}))

    def format_table(self) -> str:
        lines = ["edge         offered bytes   delivered    tombstoned"]
        # union of all three books: a multi-process per-rank meter has
        # outbound-only offered edges and inbound-only delivered edges;
        # a churned fleet has tombstone-only edges (dst died mid-run)
        edges = sorted(set(self.by_edge) | set(self.by_edge_delivered)
                       | set(self.by_edge_tombstoned))
        for (src, dst) in edges:
            b = self.by_edge.get((src, dst), 0)
            d = self.by_edge_delivered.get((src, dst), 0)
            ts = self.by_edge_tombstoned.get((src, dst), 0)
            lines.append(
                f"{src:>3} -> {dst:<3}  {b:>12,}  {d:>12,}  {ts:>12,}")
        lines.append(f"total        {self.total_bytes:>12,}  "
                     f"{self.delivered_bytes:>12,}  "
                     f"{self.tombstoned_bytes:>12,} "
                     f"({self.num_messages} sent, "
                     f"{self.delivered_messages} delivered, "
                     f"{self.tombstoned_messages} tombstoned)")
        return "\n".join(lines)
