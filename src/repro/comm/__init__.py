"""repro.comm — the prediction-exchange wire subsystem (paper §3.2).

The paper's clients learn from each other "without having to share their
data, weights or weight updates": only a few top-confidence predictions
per public sample cross the wire. This package is that wire, as an actual
subsystem instead of a simulation shortcut:

  wire.py       codecs — dense f32/f16, top-k packed (vals, idx, lse)
                reusing the `kernels/topk_wire` packing, int8-quantized
                embeddings; byte-exact serialize/decode, byte accounting.
  transport.py  how bytes move — in-process loopback, and a simulated
                network with per-edge latency (in steps), bandwidth caps
                and drop probability.
  socket.py     the same interface over real TCP on localhost: length-
                prefixed frames, per-edge connections from the graph, a
                non-blocking drain — the transport behind the
                multi-process gossip runner (`launch/gossip.py`).
  bus.py        per-edge mailboxes driven by the graph G_t from
                `core/graph.py`; staleness stamps; `PredictionPool`, the
                prediction twin of the param `CheckpointPool`.
  metering.py   bytes-per-edge-per-step ledger (measured §3.2 accounting).

`core/runtime.py` consumes all of it via ``exchange="prediction_topk"``
(or ``"prediction_dense"``): every S_P steps a client *publishes* packed
predictions on its upcoming public batches, students decode mail instead
of re-running neighbor forward passes, and params never leave a client.
`core/mhd_distributed.py` and `benchmarks/comm_efficiency.py` share the
codecs; `examples/comm_gossip.py` runs a 4-client ring over a lossy,
bandwidth-capped link.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.comm.bus import (
    Mail,
    PredictionBus,
    PredictionPool,
    PredictionWindow,
)
from repro.comm.metering import CommMeter
from repro.comm.socket import SocketTransport, allocate_ports
from repro.comm.transport import (
    Delivery,
    EdgeSpec,
    LoopbackTransport,
    SimulatedNetwork,
    Transport,
)
from repro.comm.wire import (
    Codec,
    DenseCodec,
    NonFiniteError,
    PredictionMessage,
    TopKCodec,
    dense_frame_nbytes,
    densify_topk,
    topk_frame_nbytes,
)


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Knobs of the prediction exchange (runtime ``exchange != "params"``).

    horizon: how many upcoming public batches one publish covers (W).
      0 = auto: S_P (`pool_update_every`) — fresh predictions arrive just
      as the previous window runs out. Set ≥ pool_size·S_P to emulate the
      param pool's full staleness range (the equivalence-test setting).
    budget_bytes_per_token: the entropy-adaptive wire's per-token byte
      budget for the variable (val, idx) entry streams
      (``exchange="prediction_adaptive"``; `repro.lm.adaptive_wire`).
      0 = unbounded — byte-identical to the fixed TopKCodec.
    compression: "none" | "delta" — "delta" wraps the codec in
      `repro.lm.compress.CompressedCodec` (XOR-delta + bit-packed index
      streams); "none" is today's frames byte-for-byte.
    """
    topk: int = 32
    val_dtype: str = "float16"  # "float16" | "float32"
    emb_encoding: str = "int8"  # "int8" | "float32" | "none"
    tail: str = "uniform"  # truncated-mass handling, see wire.densify_topk
    horizon: int = 0
    budget_bytes_per_token: int = 0
    compression: str = "none"  # "none" | "delta"


def make_codec(exchange: str, cfg: CommConfig) -> Codec:
    if exchange == "prediction_topk":
        codec: Codec = TopKCodec(cfg.topk, val_dtype=cfg.val_dtype,
                                 emb_encoding=cfg.emb_encoding,
                                 tail=cfg.tail)
    elif exchange == "prediction_adaptive":
        from repro.lm.adaptive_wire import AdaptiveTopKCodec

        codec = AdaptiveTopKCodec(
            cfg.topk, budget_bytes_per_token=cfg.budget_bytes_per_token,
            val_dtype=cfg.val_dtype, emb_encoding=cfg.emb_encoding,
            tail=cfg.tail)
    elif exchange == "prediction_dense":
        codec = DenseCodec(logit_dtype="float32",
                           emb_encoding=cfg.emb_encoding)
    else:
        raise ValueError(f"unknown prediction exchange mode: {exchange!r}")
    if cfg.compression == "delta":
        from repro.lm.compress import CompressedCodec

        codec = CompressedCodec(codec)
    elif cfg.compression != "none":
        raise ValueError(f"unknown wire compression: {cfg.compression!r}")
    return codec


__all__ = [
    "Codec",
    "CommConfig",
    "CommMeter",
    "Delivery",
    "DenseCodec",
    "EdgeSpec",
    "LoopbackTransport",
    "Mail",
    "NonFiniteError",
    "PredictionBus",
    "PredictionMessage",
    "PredictionPool",
    "PredictionWindow",
    "SimulatedNetwork",
    "SocketTransport",
    "TopKCodec",
    "Transport",
    "allocate_ports",
    "dense_frame_nbytes",
    "densify_topk",
    "make_codec",
    "topk_frame_nbytes",
]
