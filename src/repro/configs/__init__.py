"""Architecture config registry.

Every assigned architecture exposes:
  full()    -> exact assigned config (used ONLY via lower/compile dry-runs)
  reduced() -> smoke-test variant (<=2 repeat units, d_model<=512, <=4 experts)

``get_config(name)`` / ``get_reduced(name)`` look up by arch id.
"""
from __future__ import annotations

import importlib

from repro.common.registry import Registry

ARCHS = Registry("architecture")

_MODULES = [
    "gemma3_27b",
    "gemma3_12b",
    "llama_3_2_vision_90b",
    "qwen2_5_32b",
    "mamba2_370m",
    "minitron_4b",
    "whisper_large_v3",
    "deepseek_v3_671b",
    "zamba2_7b",
    "arctic_480b",
    "resnet",
]

ARCH_IDS = [
    "gemma3-27b",
    "gemma3-12b",
    "llama-3.2-vision-90b",
    "qwen2.5-32b",
    "mamba2-370m",
    "minitron-4b",
    "whisper-large-v3",
    "deepseek-v3-671b",
    "zamba2-7b",
    "arctic-480b",
]


def _load():
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


_load()


def get_config(name: str):
    return ARCHS.get(name)["full"]()


def get_reduced(name: str):
    return ARCHS.get(name)["reduced"]()


def arch_ids():
    return list(ARCH_IDS)
