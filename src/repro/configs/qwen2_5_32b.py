"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""
from repro.configs import ARCHS
from repro.models.config import LayerSpec, ModelConfig, uniform_stages

_SPEC = LayerSpec(attn="full", ffn="dense")


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        stages=uniform_stages(64, _SPEC),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        max_seq_len=131072,
        num_aux_heads=2,
        source="hf:Qwen/Qwen2.5-0.5B (family card), 32B variant",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-reduced",
        family="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        stages=uniform_stages(2, _SPEC),
        qkv_bias=True,
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        max_seq_len=2048,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("qwen2.5-32b")({"full": full, "reduced": reduced})
