"""deepseek-v3-671b [moe] — 61L d_model=7168 128H MLA, vocab=129280,
MoE 1 shared + 256 routed top-8 (expert d_ff=2048), sigmoid scoring,
multi-token prediction (MTP). First 3 layers dense (d_ff=18432).
[arXiv:2412.19437]"""
import dataclasses

from repro.configs import ARCHS
from repro.models.config import (
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    Stage,
)

_DENSE = LayerSpec(attn="full", ffn="dense")
_MOE = LayerSpec(attn="full", ffn="moe")


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,  # MLA: per-head KV is decompressed from the latent
        head_dim=128,
        d_ff=18432,  # dense layers (first 3)
        vocab_size=129280,
        stages=(
            Stage(block=(_DENSE,), repeats=3),
            Stage(block=(_MOE,), repeats=58),
        ),
        moe=MoEConfig(
            num_experts=256,
            top_k=8,
            d_ff_expert=2048,
            num_shared_experts=1,
            capacity_factor=1.25,
            router_aux_weight=0.0001,  # v3 uses bias-based balancing; tiny aux
        ),
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe_scoring="sigmoid",
        moe_impl="a2a",  # expert-parallel a2a dispatch (EXPERIMENTS §Perf B)
        mtp=True,
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        max_seq_len=131072,
        num_aux_heads=2,
        loss_impl="chunked",
        loss_chunk=512,  # time-axis chunks (EXPERIMENTS §Perf B5)
        source="arXiv:2412.19437 (DeepSeek-V3)",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-reduced",
        family="moe",
        num_layers=3,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        stages=(
            Stage(block=(_DENSE,), repeats=1),
            Stage(block=(_MOE,), repeats=2),
        ),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      num_shared_experts=1, capacity_factor=1.5),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32),
        moe_scoring="sigmoid",
        moe_impl="a2a",  # expert-parallel a2a dispatch (EXPERIMENTS §Perf B)
        mtp=True,
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        max_seq_len=2048,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("deepseek-v3-671b")({"full": full, "reduced": reduced})
