"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000, pruned nemotron (squared-ReLU MLP). [arXiv:2407.14679]"""
from repro.configs import ARCHS
from repro.models.config import LayerSpec, ModelConfig, uniform_stages

_SPEC = LayerSpec(attn="full", ffn="dense")


def full() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256000,
        stages=uniform_stages(32, _SPEC),
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="relu2",
        pos_embed="rope",
        max_seq_len=4096,
        num_aux_heads=2,
        source="arXiv:2407.14679 (Minitron), 4B pruned nemotron",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-reduced",
        family="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        stages=uniform_stages(2, _SPEC),
        norm="rmsnorm",
        act="relu2",
        pos_embed="rope",
        max_seq_len=2048,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("minitron-4b")({"full": full, "reduced": reduced})
