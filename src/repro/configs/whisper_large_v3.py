"""whisper-large-v3 [audio] — enc-dec, 32L(+32L enc) d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866, conv frontend STUB (precomputed frame embeddings).
Adaptation note (DESIGN.md §6): the assigned input shapes' seq_len is the
*encoder* frame count; decoder length is the model's 448 max target
positions. [arXiv:2212.04356]"""
from repro.configs import ARCHS
from repro.models.config import (
    AudioStubConfig,
    EncoderConfig,
    LayerSpec,
    ModelConfig,
    uniform_stages,
)

_SPEC = LayerSpec(attn="full", ffn="dense", cross_attn=True)


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,  # decoder layers; encoder adds 32 more (EncoderConfig)
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        stages=uniform_stages(32, _SPEC),
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
        pos_embed="learned",
        audio=AudioStubConfig(frame_dim=1280, decoder_len=448),
        encoder=EncoderConfig(num_layers=32),
        max_seq_len=448,
        num_aux_heads=2,
        source="arXiv:2212.04356 (Whisper), large-v3",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-reduced",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        stages=uniform_stages(2, _SPEC),
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
        pos_embed="learned",
        audio=AudioStubConfig(frame_dim=48, decoder_len=32),
        encoder=EncoderConfig(num_layers=2),
        max_seq_len=64,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("whisper-large-v3")({"full": full, "reduced": reduced})
