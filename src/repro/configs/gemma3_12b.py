"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global sliding-window, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.configs import ARCHS
from repro.models.config import LayerSpec, ModelConfig, patterned_stages

_PATTERN = [LayerSpec(attn="swa")] * 5 + [LayerSpec(attn="full")]


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        stages=patterned_stages(48, _PATTERN),
        window_size=1024,
        qk_norm=True,
        rope_theta=1_000_000.0,
        norm="rmsnorm",
        act="silu",
        tie_embeddings=True,
        scale_embeddings=True,
        pos_embed="rope",
        max_seq_len=131072,
        num_aux_heads=2,
        source="hf:google/gemma-3-1b-pt (family card), 12B variant",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-reduced",
        family="dense",
        num_layers=6,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        stages=patterned_stages(6, _PATTERN),
        window_size=32,
        qk_norm=True,
        norm="rmsnorm",
        act="silu",
        tie_embeddings=True,
        scale_embeddings=True,
        pos_embed="rope",
        max_seq_len=2048,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("gemma3-12b")({"full": full, "reduced": reduced})
