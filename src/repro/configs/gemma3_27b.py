"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global sliding-window, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.configs import ARCHS
from repro.models.config import LayerSpec, ModelConfig, patterned_stages

_LOCAL = LayerSpec(attn="swa", ffn="dense")
_GLOBAL = LayerSpec(attn="full", ffn="dense")
_PATTERN = [_LOCAL] * 5 + [_GLOBAL]


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        stages=patterned_stages(62, _PATTERN),
        window_size=1024,
        qk_norm=True,
        rope_theta=1_000_000.0,
        norm="rmsnorm",
        act="silu",
        tie_embeddings=True,
        scale_embeddings=True,
        pos_embed="rope",
        max_seq_len=131072,
        num_aux_heads=2,
        source="hf:google/gemma-3-1b-pt (family card), 27B variant",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-reduced",
        family="dense",
        num_layers=12,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        stages=patterned_stages(12, _PATTERN),
        window_size=64,
        qk_norm=True,
        norm="rmsnorm",
        act="silu",
        tie_embeddings=True,
        scale_embeddings=True,
        pos_embed="rope",
        max_seq_len=4096,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("gemma3-27b")({"full": full, "reduced": reduced})
