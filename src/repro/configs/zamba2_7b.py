"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64: Mamba2 backbone with a *shared* attention block applied every
6th layer (shared weights are closure constants, not scanned — DESIGN.md §5).
[arXiv:2411.15242]"""
from repro.configs import ARCHS
from repro.models.config import (
    LayerSpec,
    MambaConfig,
    ModelConfig,
    patterned_stages,
)

_M = LayerSpec(attn="mamba2", ffn="none")
_MS = LayerSpec(attn="mamba2", ffn="dense", shared_attn=True)
_PATTERN = [_M] * 5 + [_MS]


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        stages=patterned_stages(81, _PATTERN),
        mamba=MambaConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                          chunk_size=256),
        rope_theta=10_000.0,
        norm="rmsnorm",
        tie_embeddings=True,
        pos_embed="rope",
        max_seq_len=1_048_576,
        num_aux_heads=2,
        source="arXiv:2411.15242 (Zamba2-7B)",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-reduced",
        family="hybrid",
        num_layers=12,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        stages=patterned_stages(12, _PATTERN),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                          chunk_size=32),
        norm="rmsnorm",
        tie_embeddings=True,
        pos_embed="rope",
        max_seq_len=65536,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("zamba2-7b")({"full": full, "reduced": reduced})
