"""The paper's own client architectures: ResNet-18 / ResNet-34 (He et al.
2016) plus CPU-scale tiny variants used by the experiment harness.

These are registered alongside the assigned archs so the launcher can train
the *faithful* reproduction (`--arch resnet34-imagenet`) and the benchmark
harness can build heterogeneous ensembles (§4.5: one ResNet34 + 3×ResNet18).
"""
from repro.configs import ARCHS
from repro.models.resnet import (
    resnet18,
    resnet34,
    resnet_tiny,
    resnet_tiny34,
)

ARCHS.register("resnet18-imagenet")(
    {"full": lambda: resnet18(1000, num_aux_heads=4),
     "reduced": lambda: resnet_tiny(20, num_aux_heads=4)})

ARCHS.register("resnet34-imagenet")(
    {"full": lambda: resnet34(1000, num_aux_heads=4),
     "reduced": lambda: resnet_tiny34(20, num_aux_heads=4)})
