"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
dense-MoE hybrid: every layer has a dense residual MLP in parallel with a
128-expert top-2 MoE. [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs import ARCHS
from repro.models.config import (
    LayerSpec,
    MoEConfig,
    ModelConfig,
    uniform_stages,
)

_SPEC = LayerSpec(attn="full", ffn="moe_dense_parallel")


def full() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,  # the parallel dense residual MLP
        vocab_size=32000,
        stages=uniform_stages(35, _SPEC),
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            d_ff_expert=4864,
            num_shared_experts=0,
            capacity_factor=1.25,
            router_aux_weight=0.01,
        ),
        moe_impl="a2a",  # expert-parallel a2a dispatch (EXPERIMENTS §Perf B)
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        max_seq_len=4096,
        num_aux_heads=2,
        source="hf:Snowflake/snowflake-arctic-base",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-reduced",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        stages=uniform_stages(2, _SPEC),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=1.5),
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        max_seq_len=2048,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("arctic-480b")({"full": full, "reduced": reduced})
