"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, gated cross-attention image layers every 5th layer.
Vision frontend (ViT-H/14 + projector input 7680) is a STUB: input_specs()
provides precomputed patch embeddings (DESIGN.md §5).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs import ARCHS
from repro.models.config import (
    LayerSpec,
    ModelConfig,
    VisionStubConfig,
    patterned_stages,
)

# one gated cross-attn layer then four self-attn layers, repeated
_PATTERN = [LayerSpec(attn="cross")] + [LayerSpec(attn="full")] * 4


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        stages=patterned_stages(100, _PATTERN),
        rope_theta=500_000.0,
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        vision=VisionStubConfig(num_patches=1600, embed_dim=7680),
        max_seq_len=131072,
        num_aux_heads=2,
        source="hf:meta-llama/Llama-3.2-11B-Vision (family card), 90B variant",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-reduced",
        family="vlm",
        num_layers=10,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        stages=patterned_stages(10, _PATTERN),
        norm="rmsnorm",
        act="silu",
        pos_embed="rope",
        vision=VisionStubConfig(num_patches=16, embed_dim=48),
        max_seq_len=2048,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("llama-3.2-vision-90b")({"full": full, "reduced": reduced})
