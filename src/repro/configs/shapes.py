"""Assigned input shapes + ShapeDtypeStruct stand-ins for the dry-run.

INPUT_SHAPES are the four assigned (seq_len, global_batch) points. ``mode``
is derived per shape: train_4k lowers ``train_step``; prefill_32k lowers the
``prefill`` forward; decode shapes lower ``serve_step`` (one new token
against a seq_len KV cache).

``long_500k`` applicability is decided by ``supports_shape`` per DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.resnet import ResNetConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# archs allowed to run the 500k-context decode (DESIGN.md §6)
LONG_CONTEXT_ARCHS = {
    "mamba2-370m",  # O(1) SSM state
    "zamba2-7b",  # hybrid: mamba state + 1 shared-attn KV per 6 layers
    "gemma3-27b",  # sliding window: only 1-in-6 global layers keep 500k KV
    "gemma3-12b",
    "deepseek-v3-671b",  # MLA compressed 576-dim latent cache
}


def supports_shape(arch_name: str, cfg: Any, shape: InputShape) -> Optional[str]:
    """None if supported, else a human-readable skip reason."""
    if isinstance(cfg, ResNetConfig):
        if shape.mode != "train":
            return "cnn classifier: no autoregressive decode/prefill"
        return None
    if shape.name == "long_500k" and arch_name not in LONG_CONTEXT_ARCHS:
        return ("full-attention KV at 500k tokens is multi-TB; no "
                "sliding-window variant in the source model (DESIGN.md §6)")
    if cfg.family == "audio" and shape.name == "long_500k":
        return "whisper: 500k frames ≈ 2.9h audio exceeds the 30s design point"
    return None


def _token_batch(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    sds = jax.ShapeDtypeStruct
    specs: Dict[str, Any] = {}
    if cfg.family == "audio":
        # seq_len = encoder frame count; decoder length fixed at 448 (card max)
        specs["tokens"] = sds((batch, cfg.audio.decoder_len), jnp.int32)
        specs["audio_frames"] = sds((batch, seq, cfg.audio.frame_dim), jnp.bfloat16)
    else:
        specs["tokens"] = sds((batch, seq), jnp.int32)
        if cfg.vision is not None:
            specs["vision_embeds"] = sds(
                (batch, cfg.vision.num_patches, cfg.vision.embed_dim), jnp.bfloat16)
    return specs


def input_specs(cfg: Any, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for (arch, shape) — never allocates."""
    shape = INPUT_SHAPES[shape_name]
    sds = jax.ShapeDtypeStruct
    if isinstance(cfg, ResNetConfig):
        return {
            "images": sds((shape.global_batch, 224, 224, 3), jnp.bfloat16),
            "labels": sds((shape.global_batch,), jnp.int32),
        }
    if shape.mode in ("train", "prefill"):
        return _token_batch(cfg, shape.global_batch, shape.seq_len)
    # decode: one token + caches of length seq_len
    from repro.models.transformer import init_lm_cache

    caches = jax.eval_shape(
        lambda: init_lm_cache(cfg, shape.global_batch, shape.seq_len,
                              jnp.bfloat16))
    return {
        "token": sds((shape.global_batch, 1), jnp.int32),
        "caches": caches,
    }
