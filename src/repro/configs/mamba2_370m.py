"""mamba2-370m [ssm] — 48L d_model=1024 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs import ARCHS
from repro.models.config import LayerSpec, MambaConfig, ModelConfig, uniform_stages

_SPEC = LayerSpec(attn="mamba2", ffn="none")


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=32,  # d_inner / head_dim = 2048 / 64
        num_kv_heads=32,
        d_ff=0,
        vocab_size=50280,
        stages=uniform_stages(48, _SPEC),
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                          chunk_size=256),
        norm="rmsnorm",
        tie_embeddings=True,
        pos_embed="none",
        max_seq_len=1_048_576,
        num_aux_heads=2,
        source="arXiv:2405.21060 (Mamba2), 370m preset",
    ).validate()


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-reduced",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        stages=uniform_stages(2, _SPEC),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                          chunk_size=32),
        norm="rmsnorm",
        tie_embeddings=True,
        pos_embed="none",
        max_seq_len=65536,
        num_aux_heads=2,
        remat="none",
    ).validate()


ARCHS.register("mamba2-370m")({"full": full, "reduced": reduced})
