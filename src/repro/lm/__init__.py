"""repro.lm — heterogeneous-architecture LM distillation (ROADMAP item 4).

The decentralized MHD runtime learns a second modality: mixed fleets of
LM clients (SSM + dense transformer + MoE, from the model zoo's reduced
shapes) distill next-token predictions over a shared public token
stream through the existing metered gossip wire, with two wire upgrades
that only make sense at LM vocab sizes:

  pool.py          the public token pool + the `ModelBundle` wrapper
                   that turns token positions into MHD samples
                   (positions-as-samples, `core/lm_adapter.py`).
  adaptive_wire.py `AdaptiveTopKCodec` — per-token top-k chosen from
                   teacher entropy under a bytes/token budget; the
                   `CommMeter` ledger is the objective. Unbounded
                   budget == `TopKCodec` byte-for-byte.
  compress.py      `CompressedCodec` — XOR-delta + bit-packed index
                   streams as a composable wrapper codec, decode-exact.

Spec surface: ``DataSpec(kind="synthetic_text")``,
``WireSpec(exchange="prediction_adaptive", budget_bytes_per_token=...,
compression=...)``, the ``lm_ssm``/``lm_transformer``/``lm_moe`` client
archs and the ``lm_hetero`` preset. See docs/lm_distillation.md.
"""
from __future__ import annotations

from repro.lm.adaptive_wire import (
    AdaptiveTopKCodec,
    adaptive_frame_max_nbytes,
    densify_adaptive,
)
from repro.lm.compress import CompressedCodec, pack_bits, unpack_bits
from repro.lm.pool import lm_client_bundle, lm_wire_tokens, make_text_arrays

__all__ = [
    "AdaptiveTopKCodec",
    "CompressedCodec",
    "adaptive_frame_max_nbytes",
    "densify_adaptive",
    "lm_client_bundle",
    "lm_wire_tokens",
    "make_text_arrays",
    "pack_bits",
    "unpack_bits",
]
