"""Entropy-adaptive top-k prediction codec (ROADMAP item 4, wire half).

The fixed `TopKCodec` spends the same k entries on every token — a
teacher that is *certain* about a token (entropy ~0) wastes k-1 of
them, while a token it is uncertain about may deserve more than k. The
`AdaptiveTopKCodec` turns the byte ledger into the objective: given a
``budget_bytes_per_token`` it allocates retention *per token* from the
teacher's main-head entropy — spend bytes where the teacher is
uncertain — under a hard ceiling (the codec's k) and a floor
(``k_min``, never less than the top-1 prediction).

Frame layout (codec_id 3), riding the `PredictionMessage` format:

  sample_ids  (W, B)  u64      — unchanged: PublicPool keying holds
  k_per_token (W, N)  u16      — the retention plan, N tokens per window
  vals        (H, T)  f16/f32  — ragged streams packed per head,
  idx         (H, T)  u16/u32    token-major (T = sum of k_per_token)
  lse         (W, H, N) f32    — exact logsumexp, as the fixed codec
  emb_q/emb_scale | embedding  — unchanged embedding lane

Budget semantics: ``budget_bytes_per_token`` bounds the *variable* head
payload — the (val, idx) entry streams across all H heads — per token:
``vals.nbytes + idx.nbytes <= budget * N_tokens`` holds by construction
(the allocation is integer arithmetic over a compile-time entry size).
``lse``, ``sample_ids``, the embedding lane and the frame headers are
fixed, shape-computable overhead (`adaptive_frame_max_nbytes`). A
budget below the ``k_min`` floor is *exhausted*: every token still
travels with k_min entries — the wire never sends less than top-1.

Bitwise anchors (tested):
  * budget <= 0 (unbounded) delegates encoding entirely to the fixed
    `TopKCodec` — byte-for-byte identical payloads, codec_id 2 header
    included; `decode`/`densify` accept both frame kinds, so one
    codec instance serves a fleet mixing budgets.
  * the device path (jax.Array outputs) and the numpy path produce
    byte-identical payloads: all float math (top-k, entropy, the
    allocation itself) lives in one jitted graph
    (`kernels.ops.adaptive_topk_wire_frame`) called by *both* paths,
    and the ragged gather that drops each token's unspent tail is
    shared host-side numpy.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.wire import (Codec, NonFiniteError, PredictionMessage,
                             TopKCodec, _check_finite, _deserialize,
                             _serialize, _split_heads, _stack_heads)


def densify_adaptive(vals: np.ndarray, idx: np.ndarray, lse: np.ndarray,
                     k_per_token: np.ndarray, num_classes: int,
                     tail: str = "uniform") -> np.ndarray:
    """Reconstruct dense (W, H, N, C) logits from an adaptive frame.

    Same tail semantics as `wire.densify_topk`, per token: with
    tail="uniform" the truncated mass is spread over the non-retained
    classes so logsumexp(recon) == lse and top-1 confidence stays exact;
    a token whose k covers the whole vocab (or tail="drop") fills with
    -1e30.
    """
    lse = np.asarray(lse, np.float32)
    W, H, N = lse.shape
    vals = np.asarray(vals, np.float32)
    idx = np.asarray(idx, np.int64)
    kt = np.asarray(k_per_token, np.int64).reshape(-1)  # (W*N,)
    col = np.repeat(np.arange(W * N), kt)  # token of each packed entry
    lse_hn = np.moveaxis(lse, 1, 0).reshape(H, W * N)
    out = np.empty((H, W * N, num_classes), np.float32)
    for h in range(H):
        if tail == "drop":
            fill = np.full(W * N, -1e30, np.float32)
        else:
            retained = np.zeros(W * N, np.float32)
            np.add.at(retained, col, np.exp(vals[h] - lse_hn[h, col]))
            tail_mass = np.clip(1.0 - retained, 1e-30, None)
            denom = np.maximum(num_classes - kt, 1)
            fill = (lse_hn[h] + np.log(tail_mass / denom)).astype(
                np.float32)
            fill = np.where(kt >= num_classes, np.float32(-1e30), fill)
        out[h] = np.broadcast_to(fill[:, None],
                                 (W * N, num_classes)).copy()
        out[h, col, idx[h]] = vals[h]
    return np.moveaxis(out.reshape(H, W, N, num_classes), 0, 1)


class AdaptiveTopKCodec(Codec):
    """Per-token entropy-adaptive top-k under a bytes/token budget."""

    codec_id = 3

    def __init__(self, k: int, budget_bytes_per_token: int = 0,
                 k_min: int = 1, val_dtype: str = "float16",
                 emb_encoding: str = "int8", tail: str = "uniform",
                 use_pallas: Optional[bool] = None):
        if k > 0xFFFF:
            raise ValueError(f"adaptive k {k} exceeds the u16 "
                             "k_per_token plan")
        self.k = int(k)
        self.budget = int(budget_bytes_per_token)
        self.k_min = max(1, int(k_min))
        self.val_dtype = np.dtype("<f2" if val_dtype == "float16"
                                  else "<f4")
        self.emb_encoding = emb_encoding
        self.tail = tail
        self.use_pallas = use_pallas
        # the unbounded degenerate case IS the fixed codec (bitwise)
        self._fixed = TopKCodec(k, val_dtype=val_dtype,
                                emb_encoding=emb_encoding, tail=tail,
                                use_pallas=use_pallas)

    # -- encode ---------------------------------------------------------

    def encode(self, src, sent_step, t0, sample_ids, outs) -> bytes:
        if self.budget <= 0:
            # unbounded budget: byte-for-byte the fixed TopKCodec frame
            # (codec_id 2 on the wire; decode/densify accept it)
            return self._fixed.encode(src, sent_step, t0, sample_ids,
                                      outs)
        if isinstance(outs.get("logits"), jax.Array):
            return self._encode_device(src, sent_step, t0, sample_ids,
                                       outs)
        heads = _stack_heads(outs)
        _check_finite("logits", heads)
        C = int(heads.shape[-1])
        dev, finite = self._frame(jnp.asarray(heads), None, C)
        if not bool(finite):
            raise NonFiniteError(
                "non-finite values in prediction outputs (or their f16 "
                "wire cast): refusing to encode")
        arrays: Dict[str, np.ndarray] = {
            "sample_ids": np.asarray(sample_ids, np.uint64)}
        arrays.update(self._ragged_pack(dev))
        self._encode_emb(arrays, outs)
        return _serialize(PredictionMessage(src, sent_step, t0, C, arrays),
                          self.codec_id)

    def _encode_device(self, src, sent_step, t0, sample_ids, outs) -> bytes:
        """Fused encode: stacking, top-k, entropy, budget allocation,
        wire casts, embedding quantization and the finiteness checks in
        one jitted graph — byte-identical to the numpy path because the
        numpy path calls the *same* graph and shares the host-side
        ragged gather."""
        main = outs["logits"].astype(jnp.float32)[:, None]
        heads = jnp.concatenate(
            [main, outs["aux_logits"].astype(jnp.float32)], axis=1)
        C = int(heads.shape[-1])
        emb = outs.get("embedding") if self.emb_encoding != "none" else None
        dev, finite = self._frame(heads, emb, C)
        if not bool(finite):
            raise NonFiniteError(
                "non-finite values in prediction outputs (or their f16 "
                "wire cast): refusing to encode")
        arrays: Dict[str, np.ndarray] = {
            "sample_ids": np.asarray(sample_ids, np.uint64)}
        arrays.update(self._ragged_pack(dev))
        for name in ("emb_q", "emb_scale", "embedding"):
            if name in dev:
                arrays[name] = np.asarray(dev[name])
        return _serialize(PredictionMessage(src, sent_step, t0, C, arrays),
                          self.codec_id)

    def _frame(self, heads, emb, C: int):
        from repro.kernels import ops

        k = min(self.k, C)
        idx_dt = "uint16" if C <= 0xFFFF else "uint32"
        entry = self.val_dtype.itemsize + (2 if idx_dt == "uint16" else 4)
        return ops.adaptive_topk_wire_frame(
            heads, emb, k, k_min=min(self.k_min, k),
            budget_bytes_per_token=self.budget, entry_bytes=entry,
            val_dtype="float16" if self.val_dtype.itemsize == 2
            else "float32",
            idx_dtype=idx_dt, emb_encoding=self.emb_encoding,
            use_pallas=self.use_pallas)

    def _ragged_pack(self, dev: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Drop each token's unspent tail: rectangular (W, H, N, k)
        device arrays -> token-major packed streams (H, T). Plain numpy
        integer gathers, shared by both encode paths."""
        vals_r = np.asarray(dev["vals"])
        idx_r = np.asarray(dev["idx"])
        k_tok = np.asarray(dev["k_per_token"])  # (W, N) u16
        W, H, N, k = vals_r.shape
        kt = k_tok.reshape(W * N).astype(np.int64)
        keep = np.arange(k)[None, :] < kt[:, None]  # (W*N, k)
        vals_t = np.moveaxis(vals_r, 1, 0).reshape(H, W * N, k)
        idx_t = np.moveaxis(idx_r, 1, 0).reshape(H, W * N, k)
        return {
            "k_per_token": k_tok,
            "vals": vals_t[:, keep],
            "idx": idx_t[:, keep],
            "lse": np.asarray(dev["lse"], np.float32),
        }

    # -- decode ---------------------------------------------------------

    def decode(self, payload: bytes) -> PredictionMessage:
        msg, codec_id = _deserialize(payload)
        if codec_id not in (self.codec_id, TopKCodec.codec_id):
            raise ValueError(
                f"payload codec id {codec_id} not in "
                f"({self.codec_id}, {TopKCodec.codec_id})")
        return msg

    def densify(self, msg: PredictionMessage) -> Dict[str, np.ndarray]:
        if "k_per_token" not in msg.arrays:  # fixed-format (unbounded)
            return self._fixed.densify(msg)
        heads = densify_adaptive(
            msg.arrays["vals"], msg.arrays["idx"], msg.arrays["lse"],
            msg.arrays["k_per_token"], msg.num_classes, tail=self.tail)
        out = _split_heads(heads)
        emb = self._decode_emb(msg)
        if emb is not None:
            out["embedding"] = emb
        return out


def adaptive_frame_max_nbytes(window: int, seq_batch: int, tokens: int,
                              num_heads: int,
                              budget_bytes_per_token: int,
                              emb_dim: int = 0, val_bytes: int = 2,
                              idx_bytes: int = 2, k_min: int = 1,
                              emb_encoding: str = "int8") -> int:
    """Exact serialized-size ceiling of ONE adaptive frame (codec_id 3).

    The variable entry streams are bounded by the budget
    (``<= budget * window * tokens`` bytes by construction) — except
    when the budget sits below the ``k_min`` floor, where every token
    still travels with k_min entries (the wire never sends less than
    top-1), so the bound is the max of the two. Everything else —
    headers, sample_ids (window, seq_batch), the retention plan, lse
    and the embedding lane — is fixed overhead computed from the frame
    shape. The smoke asserts measured offered bytes against this
    ceiling, so the meter ledger IS the budget objective.
    """
    def arr(name: str, ndim: int, nbytes: int) -> int:
        return 1 + len(name) + 2 + 8 * ndim + nbytes

    N = window * tokens
    total = 40  # magic + <BBH> + <qqqq>
    total += arr("sample_ids", 2, window * seq_batch * 8)
    total += arr("k_per_token", 2, N * 2)
    total += arr("vals", 2, 0) + arr("idx", 2, 0)
    floor = num_heads * N * k_min * (val_bytes + idx_bytes)
    total += max(budget_bytes_per_token * N, floor)  # entry-stream bound
    total += arr("lse", 3, N * num_heads * 4)
    if emb_dim:
        if emb_encoding == "int8":
            total += arr("emb_q", 3, N * emb_dim)
            total += arr("emb_scale", 2, N * 4)
        elif emb_encoding != "none":
            total += arr("embedding", 3, N * emb_dim * 4)
    return total
