"""Public token pool + LM client glue (ROADMAP item 4, fleet half).

The decentralized runtime is modality-agnostic: it samples deterministic
public batches from a `PublicPool`, publishes teacher outputs over the
metered wire, and distills students against decoded windows. This module
supplies the *text* instantiation of that contract:

  * `make_text_arrays` — the deterministic public token stream:
    per-domain bigram languages (`data.synthetic.make_synthetic_text`)
    with the transition tables pinned by a separate ``table_seed``, so a
    test split shares the train split's domain languages the same way
    the vision sets share ``prototype_seed``. The arrays
    ({"tokens", "labels"}) drop into `PublicPool` / `BatchIterator`
    unchanged — windowed ``sample_ids`` stay per-*sequence*, so
    teacher-cache and serve→distill feedback keying holds.
  * `lm_client_bundle` — wraps any LM `ModelBundle` so its ``apply``
    returns the positions-as-samples MHD layout
    (`core.lm_adapter.lm_mhd_outputs`): every next-token position is one
    MHD sample carrying its own CE target ("labels") and its source
    sequence ("sample_rows", for per-domain eval aggregation). The
    `DecentralizedTrainer` needs no LM branch — it sees a bundle whose
    outputs happen to have B' = positions rows.

A mixed fleet (SSM + dense transformer + MoE) is then just three
`CLIENT_ARCHS` entries sharing an embedding width — see the
``lm_hetero`` preset and docs/lm_distillation.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.lm_adapter import lm_mhd_outputs
from repro.data.synthetic import make_synthetic_text
from repro.models.zoo import ModelBundle


def make_text_arrays(num_domains: int, sequences_per_domain: int,
                     seq_len: int, vocab_size: int,
                     temperature: float = 0.5, seed: int = 0,
                     table_seed: Optional[int] = None
                     ) -> Dict[str, np.ndarray]:
    """Array dict for the public/private text pools: {"tokens" (N, T) i32,
    "labels" (N,) i32 domain ids}."""
    ds = make_synthetic_text(
        num_domains=num_domains,
        sequences_per_domain=sequences_per_domain, seq_len=seq_len,
        vocab_size=vocab_size, temperature=temperature, seed=seed,
        table_seed=table_seed)
    return {"tokens": ds.tokens, "labels": ds.labels}


def lm_client_bundle(bundle: ModelBundle, max_positions: int = 0,
                     position_seed: Optional[int] = None) -> ModelBundle:
    """An LM bundle whose ``apply`` speaks the MHD client protocol.

    The wrapped apply returns {"embedding" (B', D), "logits" (B', V),
    "aux_logits" (m, B', V), "labels" (B',), "sample_rows" (B',),
    "aux_loss"} with B' = the (optionally seeded-subsampled) next-token
    positions of the batch. Every client and teacher of a fleet must
    share ``max_positions``/``position_seed`` so their position rows
    align — the spec (`DataSpec`) owns both knobs.
    """
    def apply(params, batch):
        out = lm_mhd_outputs(bundle, params, batch,
                             max_positions=max_positions,
                             position_seed=position_seed)
        return {k: v for k, v in out.items() if v is not None}

    return dataclasses.replace(bundle, apply=apply)


def lm_wire_tokens(batch_sequences: int, seq_len: int,
                   max_positions: int = 0) -> int:
    """Tokens per public batch on the wire: B·(T−1) next-token positions,
    truncated by ``max_positions`` — the N that bytes/token budgets and
    the smoke's ledger assertions are denominated in."""
    n = batch_sequences * (seq_len - 1)
    return min(n, max_positions) if max_positions else n
