"""Window compression for prediction frames (ROADMAP item 4, second
wire half).

`CompressedCodec` wraps ANY inner prediction codec: it encodes through
the inner codec, then rewrites the frame's index stream — the one array
whose values are small integers with heavy structure — as

  1. an XOR delta: consecutive windows of a rectangular top-k frame
     (axis 0), or consecutive entries of an adaptive frame's packed
     per-head stream (last axis). XOR (not subtraction) keeps the
     transform closed over the unsigned wire dtypes — bijective, so the
     decode is exact by construction.
  2. a fixed-width bit-pack: the delta stream is stored at the minimal
     bit width that holds its maximum value (e.g. a 512-vocab fleet's
     u16 indices travel at <= 10 bits after the delta).

The rewritten frame is re-serialized under codec_id 4 with the original
"idx" replaced in place by "idx_meta" (inner codec id, dtype, bit
width, delta axis, shape) + "idx_bits" (the packed bytes), preserving
array order; every other array is untouched. ``decode`` reconstructs
the inner frame bit-for-bit and ``densify`` delegates to the inner
codec — compression is invisible above the wire, visible only in the
`CommMeter` ledger.

Anchors: compression "none" never constructs this wrapper (today's
frames, byte-for-byte — see `repro.comm.make_codec`); an inner frame
without an index stream (dense layout) passes through unchanged, and
``decode`` accepts such passthrough frames via the inner codec.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.comm.wire import (_DTYPES, _DTYPE_CODES, Codec,
                             PredictionMessage, _deserialize, _serialize)


def pack_bits(values: np.ndarray, width: int) -> np.ndarray:
    """Pack non-negative integers into a little-endian bitstream of
    ``width`` bits each. Returns a u8 array of ceil(n*width/8) bytes."""
    v = np.ascontiguousarray(values, np.uint64).reshape(-1)
    if v.size == 0:
        return np.zeros(0, np.uint8)
    shifts = np.arange(width, dtype=np.uint64)
    bits = ((v[:, None] >> shifts[None, :]) & np.uint64(1)).astype(
        np.uint8)
    return np.packbits(bits.reshape(-1), bitorder="little")


def unpack_bits(packed: np.ndarray, count: int, width: int) -> np.ndarray:
    """Inverse of `pack_bits`: the first ``count`` ``width``-bit values."""
    if count == 0:
        return np.zeros(0, np.uint64)
    bits = np.unpackbits(np.asarray(packed, np.uint8),
                         count=count * width,
                         bitorder="little").reshape(count, width)
    shifts = np.arange(width, dtype=np.uint64)
    return (bits.astype(np.uint64) << shifts[None, :]).sum(
        axis=1, dtype=np.uint64)


def _xor_delta(idx: np.ndarray, axis: int) -> np.ndarray:
    out = idx.copy()
    head = [slice(None)] * idx.ndim
    tail = [slice(None)] * idx.ndim
    head[axis] = slice(1, None)
    tail[axis] = slice(0, -1)
    out[tuple(head)] = idx[tuple(head)] ^ idx[tuple(tail)]
    return out


class CompressedCodec(Codec):
    """Delta + bit-pack the index stream of an inner prediction codec."""

    codec_id = 4

    def __init__(self, inner: Codec):
        self.inner = inner
        self.emb_encoding = getattr(inner, "emb_encoding", "none")

    def encode(self, src, sent_step, t0, sample_ids, outs) -> bytes:
        payload = self.inner.encode(src, sent_step, t0, sample_ids, outs)
        msg, inner_id = _deserialize(payload)
        idx = msg.arrays.get("idx")
        if idx is None:  # no index stream (dense frame): passthrough
            return payload
        # rectangular frames delta across the window (axis 0);
        # adaptive packed streams delta along each head's stream
        axis = 0 if idx.ndim >= 3 else idx.ndim - 1
        delta = _xor_delta(idx, axis)
        width = max(1, int(delta.max()).bit_length()) if delta.size else 1
        dt = np.dtype(idx.dtype.newbyteorder("<"))
        arrays: Dict[str, np.ndarray] = {}
        for name, arr in msg.arrays.items():
            if name != "idx":
                arrays[name] = arr
                continue
            arrays["idx_meta"] = np.array(
                [inner_id, _DTYPE_CODES[dt], width, axis, idx.ndim]
                + list(idx.shape), "<u4")
            arrays["idx_bits"] = pack_bits(delta, width)
        return _serialize(
            PredictionMessage(msg.src, msg.sent_step, msg.t0,
                              msg.num_classes, arrays), self.codec_id)

    def decode(self, payload: bytes) -> PredictionMessage:
        head, codec_id = _deserialize(payload)
        if codec_id != self.codec_id:
            # an uncompressed passthrough frame: the inner codec owns it
            return self.inner.decode(payload)
        meta = np.asarray(head.arrays["idx_meta"], np.int64)
        inner_id, dt_code, width, axis, ndim = (int(v) for v in meta[:5])
        shape = tuple(int(v) for v in meta[5:5 + ndim])
        count = int(np.prod(shape)) if ndim else 1
        delta = unpack_bits(head.arrays["idx_bits"], count,
                            width).astype(_DTYPES[dt_code]).reshape(shape)
        idx = np.bitwise_xor.accumulate(delta, axis=axis)
        arrays: Dict[str, np.ndarray] = {}
        for name, arr in head.arrays.items():
            if name == "idx_meta":
                arrays["idx"] = idx
            elif name != "idx_bits":
                arrays[name] = arr
        return PredictionMessage(head.src, head.sent_step, head.t0,
                                 head.num_classes, arrays)

    def densify(self, msg: PredictionMessage) -> Dict[str, np.ndarray]:
        return self.inner.densify(msg)
