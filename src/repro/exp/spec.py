"""`ExperimentSpec` — the declarative, JSON-serializable description of one
decentralized-learning experiment.

A spec says *what* to run — data + partition protocol, the client fleet
(per-client architectures), the algorithm and its config, communication
topology, schedule (sync, lockstep, or out-of-order scoreboard), transport
+ wire format, optimizer, and the train/eval cadence — and `repro.exp.runner`
says *how*. Every block is a frozen dataclass; ``to_json``/``from_json``
round-trip exactly (asserted in tests), so a spec file is a complete,
shareable record of an experiment and new scenarios are spec edits, not
new harnesses.

Client architectures are resolved through the ``CLIENT_ARCHS`` registry
(`common/registry.py`), which maps an arch name to a model-config factory
``(num_labels, aux_heads, width) -> config`` consumable by
`models.zoo.build_bundle`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, Optional, Tuple

from repro.common.registry import Registry
from repro.models import resnet as _RN

# -- client architecture registry -------------------------------------------

CLIENT_ARCHS: Registry = Registry("client architecture")


# -- transport registry ------------------------------------------------------
#
# kind -> builder(spec) -> repro.comm.Transport | None (None = the trainer's
# default in-process loopback). ``validate`` checks membership and calls the
# builder's optional ``validate_spec`` attribute (structural checks, no
# construction); the runner's `build_transport` dispatches here — a new
# transport is a registry entry, not an edit to a hard-coded kind list.

TRANSPORTS: Registry[Callable[["ExperimentSpec"], Any]] = Registry(
    "transport kind")


def _reject_socket_fields(spec: "ExperimentSpec") -> None:
    t = spec.transport
    if t.base_port is not None or t.host != "127.0.0.1":
        raise ValueError(
            "transport base_port/host configure the socket transport; "
            f"kind={t.kind!r} would silently ignore them")


@TRANSPORTS.register("loopback")
def _loopback_transport(spec: "ExperimentSpec") -> Any:
    return None  # DecentralizedTrainer's default LoopbackTransport


_loopback_transport.validate_spec = _reject_socket_fields


@TRANSPORTS.register("simulated")
def _simulated_transport(spec: "ExperimentSpec") -> Any:
    from repro.comm import SimulatedNetwork

    t = spec.transport
    return SimulatedNetwork(latency=t.latency, bandwidth=t.bandwidth,
                            drop_prob=t.drop_prob, seed=t.seed,
                            client_rates=t.client_rates)


_simulated_transport.validate_spec = _reject_socket_fields


@TRANSPORTS.register("socket")
def _socket_transport(spec: "ExperimentSpec") -> Any:
    """One in-process instance hosting the whole fleet over real TCP —
    `Experiment.run()`'s view of ``kind="socket"``. The multi-process
    launcher (`launch/gossip.py`) builds one single-client instance per
    OS process instead, with ports rendezvoused between them."""
    from repro.comm import SocketTransport

    t = spec.transport
    ports = None
    if t.base_port is not None:
        ports = {i: t.base_port + i for i in range(spec.num_clients)}
    return SocketTransport(spec.num_clients, ports=ports, host=t.host)


def _socket_validate(spec: "ExperimentSpec") -> None:
    t = spec.transport
    if t.latency or t.bandwidth or t.drop_prob or t.client_rates:
        raise ValueError(
            "transport latency/bandwidth/drop_prob/client_rates "
            "parameterize the simulated network; a socket transport "
            "is a real wire and would silently ignore them")


_socket_transport.validate_spec = _socket_validate


@CLIENT_ARCHS.register("resnet_tiny")
def _resnet_tiny(num_labels: int, aux_heads: int, width: int):
    return _RN.resnet_tiny(num_labels, num_aux_heads=aux_heads, width=width)


@CLIENT_ARCHS.register("resnet_tiny34")
def _resnet_tiny34(num_labels: int, aux_heads: int, width: int):
    return _RN.resnet_tiny34(num_labels, num_aux_heads=aux_heads, width=width)


def _register_lm(arch_name: str, zoo_name: str) -> None:
    """Reduced LM zoo configs as fleet archs. ``num_labels`` carries the
    head dimension — the shared vocab of a text fleet (the runner passes
    ``data.vocab_size`` when ``data.kind == "synthetic_text"``) — and
    ``width`` the model dim, so heterogeneous backbones (SSM, dense
    transformer, MoE) expose identical head shapes to the MHD wire."""


    @CLIENT_ARCHS.register(arch_name)
    def _factory(num_labels: int, aux_heads: int, width: int):
        from repro.configs import get_reduced

        return dataclasses.replace(
            get_reduced(zoo_name), vocab_size=num_labels,
            d_model=width, num_aux_heads=aux_heads)


_register_lm("lm_ssm", "mamba2-370m")
_register_lm("lm_transformer", "gemma3-12b")
_register_lm("lm_moe", "arctic-480b")


# -- spec blocks -------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Synthetic class-conditional dataset (DESIGN.md §7.1 CPU scale).

    The test set is drawn from the same class prototypes
    (``prototype_seed = seed``) with sample seed ``seed + 991`` — the
    convention every benchmark harness used.

    ``kind="synthetic_text"`` (per-domain bigram LMs,
    `data.synthetic.make_synthetic_text`) reuses the label fields as
    their text twins: ``num_labels`` = number of domains,
    ``samples_per_label`` = sequences per domain — β metrics then
    aggregate per domain exactly as per class. The test split pins the
    domain languages with ``table_seed = seed`` and draws samples from
    ``seed + 991``. ``vocab_size``/``seq_len`` shape the sequences;
    ``max_positions`` bounds the per-batch token positions entering MHD
    (0 = all ``batch·(seq_len−1)``) and ``position_seed`` picks them as
    a fixed random subset instead of the biased batch-head prefix
    (`core/lm_adapter.lm_mhd_outputs`)."""

    kind: str = "synthetic_vision"
    num_labels: int = 16
    samples_per_label: int = 200
    image_size: int = 8
    noise: float = 2.0
    test_samples_per_label: int = 15
    seed: int = 0
    vocab_size: int = 64  # text: shared vocab (= every client's head dim)
    seq_len: int = 16  # text: tokens per sequence
    max_positions: int = 0  # text: MHD positions per batch; 0 = all
    position_seed: Optional[int] = None  # text: None = prefix truncation


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """Paper §3.3 protocol: public pool fraction γ_pub + skewed shards."""

    labels_per_client: int = 4
    assignment: str = "random"  # "random" | "even"
    skew: float = 100.0  # the paper's s
    gamma_pub: float = 0.1
    even_multiplicity: int = 2
    seed: Optional[int] = None  # None = DataSpec.seed


@dataclasses.dataclass(frozen=True)
class ClientSpec:
    """One fleet member. Heterogeneous fleets list different archs."""

    arch: str = "resnet_tiny"
    aux_heads: int = 0
    width: int = 8


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """Which `Algorithm` adapter runs, plus its free-form config.

    ``params`` is passed to the adapter (e.g. MHD: ``nu_emb``, ``nu_aux``,
    ``delta``, ``pool_size``, ``pool_update_every``, ...; fedmd:
    ``digest_weight``; fedavg: ``average_every``; supervised: ``scope``).
    Adapters validate the keys they understand."""

    name: str = "mhd"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Communication graph G_t (`core/graph.py`)."""

    name: str = "complete"  # complete|cycle|chain|islands|isolated
    hops: int = 1  # cycle reach
    islands: int = 2  # islands count


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Stepping model: the synchronous loop or the scoreboard runtime.

    ``mode="lockstep"`` drives the algorithm with per-client logical
    clocks in strict wall-tick order (`core/scheduler.AsyncScheduler`;
    ``"async"`` is the historical alias), ``mode="scoreboard"`` issues
    each client's LocalStep/Publish/Pull/Resolve ops the moment their
    dependencies are satisfied (`core/scheduler.ScoreboardScheduler`).
    ``train.steps`` then counts wall ticks. ``rates[i]`` is wall ticks
    per local step of client i (None = uniform 1×).

    Scoreboard-only knobs: ``runahead`` bounds how many wall ticks a
    client may advance past its slowest in-neighbor before backpressure
    stalls it (None = unbounded); ``pace_ms[i]`` is client i's minimum
    real milliseconds between local steps (None = unpaced)."""

    mode: str = "sync"  # "sync" | "lockstep" (alias "async") | "scoreboard"
    rates: Optional[Tuple[int, ...]] = None
    runahead: Optional[int] = None
    pace_ms: Optional[Tuple[float, ...]] = None


@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """How published bytes move — resolved through the ``TRANSPORTS``
    registry (built-in kinds: "loopback", "simulated", "socket").

    ``latency``/``bandwidth``/``drop_prob``/``client_rates`` parameterize
    the simulated network only; a socket transport is a real wire whose
    behavior comes from the host network. ``base_port``/``host`` apply to
    sockets: ``base_port=None`` binds OS-assigned ports (in-process runs);
    an explicit base gives client i port ``base_port + i`` (the
    fixed-rendezvous option for multi-process runs)."""

    kind: str = "loopback"  # any registered TRANSPORTS kind
    latency: int = 0  # wall ticks of propagation
    bandwidth: Optional[int] = None  # bytes per wall tick; None = unlimited
    drop_prob: float = 0.0
    seed: int = 0
    client_rates: Optional[Dict[int, int]] = None  # slow uplinks (async)
    base_port: Optional[int] = None  # socket: client i listens on base+i
    host: str = "127.0.0.1"  # socket: bind/connect address


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """What crosses the wire (`repro.comm.wire`).

    ``exchange="params"`` is the legacy simulation shortcut (raw
    parameters, nothing metered); the prediction modes are the paper's
    §3.2 protocol. ``"prediction_adaptive"`` is the entropy-adaptive
    top-k wire (`repro.lm.adaptive_wire`): k varies per token under
    ``budget_bytes_per_token`` (0 = unbounded — byte-identical to
    ``"prediction_topk"``). ``compression="delta"`` wraps whichever
    codec in the XOR-delta + bit-packed index stream
    (`repro.lm.compress`); ``"none"`` is today's frames byte-for-byte."""

    exchange: str = "params"  # params|prediction_{topk,dense,adaptive}
    topk: int = 32
    val_dtype: str = "float16"
    emb_encoding: str = "int8"
    tail: str = "uniform"
    horizon: int = 0  # 0 = auto (S_P)
    budget_bytes_per_token: int = 0  # adaptive: (val,idx) bytes/token cap
    compression: str = "none"  # "none" | "delta"


@dataclasses.dataclass(frozen=True)
class ChurnEventSpec:
    """One scripted fleet event (`repro.fleet.events`), in wall steps.

    ``kind``: "kill" | "restart" | "join" | "rewire". ``client`` names
    the affected client (kill/restart/join); ``from_snapshot`` picks the
    restart source (latest fleet snapshot vs fresh re-init); ``arch`` is
    documentation for joins (the fleet's ClientSpec list owns the
    architecture); ``edges`` is a full adjacency for rewires."""

    kind: str
    step: int
    client: Optional[int] = None
    from_snapshot: bool = True
    arch: Optional[str] = None
    edges: Optional[Tuple[Tuple[int, ...], ...]] = None


@dataclasses.dataclass(frozen=True)
class ChurnSpec:
    """The scripted churn timeline — empty means a static fleet (the
    pre-fleet behavior, unchanged)."""

    events: Tuple[ChurnEventSpec, ...] = ()


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    """Mirror of `optim.optimizers.OptimizerConfig`; ``total_steps=None``
    follows ``train.steps``."""

    name: str = "sgd_momentum"
    init_lr: float = 0.05
    total_steps: Optional[int] = None
    warmup_steps: int = 0
    momentum: float = 0.9
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = None
    state_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Loop cadence: steps (wall ticks when async), batching, eval and
    checkpoint rhythm. ``eval_every=0`` = final evaluation only.

    ``checkpoint_*`` is the plain params-only checkpoint
    (`checkpoint/io`); ``snapshot_*`` is the full *fleet* snapshot
    (`repro.fleet.snapshot`: params + opt + pools + mailboxes + clocks +
    stream positions — the bitwise-resume and churn-restart unit).
    ``snapshot_every=0`` disables snapshotting."""

    steps: int = 600
    batch_size: int = 32
    public_batch_size: int = 32
    eval_every: int = 0
    eval_batch_size: int = 256
    max_staleness: Optional[int] = None
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0  # 0 = final only (when checkpoint_dir is set)
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 0  # fleet snapshots every N steps; 0 = never
    trace_dir: Optional[str] = None  # repro.obs traces land here; None = off


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """The inference path (`repro.serve`): serve the trained fleet from
    its snapshot, optionally feeding served traffic back as the public
    distillation stream.

    ``requests=0`` disables serving (the default — training specs are
    unchanged). The serve block is consumed by
    `repro.serve.run_serve_scenario` (via ``launch/serve.py --preset`` or
    `benchmarks/serve.py`), *after* training; `Experiment.run()` itself
    never serves. ``engine_arch`` names a reduced zoo LM config
    (`repro.configs.get_reduced`) for the continuous-batching decode
    engine; ``None`` serves the classify/teacher paths only.
    ``feedback_steps`` distills that many extra steps from the served
    `TrafficLog` (needs a prediction exchange — the feedback rides the
    metered wire)."""

    requests: int = 0  # mixed classify/teacher queries; 0 = disabled
    router: str = "label_affinity"  # client_id|label_affinity|round_robin
    num_slots: int = 4  # continuous-batching engine lanes
    max_new_tokens: int = 16  # decode budget per generate request
    engine_arch: Optional[str] = None  # reduced LM config name; None = off
    cache_windows: int = 8  # teacher-cache LRU capacity
    teachers: Optional[Tuple[int, ...]] = None  # None = the whole fleet
    feedback_steps: int = 0  # serve→distill steps on served traffic
    seed: int = 0  # request stream + engine params


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    name: str = "experiment"
    algorithm: AlgorithmSpec = dataclasses.field(default_factory=AlgorithmSpec)
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    partition: PartitionSpec = dataclasses.field(
        default_factory=PartitionSpec)
    clients: Tuple[ClientSpec, ...] = (ClientSpec(),) * 4
    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)
    schedule: ScheduleSpec = dataclasses.field(default_factory=ScheduleSpec)
    transport: TransportSpec = dataclasses.field(
        default_factory=TransportSpec)
    wire: WireSpec = dataclasses.field(default_factory=WireSpec)
    optimizer: OptimizerSpec = dataclasses.field(
        default_factory=OptimizerSpec)
    train: TrainSpec = dataclasses.field(default_factory=TrainSpec)
    churn: ChurnSpec = dataclasses.field(default_factory=ChurnSpec)
    serve: ServeSpec = dataclasses.field(default_factory=ServeSpec)
    # model-init rng scheme: "legacy" = the shared split chain every
    # process replays for the whole fleet (bitwise-identical to pre-fleet
    # runs, O(K²) fleet startup across K processes); "per_client" =
    # fold_in(seed, client_id), so a gossip child materializes only its
    # own clients — O(K) startup. Different stream, hence opt-in.
    init_scheme: str = "legacy"

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        sub = {
            "algorithm": AlgorithmSpec,
            "data": DataSpec,
            "partition": PartitionSpec,
            "topology": TopologySpec,
            "schedule": ScheduleSpec,
            "transport": TransportSpec,
            "wire": WireSpec,
            "optimizer": OptimizerSpec,
            "train": TrainSpec,
            "churn": ChurnSpec,
            "serve": ServeSpec,
        }
        kwargs: Dict[str, Any] = {}
        for key, val in d.items():
            if key in ("name", "init_scheme"):
                kwargs[key] = val
            elif key == "clients":
                kwargs[key] = tuple(_build(ClientSpec, c) for c in val)
            elif key in sub:
                kwargs[key] = _build(sub[key], val)
            else:
                raise ValueError(f"unknown ExperimentSpec field {key!r}")
        return cls(**kwargs)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    def validate(self) -> "ExperimentSpec":
        """Cheap structural checks (registry membership is the runner's
        job — it owns the Algorithm registry)."""
        if not self.clients:
            raise ValueError("an experiment needs at least one client")
        for c in self.clients:
            if c.arch not in CLIENT_ARCHS:
                raise ValueError(
                    f"unknown client arch {c.arch!r}; "
                    f"known: {CLIENT_ARCHS.names()}")
        self._validate_schedule()
        if self.transport.kind not in TRANSPORTS:
            raise ValueError(f"unknown transport kind "
                             f"{self.transport.kind!r}; "
                             f"known: {TRANSPORTS.names()}")
        kind_check = getattr(TRANSPORTS.get(self.transport.kind),
                             "validate_spec", None)
        if kind_check is not None:
            kind_check(self)
        if self.wire.exchange == "params" and \
                self.transport.kind != "loopback":
            raise ValueError(
                "wire.exchange='params' puts nothing on a transport — a "
                f"{self.transport.kind!r} transport would silently not "
                "apply; use a prediction exchange or transport 'loopback'")
        if self.wire.exchange not in ("params", "prediction_topk",
                                      "prediction_dense",
                                      "prediction_adaptive"):
            raise ValueError(f"unknown exchange {self.wire.exchange!r}")
        if self.wire.compression not in ("none", "delta"):
            raise ValueError(
                f"unknown wire compression {self.wire.compression!r}")
        if self.wire.compression != "none" and \
                self.wire.exchange == "params":
            raise ValueError(
                "wire.compression applies to prediction frames; "
                "wire.exchange='params' has none — it would silently "
                "not apply")
        if self.wire.budget_bytes_per_token < 0:
            raise ValueError("wire.budget_bytes_per_token must be >= 0")
        if self.wire.budget_bytes_per_token and \
                self.wire.exchange != "prediction_adaptive":
            raise ValueError(
                "wire.budget_bytes_per_token is the adaptive wire's "
                f"knob; exchange {self.wire.exchange!r} would silently "
                "ignore it")
        if self.topology.name not in ("complete", "cycle", "chain",
                                      "islands", "isolated"):
            raise ValueError(f"unknown topology {self.topology.name!r}")
        if self.data.kind not in ("synthetic_vision", "synthetic_text"):
            raise ValueError(f"unknown data kind {self.data.kind!r}")
        if self.data.kind == "synthetic_text":
            if self.data.vocab_size < 2 or self.data.seq_len < 2:
                raise ValueError(
                    "synthetic_text needs vocab_size >= 2 and "
                    "seq_len >= 2 (next-token positions are T-1)")
        if self.init_scheme not in ("legacy", "per_client"):
            raise ValueError(f"unknown init_scheme {self.init_scheme!r}; "
                             "known: legacy, per_client")
        if self.init_scheme == "per_client" and \
                self.wire.exchange == "params":
            raise ValueError(
                "init_scheme='per_client' skips materializing non-local "
                "clients; the params exchange reads every client's raw "
                "params and needs init_scheme='legacy'")
        if self.train.snapshot_every and not self.train.snapshot_dir:
            raise ValueError(
                "train.snapshot_every needs train.snapshot_dir")
        self._validate_churn()
        self._validate_serve()
        return self

    def _validate_schedule(self) -> None:
        s = self.schedule
        if s.mode not in ("sync", "async", "lockstep", "scoreboard"):
            raise ValueError(f"unknown schedule mode {s.mode!r}")
        if s.rates is not None and len(s.rates) != self.num_clients:
            raise ValueError(
                f"{len(s.rates)} schedule rates for "
                f"{self.num_clients} clients")
        if s.mode == "sync":
            for knob in ("rates", "runahead", "pace_ms"):
                if getattr(s, knob) is not None:
                    raise ValueError(
                        f"schedule.{knob} only applies to the scheduler "
                        "modes; a sync run would silently ignore it")
            return
        if s.rates is not None and any(int(r) < 1 for r in s.rates):
            raise ValueError("schedule.rates must be >= 1")
        if s.runahead is not None and int(s.runahead) < 1:
            raise ValueError("schedule.runahead must be >= 1 wall tick")
        if s.pace_ms is not None:
            if len(s.pace_ms) != self.num_clients:
                raise ValueError(
                    f"{len(s.pace_ms)} schedule pace_ms for "
                    f"{self.num_clients} clients")
            if any(float(p) < 0 for p in s.pace_ms):
                raise ValueError("schedule.pace_ms must be >= 0")
        # Horizon-vs-publish-gap: a rate-r client only reaches its next
        # pool boundary every r*S_P wall ticks, so prediction mailboxes
        # must survive at least that long or a straggler's neighbors
        # read nothing between its publishes.
        if self.algorithm.name == "mhd" and \
                self.wire.exchange in ("prediction_topk",
                                       "prediction_dense",
                                       "prediction_adaptive"):
            s_p = int(self.algorithm.params.get("pool_update_every", 200))
            horizon = int(self.wire.horizon) or s_p
            max_rate = max(int(r) for r in s.rates) if s.rates else 1
            if horizon < max_rate * s_p:
                raise ValueError(
                    f"wire.horizon={horizon} is shorter than the slowest "
                    f"client's publish gap (max rate {max_rate} x "
                    f"pool_update_every {s_p} = {max_rate * s_p} wall "
                    "ticks); its mailboxes would expire before neighbors "
                    "read them — raise wire.horizon or lower the rate "
                    "skew")

    def _validate_serve(self) -> None:
        s = self.serve
        if s.requests < 0 or s.feedback_steps < 0:
            raise ValueError("serve.requests/feedback_steps must be >= 0")
        if s.router not in ("client_id", "label_affinity", "round_robin"):
            raise ValueError(f"unknown serve router {s.router!r}")
        if s.num_slots < 1 or s.max_new_tokens < 1 or s.cache_windows < 1:
            raise ValueError(
                "serve.num_slots/max_new_tokens/cache_windows must be >= 1")
        if s.teachers is not None:
            bad = [t for t in s.teachers
                   if not 0 <= int(t) < self.num_clients]
            if bad:
                raise ValueError(f"serve.teachers {bad} out of range for "
                                 f"{self.num_clients} clients")
        if s.feedback_steps > 0 and s.requests <= 0:
            raise ValueError(
                "serve.feedback_steps > 0 needs serve.requests > 0 — "
                "feedback distills from served traffic")
        if s.feedback_steps > 0 and self.wire.exchange == "params":
            raise ValueError(
                "serve→distill feedback rides the prediction wire; "
                "wire.exchange='params' has no metered wire — use a "
                "prediction exchange")

    def _validate_churn(self) -> None:
        for ev in self.churn.events:
            if ev.kind not in ("kill", "restart", "join", "rewire"):
                raise ValueError(f"unknown churn event kind {ev.kind!r}")
            if ev.step < 0:
                raise ValueError(f"churn event at negative step {ev.step}")
            if ev.kind == "rewire":
                if ev.edges is None or len(ev.edges) != self.num_clients:
                    raise ValueError(
                        f"rewire@{ev.step} needs a full adjacency "
                        f"({self.num_clients} rows)")
                continue
            if ev.client is None or not \
                    (0 <= ev.client < self.num_clients):
                raise ValueError(
                    f"churn {ev.kind}@{ev.step} needs a client id in "
                    f"[0, {self.num_clients})")
            if ev.kind == "restart" and ev.from_snapshot and \
                    not self.train.snapshot_dir:
                raise ValueError(
                    f"restart@{ev.step} from snapshot needs "
                    "train.snapshot_dir (or from_snapshot=false for a "
                    "fresh re-init)")
        if self.churn.events:
            # full timeline coherence (kill/restart alternation, rewire
            # adjacency validity): delegate to the runtime's Membership,
            # so --dry-run rejects an incoherent script before training
            from repro.fleet import Membership, events_from_spec

            Membership(lambda step: [()] * self.num_clients,
                       self.num_clients, events_from_spec(self.churn))

    # -- convenience constructors ------------------------------------------

    @staticmethod
    def uniform_fleet(num_clients: int, arch: str = "resnet_tiny",
                      aux_heads: int = 0,
                      width: int = 8) -> Tuple[ClientSpec, ...]:
        return tuple(ClientSpec(arch=arch, aux_heads=aux_heads, width=width)
                     for _ in range(num_clients))


def _build(cls, d: Any) -> Any:
    """Rebuild one frozen spec block from its asdict/JSON form, restoring
    the non-JSON-native types (tuples, int dict keys)."""
    if isinstance(d, cls):
        return d
    if not isinstance(d, dict):
        raise TypeError(f"expected a dict for {cls.__name__}, got {d!r}")
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} fields {sorted(unknown)}; "
            f"known: {sorted(known)}")
    kwargs = dict(d)
    if cls is ScheduleSpec and kwargs.get("rates") is not None:
        kwargs["rates"] = tuple(int(r) for r in kwargs["rates"])
    if cls is ScheduleSpec and kwargs.get("pace_ms") is not None:
        kwargs["pace_ms"] = tuple(float(p) for p in kwargs["pace_ms"])
    if cls is TransportSpec and kwargs.get("client_rates") is not None:
        kwargs["client_rates"] = {int(k): int(v)
                                  for k, v in kwargs["client_rates"].items()}
    if cls is ChurnSpec and kwargs.get("events") is not None:
        kwargs["events"] = tuple(_build(ChurnEventSpec, e)
                                 for e in kwargs["events"])
    if cls is ChurnEventSpec and kwargs.get("edges") is not None:
        kwargs["edges"] = tuple(tuple(int(j) for j in nbrs)
                                for nbrs in kwargs["edges"])
    if cls is ServeSpec and kwargs.get("teachers") is not None:
        kwargs["teachers"] = tuple(int(t) for t in kwargs["teachers"])
    return cls(**kwargs)
