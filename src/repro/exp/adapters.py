"""Adapters registering the four paper algorithms behind the `Algorithm`
protocol.

Each adapter translates spec blocks into one concrete trainer's
constructor and forwards the step/evaluate/save surface:

  mhd         -> `core.runtime.DecentralizedTrainer` (sync) or the same
                 trainer driven by `core.scheduler.AsyncScheduler`
                 (lockstep) / `ScoreboardScheduler` (out-of-order)
  fedmd       -> `core.fedmd.FedMDTrainer` (central consensus server)
  fedavg      -> `core.fedavg.FedAvgTrainer` (weight averaging)
  supervised  -> `core.supervised.SupervisedTrainer` (pooled | separate)

Unknown ``AlgorithmSpec.params`` keys raise — a typo'd knob must never
silently run the default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.core.mhd import MHDConfig
from repro.exp.algorithm import ALGORITHMS, Algorithm, Bindings, Capabilities
from repro.exp.spec import ExperimentSpec


def _take_params(spec: ExperimentSpec, allowed: Dict[str, Any],
                 kind: str) -> Dict[str, Any]:
    """Overlay spec params on the adapter's defaults, rejecting unknowns."""
    params = dict(spec.algorithm.params)
    unknown = set(params) - set(allowed)
    if unknown:
        raise ValueError(
            f"unknown {kind} params {sorted(unknown)}; "
            f"known: {sorted(allowed)}")
    out = dict(allowed)
    out.update(params)
    return out


class _AdapterBase:
    """Common scaffolding: hold the spec, delegate to ``self.trainer``.

    ``_require_whole_fleet`` guards ``Bindings.local_clients``: only
    decentralized algorithms can drive a subset of the fleet from one
    process; centralized baselines must fail loudly instead of silently
    training the whole fleet in every process.

    Everything validatable from the spec alone happens at construction
    (``_resolve_params``), so `make_algorithm(spec)` — and therefore the
    CLI's ``--dry-run`` — rejects typo'd knobs and impossible fleets
    without building data or models; ``setup`` only binds resources."""

    name: str = ""
    capabilities = Capabilities()

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.trainer: Any = None
        self.params = self._resolve_params(spec)

    def _resolve_params(self, spec: ExperimentSpec) -> Dict[str, Any]:
        return _take_params(spec, {}, self.name)

    def _require_whole_fleet(self, bindings: Bindings) -> None:
        if bindings.local_clients is not None:
            raise ValueError(
                f"algorithm {self.name!r} has a central aggregation step "
                "and cannot drive a subset of the fleet per process "
                "(Bindings.local_clients)")

    def step(self, t: int) -> Dict[str, float]:
        return self.trainer.step(t)

    def evaluate(self, arrays) -> Dict[str, float]:
        return self.trainer.evaluate(arrays)

    def save(self, directory: str, step: int) -> None:
        self.trainer.save(directory, step)

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        return self.trainer.restore(directory, step)

    # -- fleet snapshots (repro.fleet.snapshot) --------------------------

    def snapshot(self, directory: str, step: int) -> None:
        """Full fleet snapshot — the bitwise-resume / churn-restart unit
        (vs ``save``, which persists params+opt only)."""
        from repro.fleet.snapshot import save_fleet

        save_fleet(directory, step, self.trainer,
                   scheduler=getattr(self, "scheduler", None))

    def restore_snapshot(self, directory: str,
                         step: Optional[int] = None) -> int:
        from repro.fleet.snapshot import restore_fleet

        return restore_fleet(directory, self.trainer,
                             scheduler=getattr(self, "scheduler", None),
                             step=step)


@ALGORITHMS.register("mhd")
class MHDAdapter(_AdapterBase):
    """The paper's Multi-Headed Distillation runtime. Non-sync schedules
    wrap the trainer in a scheduler — `AsyncScheduler` for lockstep,
    `ScoreboardScheduler` for out-of-order issue; ``step(t)`` is then one
    wall tick."""

    name = "mhd"
    capabilities = Capabilities(needs_public_pool=True, supports_async=True,
                                heterogeneous_clients=True,
                                uses_topology=True, decentralized=True,
                                elastic=True)

    MHD_DEFAULTS = {f.name: f.default
                    for f in dataclasses.fields(MHDConfig)}

    def __init__(self, spec: ExperimentSpec):
        super().__init__(spec)
        self.scheduler = None
        self.transport = None
        self.membership = None
        self.churn = None

    def _resolve_params(self, spec: ExperimentSpec) -> Dict[str, Any]:
        defaults = dict(self.MHD_DEFAULTS)
        # fleet and distillation config must agree on the head chain
        defaults["num_aux_heads"] = spec.clients[0].aux_heads
        params = _take_params(spec, defaults, "mhd")
        # the loss stacks per-level head outputs — every model must carry
        # exactly the configured chain (mhd_total_loss asserts equality)
        off = [i for i, c in enumerate(spec.clients)
               if c.aux_heads != params["num_aux_heads"]]
        if off:
            raise ValueError(
                f"mhd distills through {params['num_aux_heads']} aux heads "
                f"but clients {off} declare a different count; every "
                "ClientSpec.aux_heads must equal num_aux_heads")
        return params

    def setup(self, bindings: Bindings) -> None:
        from repro.core import (AsyncScheduler, DecentralizedTrainer,
                                RunConfig, ScheduleConfig,
                                ScoreboardScheduler)

        spec = self.spec
        mhd_cfg = MHDConfig(**self.params)
        run_cfg = RunConfig(
            steps=spec.train.steps, batch_size=spec.train.batch_size,
            public_batch_size=spec.train.public_batch_size,
            eval_every=0,  # the runner owns eval cadence
            eval_batch_size=spec.train.eval_batch_size,
            seed=spec.train.seed, max_staleness=spec.train.max_staleness)
        comm_cfg = None
        if spec.wire.exchange != "params":
            from repro.comm import CommConfig

            comm_cfg = CommConfig(
                topk=spec.wire.topk, val_dtype=spec.wire.val_dtype,
                emb_encoding=spec.wire.emb_encoding, tail=spec.wire.tail,
                horizon=spec.wire.horizon,
                budget_bytes_per_token=spec.wire.budget_bytes_per_token,
                compression=spec.wire.compression)
        self.transport = bindings.transport
        graph = bindings.graph
        if spec.churn.events:
            from repro.fleet import Membership, events_from_spec

            events = events_from_spec(spec.churn)
            self.membership = Membership(bindings.graph,
                                         spec.num_clients, events)
            graph = self.membership.graph_view
        self.trainer = DecentralizedTrainer(
            bindings.bundles, bindings.optimizer, mhd_cfg, run_cfg,
            bindings.arrays, bindings.partition.client_indices,
            bindings.partition.public_indices, graph,
            bindings.num_labels, exchange=spec.wire.exchange,
            comm=comm_cfg, transport=bindings.transport,
            local_clients=bindings.local_clients,
            init_scheme=spec.init_scheme, membership=self.membership)
        if spec.schedule.mode != "sync":
            rates = spec.schedule.rates or \
                tuple([1] * len(bindings.bundles))
            pace = None
            if spec.schedule.pace_ms is not None:
                pace = tuple(p / 1000.0 for p in spec.schedule.pace_ms)
            cfg = ScheduleConfig(tuple(rates),
                                 runahead=spec.schedule.runahead,
                                 pace_s=pace)
            cls = (ScoreboardScheduler
                   if spec.schedule.mode == "scoreboard"
                   else AsyncScheduler)
            self.scheduler = cls(self.trainer, cfg)
        if spec.churn.events:
            from repro.fleet import ChurnDriver

            self.churn = ChurnDriver(self.trainer, events,
                                     snapshot_dir=spec.train.snapshot_dir)

    def step(self, t: int) -> Dict[str, float]:
        if self.churn is not None:
            self.churn.before_step(t)
        if self.scheduler is not None:
            metrics = self.scheduler.tick()
        else:
            metrics = self.trainer.step(t)
        if self.membership is not None:
            metrics["fleet/epoch"] = float(self.membership.epoch(t))
            metrics["fleet/alive"] = float(len(self.trainer.local))
        return metrics


@ALGORITHMS.register("fedmd")
class FedMDAdapter(_AdapterBase):
    """Centralized consensus distillation (Li & Wang, 2019)."""

    name = "fedmd"
    capabilities = Capabilities(needs_public_pool=True,
                                heterogeneous_clients=True)

    def _resolve_params(self, spec: ExperimentSpec) -> Dict[str, Any]:
        return _take_params(
            spec, {"digest_weight": 1.0, "public_batch_size": None},
            "fedmd")

    def setup(self, bindings: Bindings) -> None:
        from repro.core.fedmd import FedMDTrainer

        self._require_whole_fleet(bindings)
        spec = self.spec
        public_bs = self.params["public_batch_size"]
        self.trainer = FedMDTrainer(
            bindings.bundles, bindings.optimizer, bindings.arrays,
            bindings.partition.client_indices,
            bindings.partition.public_indices, bindings.num_labels,
            batch_size=spec.train.batch_size,
            public_batch_size=(spec.train.public_batch_size
                               if public_bs is None else int(public_bs)),
            digest_weight=float(self.params["digest_weight"]),
            seed=spec.train.seed,
            eval_batch_size=spec.train.eval_batch_size)


@ALGORITHMS.register("fedavg")
class FedAvgAdapter(_AdapterBase):
    """Weight aggregation (McMahan et al., 2017); identical archs only."""

    name = "fedavg"
    capabilities = Capabilities()

    def _resolve_params(self, spec: ExperimentSpec) -> Dict[str, Any]:
        if len(set(spec.clients)) > 1:
            raise ValueError(
                "fedavg averages parameters — every ClientSpec in the "
                f"fleet must be identical, got {spec.clients}")
        return _take_params(spec, {"average_every": 200}, "fedavg")

    def setup(self, bindings: Bindings) -> None:
        from repro.core.fedavg import FedAvgTrainer

        self._require_whole_fleet(bindings)
        spec = self.spec
        self.trainer = FedAvgTrainer(
            bindings.bundles[0], bindings.optimizer, bindings.arrays,
            bindings.partition.client_indices, bindings.num_labels,
            batch_size=spec.train.batch_size,
            average_every=int(self.params["average_every"]),
            seed=spec.train.seed,
            eval_batch_size=spec.train.eval_batch_size)


@ALGORITHMS.register("supervised")
class SupervisedAdapter(_AdapterBase):
    """'Supervised' upper bound (scope="pooled") and the 'Separate'
    isolated baseline (scope="separate")."""

    name = "supervised"
    capabilities = Capabilities(heterogeneous_clients=True)

    def _resolve_params(self, spec: ExperimentSpec) -> Dict[str, Any]:
        params = _take_params(spec, {"scope": "separate"}, "supervised")
        if params["scope"] == "pooled" and len(set(spec.clients)) > 1:
            raise ValueError(
                "supervised scope='pooled' trains one model — the fleet "
                f"must be uniform, got {spec.clients}; use "
                "scope='separate' for heterogeneous fleets")
        return params

    def setup(self, bindings: Bindings) -> None:
        from repro.core.supervised import SupervisedTrainer

        self._require_whole_fleet(bindings)
        spec = self.spec
        self.trainer = SupervisedTrainer(
            bindings.bundles, bindings.optimizer, bindings.arrays,
            bindings.partition.client_indices, bindings.num_labels,
            batch_size=spec.train.batch_size,
            scope=str(self.params["scope"]), seed=spec.train.seed,
            eval_batch_size=spec.train.eval_batch_size)
