"""`Experiment` — the single runner behind every algorithm.

``Experiment(spec).run()`` materializes the spec (data, partition, fleet,
optimizer, graph, transport), instantiates the registered `Algorithm`
adapter, and owns the loop: stepping, the unified metric namespace
(``c{i}/...`` step metrics, ``mean/...`` eval metrics, ``comm/...``
meters), the eval-history cadence, and checkpointing. The result's
``metrics``/``history`` are JSON-serializable; live objects (the trainer,
transport, scheduler) ride out-of-band on `ExperimentResult`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import (
    chain_graph,
    complete_graph,
    cycle_graph,
    islands_graph,
    isolated_graph,
)
from repro.data import (
    PartitionConfig,
    Partition,
    make_synthetic_vision,
    partition_dataset,
)
from repro.exp.algorithm import Algorithm, Bindings, make_algorithm
from repro.exp.spec import (
    CLIENT_ARCHS,
    TRANSPORTS,
    DataSpec,
    ExperimentSpec,
    PartitionSpec,
)
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer

DataTriple = Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray], Partition]


# -- spec materialization ----------------------------------------------------


def materialize_data(data: DataSpec, partition: PartitionSpec,
                     num_clients: int) -> DataTriple:
    """(train arrays, test arrays, partition) for a spec — the one data
    construction path every harness shares.

    Text mirrors vision: the domain languages (transition tables) are
    pinned with ``table_seed = seed`` so the test split (sample seed
    ``seed + 991``) speaks the same languages — the twin of the vision
    sets' ``prototype_seed`` convention."""
    if data.kind == "synthetic_text":
        from repro.lm.pool import make_text_arrays

        arrays = make_text_arrays(
            num_domains=data.num_labels,
            sequences_per_domain=data.samples_per_label,
            seq_len=data.seq_len, vocab_size=data.vocab_size,
            seed=data.seed, table_seed=data.seed)
        test_arrays = make_text_arrays(
            num_domains=data.num_labels,
            sequences_per_domain=data.test_samples_per_label,
            seq_len=data.seq_len, vocab_size=data.vocab_size,
            seed=data.seed + 991, table_seed=data.seed)
        labels = arrays["labels"]
    else:
        ds = make_synthetic_vision(
            num_labels=data.num_labels,
            samples_per_label=data.samples_per_label,
            image_size=data.image_size, noise=data.noise, seed=data.seed)
        test = make_synthetic_vision(
            num_labels=data.num_labels,
            samples_per_label=data.test_samples_per_label,
            image_size=data.image_size, noise=data.noise,
            seed=data.seed + 991, prototype_seed=data.seed)
        arrays = {"images": ds.images, "labels": ds.labels}
        test_arrays = {"images": test.images, "labels": test.labels}
        labels = ds.labels
    pcfg = PartitionConfig(
        num_clients=num_clients, num_labels=data.num_labels,
        labels_per_client=partition.labels_per_client,
        assignment=partition.assignment, skew=partition.skew,
        gamma_pub=partition.gamma_pub,
        even_multiplicity=partition.even_multiplicity,
        seed=data.seed if partition.seed is None else partition.seed)
    part = partition_dataset(labels, pcfg)
    return arrays, test_arrays, part


def build_bundles(spec: ExperimentSpec) -> List[Any]:
    """Text fleets get the shared vocab as the head dim (every backbone —
    SSM, transformer, MoE — must expose identical (B', V) head shapes to
    the wire) and the positions-as-samples adapter wrap."""
    text = spec.data.kind == "synthetic_text"
    head_dim = spec.data.vocab_size if text else spec.data.num_labels
    bundles = [build_bundle(CLIENT_ARCHS.get(c.arch)(
        head_dim, c.aux_heads, c.width))
        for c in spec.clients]
    if text:
        from repro.lm.pool import lm_client_bundle

        bundles = [lm_client_bundle(b, spec.data.max_positions,
                                    spec.data.position_seed)
                   for b in bundles]
    return bundles


def build_graph(spec: ExperimentSpec):
    k = spec.num_clients
    topo = spec.topology
    if topo.name == "complete":
        return complete_graph(k)
    if topo.name == "cycle":
        return cycle_graph(k, hops=topo.hops)
    if topo.name == "chain":
        return chain_graph(k)
    if topo.name == "islands":
        return islands_graph(k, topo.islands)
    if topo.name == "isolated":
        return isolated_graph(k)
    raise ValueError(f"unknown topology {topo.name!r}")


def build_transport(spec: ExperimentSpec) -> Optional[Any]:
    """Resolve the spec's transport kind through the ``TRANSPORTS``
    registry (None = the trainer's default in-process loopback)."""
    return TRANSPORTS.get(spec.transport.kind)(spec)


def build_optimizer(spec: ExperimentSpec):
    o = spec.optimizer
    return make_optimizer(OptimizerConfig(
        name=o.name, init_lr=o.init_lr,
        total_steps=(spec.train.steps if o.total_steps is None
                     else o.total_steps),
        warmup_steps=o.warmup_steps, momentum=o.momentum,
        weight_decay=o.weight_decay, grad_clip_norm=o.grad_clip_norm,
        state_dtype=o.state_dtype))


# -- results -----------------------------------------------------------------


@dataclasses.dataclass
class ExperimentResult:
    """What a run produced. ``metrics``/``history`` are plain floats (JSON
    round-trips); the live algorithm adapter rides out-of-band so
    drill-downs (per-client params, comm meters) never leak into the
    serializable payload."""

    spec: ExperimentSpec
    metrics: Dict[str, float]  # final eval + comm meters
    history: List[Tuple[int, Dict[str, float]]]  # (step, eval metrics)
    us_per_step: float
    algorithm: Algorithm = dataclasses.field(repr=False)

    @property
    def trainer(self) -> Any:
        """The underlying trainer object (out-of-band, never serialized)."""
        return getattr(self.algorithm, "trainer", None)

    @property
    def scheduler(self) -> Any:
        return getattr(self.algorithm, "scheduler", None)

    @property
    def transport(self) -> Any:
        return getattr(self.algorithm, "transport", None)

    def to_payload(self) -> Dict[str, Any]:
        return {"spec": self.spec.to_dict(),
                "metrics": self.metrics,
                "history": [[t, m] for t, m in self.history],
                "us_per_step": self.us_per_step}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_payload(), indent=indent, sort_keys=True)


# -- the runner --------------------------------------------------------------


class Experiment:
    """One declarative experiment: ``Experiment(spec).run()``.

    ``data`` overrides the spec-built ``(arrays, test_arrays, partition)``
    triple — used by benchmarks that share one dataset across several
    algorithm runs for comparability.
    """

    def __init__(self, spec: ExperimentSpec,
                 data: Optional[DataTriple] = None):
        self.spec = spec.validate()
        self._data = data

    def build_bindings(self) -> Bindings:
        spec = self.spec
        arrays, test_arrays, part = (
            self._data if self._data is not None else
            materialize_data(spec.data, spec.partition, spec.num_clients))
        return Bindings(
            spec=spec, arrays=arrays, test_arrays=test_arrays,
            partition=part, bundles=build_bundles(spec),
            optimizer=build_optimizer(spec), graph=build_graph(spec),
            transport=build_transport(spec), num_labels=spec.data.num_labels)

    def _check_capabilities(self, algo: Algorithm) -> None:
        spec, caps = self.spec, algo.capabilities
        if caps.needs_public_pool and spec.partition.gamma_pub <= 0.0:
            raise ValueError(
                f"algorithm {algo.name!r} distills on the public pool; "
                "partition.gamma_pub must be > 0")
        if spec.schedule.mode != "sync" and not caps.supports_async:
            raise ValueError(
                f"algorithm {algo.name!r} does not support async "
                "(lockstep/scoreboard) schedules")
        if len(set(spec.clients)) > 1 and not caps.heterogeneous_clients:
            raise ValueError(
                f"algorithm {algo.name!r} needs an identical-architecture "
                "fleet")
        if spec.topology.name != "complete" and not caps.uses_topology:
            raise ValueError(
                f"algorithm {algo.name!r} ignores the communication graph; "
                f"a {spec.topology.name!r} topology would silently not "
                "apply — use topology 'complete'")
        # (a non-loopback transport with exchange='params' is already
        # rejected by spec.validate(), for every algorithm)
        if spec.wire.exchange != "params" and not caps.decentralized:
            raise ValueError(
                f"algorithm {algo.name!r} has no prediction wire; "
                "set wire.exchange='params'")
        if spec.train.max_staleness is not None and not caps.decentralized:
            raise ValueError(
                f"algorithm {algo.name!r} has no staleness gate; unset "
                "train.max_staleness")
        if spec.churn.events and not caps.elastic:
            raise ValueError(
                f"algorithm {algo.name!r} is not elastic; a churn "
                "timeline (ChurnSpec.events) would silently not apply")

    def run(self,
            on_step: Optional[Callable[[int, Dict[str, float]], None]] = None,
            on_eval: Optional[Callable[[int, Dict[str, float]], None]] = None,
            ) -> ExperimentResult:
        spec = self.spec
        algo = make_algorithm(spec)
        self._check_capabilities(algo)
        bindings = self.build_bindings()

        train = spec.train
        history: List[Tuple[int, Dict[str, float]]] = []
        step_seconds = 0.0
        tracer = None
        if train.trace_dir:
            from repro.obs import trace

            os.makedirs(train.trace_dir, exist_ok=True)
            tracer = trace.enable(process_name=spec.name)
        try:
            algo.setup(bindings)
            for t in range(train.steps):
                t0 = time.perf_counter()
                metrics = algo.step(t)
                step_seconds += time.perf_counter() - t0
                if on_step is not None:
                    on_step(t, metrics)
                if train.eval_every and (t + 1) % train.eval_every == 0:
                    ev = algo.evaluate(bindings.test_arrays)
                    history.append((t + 1, ev))
                    if on_eval is not None:
                        on_eval(t + 1, ev)
                if train.checkpoint_dir and train.checkpoint_every and \
                        (t + 1) % train.checkpoint_every == 0:
                    algo.save(train.checkpoint_dir, t + 1)
                if train.snapshot_dir and train.snapshot_every and \
                        (t + 1) % train.snapshot_every == 0:
                    algo.snapshot(train.snapshot_dir, t + 1)

            if not history or history[-1][0] != train.steps:
                ev = algo.evaluate(bindings.test_arrays)
                history.append((train.steps, ev))
                if on_eval is not None:
                    on_eval(train.steps, ev)
            if train.checkpoint_dir and not (
                    train.checkpoint_every and
                    train.steps % train.checkpoint_every == 0):
                algo.save(train.checkpoint_dir, train.steps)
        finally:
            # a socket transport binds real listeners — release them when
            # the loop is over (post-run drill-downs read attributes, not
            # live sockets); Transport.close is a no-op for the others
            if bindings.transport is not None:
                bindings.transport.close()
            if tracer is not None:
                from repro.obs import trace

                trace.disable()  # events stay on the tracer object

        metrics = dict(history[-1][1])
        metrics.update(_comm_metrics(algo))
        if tracer is not None:
            from repro.obs import collect_obs, write_trace

            write_trace(os.path.join(train.trace_dir, "trace.json"),
                        tracer, meta={"spec_name": spec.name,
                                      "steps": train.steps})
            obs = collect_obs(
                trainer=getattr(algo, "trainer", None),
                scheduler=getattr(algo, "scheduler", None),
                tracer=tracer, with_roofline=True)
            metrics.update(obs.to_metrics())
        return ExperimentResult(
            spec=spec, metrics=metrics, history=history,
            us_per_step=step_seconds / max(train.steps, 1) * 1e6,
            algorithm=algo)


def _comm_metrics(algo: Algorithm) -> Dict[str, float]:
    """Fold the comm meter into the unified namespace (prediction modes)."""
    meter = getattr(getattr(algo, "trainer", None), "meter", None)
    if meter is None:
        return {}
    out = {"comm/total_bytes": float(meter.total_bytes),
           "comm/delivered_bytes": float(meter.delivered_bytes),
           "comm/rejected_publishes": float(meter.rejected_publishes),
           "comm/tombstoned_bytes": float(meter.tombstoned_bytes)}
    # transport-level backpressure (SocketTransport): retried sends that
    # stalled past drain_timeout without being dropped
    transport = getattr(getattr(algo, "trainer", None), "bus", None)
    transport = getattr(transport, "transport", None)
    if hasattr(transport, "drain_stalls"):
        out["comm/drain_stalls"] = float(transport.drain_stalls)
    for cid, g in meter.gate_summary().items():
        out[f"c{cid}/comm/fresh_teachers"] = float(g["fresh"])
        out[f"c{cid}/comm/stale_teachers"] = float(g["stale"])
    return out


def run_spec(spec: ExperimentSpec,
             data: Optional[DataTriple] = None,
             **run_kw) -> ExperimentResult:
    """Convenience one-liner."""
    return Experiment(spec, data=data).run(**run_kw)
