"""repro.exp — the declarative Experiment API.

One serializable `ExperimentSpec`, one `Algorithm` protocol, one
`Experiment.run()` runner for all four algorithms the paper compares:
MHD (sync or async), FedMD, FedAvg, and supervised (pooled/separate).
Topology, transport, wire format and schedule are spec edits, not new
harnesses; results separate JSON-serializable metrics from out-of-band
live objects (trainer, scheduler, transport).

    from repro.exp import Experiment, get_preset
    result = Experiment(get_preset("quick")).run()
    print(result.metrics["mean/aux3/beta_sh"])

Importing this package registers the four paper adapters in
``ALGORITHMS`` and the named presets in ``PRESETS``.
"""
from repro.exp.spec import (
    CLIENT_ARCHS,
    TRANSPORTS,
    AlgorithmSpec,
    ChurnEventSpec,
    ChurnSpec,
    ClientSpec,
    DataSpec,
    ExperimentSpec,
    OptimizerSpec,
    PartitionSpec,
    ScheduleSpec,
    ServeSpec,
    TopologySpec,
    TrainSpec,
    TransportSpec,
    WireSpec,
)
from repro.exp.algorithm import (
    ALGORITHMS,
    Algorithm,
    Bindings,
    Capabilities,
    make_algorithm,
)
from repro.exp import adapters as _adapters  # noqa: F401 — registers algos
from repro.exp.runner import (
    Experiment,
    ExperimentResult,
    build_bundles,
    build_graph,
    build_optimizer,
    build_transport,
    materialize_data,
    run_spec,
)
from repro.exp.presets import PRESETS, get_preset, preset_names

__all__ = [
    "ALGORITHMS",
    "Algorithm",
    "AlgorithmSpec",
    "Bindings",
    "CLIENT_ARCHS",
    "Capabilities",
    "ChurnEventSpec",
    "ChurnSpec",
    "ClientSpec",
    "DataSpec",
    "Experiment",
    "ExperimentResult",
    "ExperimentSpec",
    "OptimizerSpec",
    "PRESETS",
    "PartitionSpec",
    "ScheduleSpec",
    "ServeSpec",
    "TRANSPORTS",
    "TopologySpec",
    "TrainSpec",
    "TransportSpec",
    "WireSpec",
    "build_bundles",
    "build_graph",
    "build_optimizer",
    "build_transport",
    "get_preset",
    "make_algorithm",
    "materialize_data",
    "preset_names",
    "run_spec",
]
