"""The `Algorithm` protocol: one runtime contract for MHD, FedMD, FedAvg
and supervised training.

An algorithm adapter is constructed from an `ExperimentSpec`, bound to
materialized resources (`Bindings`: arrays, partition, bundles, optimizer,
graph, transport) via ``setup``, and then driven step by step by
`Experiment.run` — the runner owns the loop, eval cadence, metric
namespace and checkpoint rhythm; the adapter owns one step.

Adapters advertise `Capabilities` so the runner can reject impossible
specs up front (an async schedule for a barrier algorithm, a
heterogeneous fleet for FedAvg) instead of failing deep in a train loop.

Registration goes through ``ALGORITHMS`` (`common/registry.py`): a
factory ``(spec) -> Algorithm``. `repro.exp` registers the four paper
algorithms at import; downstream code can register more.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import numpy as np

from repro.common.registry import Registry
from repro.core.graph import Adjacency
from repro.data.partition import Partition
from repro.exp.spec import ExperimentSpec
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What an algorithm can consume from a spec. The runner enforces
    these; anything an algorithm doesn't support must make the run fail
    loudly, not be silently ignored."""

    needs_public_pool: bool = False  # consumes γ_pub public samples
    supports_async: bool = False  # can run under ScheduleSpec mode="async"
    heterogeneous_clients: bool = False  # per-client architectures OK
    uses_topology: bool = False  # consumes the communication graph G_t
    decentralized: bool = False  # no central aggregator on the wire
    elastic: bool = False  # survives client churn (ChurnSpec events)


@dataclasses.dataclass
class Bindings:
    """Materialized resources the runner hands to ``Algorithm.setup``."""

    spec: ExperimentSpec
    arrays: Dict[str, np.ndarray]
    test_arrays: Dict[str, np.ndarray]
    partition: Partition
    bundles: List[ModelBundle]
    optimizer: Optimizer
    graph: Adjacency
    transport: Optional[Any]  # repro.comm.Transport | None (loopback)
    num_labels: int
    # multi-process gossip: the client ids THIS process drives (None =
    # all — the single-process runner). Algorithms that cannot restrict
    # (centralized baselines) must reject a non-None value in setup.
    local_clients: Optional[Sequence[int]] = None


@runtime_checkable
class Algorithm(Protocol):
    """The uniform runtime surface `Experiment.run` drives."""

    name: str
    capabilities: Capabilities

    def setup(self, bindings: Bindings) -> None:
        """Build internal state (models, iterators, comm) from resources."""
        ...

    def step(self, t: int) -> Dict[str, float]:
        """Advance one step (one wall tick when async); returns the step's
        metrics under the ``c{i}/...`` namespace."""
        ...

    def evaluate(self, arrays: Dict[str, np.ndarray]) -> Dict[str, float]:
        """β_sh/β_priv metrics under ``c{i}/...`` + ``mean/...``."""
        ...

    def save(self, directory: str, step: int) -> None:
        ...

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        ...


# name -> factory(spec) -> Algorithm
ALGORITHMS: Registry[Callable[[ExperimentSpec], Algorithm]] = Registry(
    "algorithm")


def make_algorithm(spec: ExperimentSpec) -> Algorithm:
    return ALGORITHMS.get(spec.algorithm.name)(spec)
