"""Named experiment presets — spec-file starting points for the CLI
(`scripts/run_experiment.py --preset <name>`) and the smoke tier.

Presets are factories (specs are frozen; a factory per call keeps them
trivially safe to mutate via ``dataclasses.replace``).
"""
from __future__ import annotations

from typing import Callable, List

from repro.common.registry import Registry
from repro.exp.spec import (
    AlgorithmSpec,
    ChurnEventSpec,
    ChurnSpec,
    ClientSpec,
    DataSpec,
    ExperimentSpec,
    OptimizerSpec,
    PartitionSpec,
    ScheduleSpec,
    ServeSpec,
    TopologySpec,
    TrainSpec,
    TransportSpec,
    WireSpec,
)

PRESETS: Registry[Callable[[], ExperimentSpec]] = Registry(
    "experiment preset")


def get_preset(name: str) -> ExperimentSpec:
    return PRESETS.get(name)().validate()


def preset_names() -> List[str]:
    return PRESETS.names()


@PRESETS.register("quick")
def _quick() -> ExperimentSpec:
    """The benchmark QUICK scale: 4 MHD clients, complete graph, sync."""
    return ExperimentSpec(
        name="mhd_quick",
        algorithm=AlgorithmSpec("mhd", {
            "nu_emb": 1.0, "nu_aux": 1.0, "delta": 1,
            "pool_size": 4, "pool_update_every": 10}),
        data=DataSpec(num_labels=16, samples_per_label=200),
        partition=PartitionSpec(labels_per_client=4, skew=100.0,
                                gamma_pub=0.1),
        clients=ExperimentSpec.uniform_fleet(4, aux_heads=3),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=600, batch_size=32, public_batch_size=32))


@PRESETS.register("gossip")
def _gossip() -> ExperimentSpec:
    """The comm_gossip example: async heterogeneous-rate lossy ring with
    top-k prediction exchange (client 3 is a 4× straggler)."""
    s_p, straggler = 10, 4
    return ExperimentSpec(
        name="gossip_ring",
        algorithm=AlgorithmSpec("mhd", {
            "nu_emb": 1.0, "nu_aux": 1.0, "delta": 1,
            "pool_size": 2, "pool_update_every": s_p}),
        data=DataSpec(num_labels=12, samples_per_label=200),
        partition=PartitionSpec(labels_per_client=3, skew=100.0,
                                gamma_pub=0.1),
        clients=ExperimentSpec.uniform_fleet(4, aux_heads=2),
        topology=TopologySpec("cycle"),
        schedule=ScheduleSpec(mode="async", rates=(1, 1, 1, straggler)),
        transport=TransportSpec(kind="simulated", latency=1,
                                bandwidth=64 * 1024, drop_prob=0.10,
                                seed=7, client_rates={3: straggler}),
        wire=WireSpec(exchange="prediction_topk", topk=5,
                      val_dtype="float16", emb_encoding="int8",
                      horizon=s_p * straggler),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=200, batch_size=32, public_batch_size=32,
                        max_staleness=3 * s_p))


@PRESETS.register("gossip_socket")
def _gossip_socket() -> ExperimentSpec:
    """A 4-client prediction-exchange ring over real TCP sockets.

    In-process (`Experiment.run()`), one `SocketTransport` hosts all four
    clients over localhost TCP. The same spec drives the multi-process
    runner (`scripts/run_gossip_procs.py`): one OS process per client,
    each stepping only its own client — heterogeneous speeds are then
    real wall-clock differences. The generous horizon / staleness bound
    tolerate inter-process clock drift (a peer mid-jit-compile)."""
    s_p = 5
    return ExperimentSpec(
        name="gossip_socket_ring",
        algorithm=AlgorithmSpec("mhd", {
            "nu_emb": 1.0, "nu_aux": 1.0, "delta": 1,
            "pool_size": 2, "pool_update_every": s_p}),
        data=DataSpec(num_labels=12, samples_per_label=60),
        partition=PartitionSpec(labels_per_client=3, skew=100.0,
                                gamma_pub=0.1),
        clients=ExperimentSpec.uniform_fleet(4, aux_heads=2),
        topology=TopologySpec("cycle"),
        transport=TransportSpec(kind="socket"),
        wire=WireSpec(exchange="prediction_topk", topk=5,
                      val_dtype="float16", emb_encoding="int8",
                      horizon=4 * s_p),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=40, batch_size=16, public_batch_size=16,
                        max_staleness=4 * s_p))


@PRESETS.register("lm_hetero")
def _lm_hetero() -> ExperimentSpec:
    """The heterogeneous-architecture LM distillation fleet (repro.lm):
    an SSM, a dense transformer and a small MoE co-train on per-domain
    bigram text, exchanging next-token predictions over TCP on the
    entropy-adaptive wire — k varies per token under a bytes/token
    budget, and the index streams cross the wire XOR-delta'd and
    bit-packed. Embeddings stay local (``nu_emb=0``): the budget story
    is about the prediction streams, not the ξ lane. The same spec
    drives `Experiment.run()` in-process and the multi-process runner
    (``scripts/run_gossip_procs.py --lm-smoke``)."""
    s_p = 5
    return ExperimentSpec(
        name="lm_hetero",
        algorithm=AlgorithmSpec("mhd", {
            "nu_emb": 0.0, "nu_aux": 0.5, "delta": 1,
            "pool_size": 2, "pool_update_every": s_p}),
        data=DataSpec(kind="synthetic_text", num_labels=6,
                      samples_per_label=30, test_samples_per_label=8,
                      vocab_size=64, seq_len=12, max_positions=64,
                      position_seed=17),
        partition=PartitionSpec(labels_per_client=2, skew=100.0,
                                gamma_pub=0.2),
        clients=(ClientSpec(arch="lm_ssm", aux_heads=2, width=128),
                 ClientSpec(arch="lm_transformer", aux_heads=2, width=128),
                 ClientSpec(arch="lm_moe", aux_heads=2, width=128)),
        topology=TopologySpec("complete"),
        transport=TransportSpec(kind="socket"),
        wire=WireSpec(exchange="prediction_adaptive", topk=8,
                      val_dtype="float16", emb_encoding="none",
                      horizon=4 * s_p, budget_bytes_per_token=24,
                      compression="delta"),
        # AdamW, not the paper's SGD: the reduced LM shapes barely move
        # under SGD at these step counts (the vision presets' optimizer
        # stays paper-faithful; this is the "provided for the assigned
        # LLM architectures" path of repro.optim)
        optimizer=OptimizerSpec(name="adamw", init_lr=1e-2,
                                warmup_steps=10, grad_clip_norm=1.0),
        train=TrainSpec(steps=30, batch_size=8, public_batch_size=8,
                        eval_batch_size=8, max_staleness=4 * s_p))


@PRESETS.register("churn_ring")
def _churn_ring() -> ExperimentSpec:
    """An elastic 5-client prediction-exchange ring (repro.fleet): client
    4 joins late, client 1 crashes and restarts fresh, and the ring
    rewires to 2-hop reach mid-run — the churn-axis counterpart of the
    topology sweeps. Snapshot-based restarts need a snapshot_dir; this
    preset uses a fresh restart so it runs out of the box."""
    s_p, k = 5, 5
    two_hop = tuple(tuple(sorted(((i + 1) % k, (i + 2) % k)))
                    for i in range(k))
    return ExperimentSpec(
        name="churn_ring",
        algorithm=AlgorithmSpec("mhd", {
            "nu_emb": 1.0, "nu_aux": 1.0, "delta": 1,
            "pool_size": 2, "pool_update_every": s_p}),
        data=DataSpec(num_labels=12, samples_per_label=100),
        partition=PartitionSpec(labels_per_client=3, skew=100.0,
                                gamma_pub=0.1),
        clients=ExperimentSpec.uniform_fleet(k, aux_heads=2),
        topology=TopologySpec("cycle"),
        wire=WireSpec(exchange="prediction_topk", topk=5,
                      val_dtype="float16", emb_encoding="int8",
                      horizon=3 * s_p),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=120, batch_size=16, public_batch_size=16,
                        max_staleness=3 * s_p),
        churn=ChurnSpec(events=(
            ChurnEventSpec(kind="join", step=20, client=4),
            ChurnEventSpec(kind="kill", step=40, client=1),
            ChurnEventSpec(kind="restart", step=70, client=1,
                           from_snapshot=False),
            ChurnEventSpec(kind="rewire", step=90, edges=two_hop),
        )))


@PRESETS.register("serve_loop")
def _serve_loop() -> ExperimentSpec:
    """The full serve→distill loop (repro.serve): train a 4-client MHD
    fleet on the prediction wire, snapshot it, serve a mixed
    classify/teacher/generate stream against the snapshot, then distill
    two more steps from the served traffic. Consumed by
    ``launch/serve.py --preset serve_loop`` and `benchmarks/serve.py`
    (plain ``run_experiment.py`` runs only the training phase)."""
    s_p = 5
    return ExperimentSpec(
        name="serve_loop",
        algorithm=AlgorithmSpec("mhd", {
            "nu_emb": 1.0, "nu_aux": 1.0, "delta": 1,
            "pool_size": 2, "pool_update_every": s_p}),
        data=DataSpec(num_labels=12, samples_per_label=60),
        partition=PartitionSpec(labels_per_client=3, skew=100.0,
                                gamma_pub=0.1),
        clients=ExperimentSpec.uniform_fleet(4, aux_heads=2),
        wire=WireSpec(exchange="prediction_topk", topk=5,
                      val_dtype="float16", emb_encoding="int8",
                      horizon=2 * s_p),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=30, batch_size=16, public_batch_size=16),
        serve=ServeSpec(requests=24, router="label_affinity", num_slots=4,
                        max_new_tokens=12, engine_arch="minitron-4b",
                        cache_windows=4, feedback_steps=2))


@PRESETS.register("fedmd_quick")
def _fedmd_quick() -> ExperimentSpec:
    """FedMD at the QUICK scale, heterogeneous two-arch fleet (Table 2)."""
    return ExperimentSpec(
        name="fedmd_quick",
        algorithm=AlgorithmSpec("fedmd", {"digest_weight": 1.0}),
        data=DataSpec(num_labels=16, samples_per_label=200),
        partition=PartitionSpec(labels_per_client=4, skew=100.0),
        clients=tuple(ClientSpec(arch=("resnet_tiny34" if i % 2
                                       else "resnet_tiny"))
                      for i in range(4)),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=600, batch_size=32, public_batch_size=32))


@PRESETS.register("fedavg_quick")
def _fedavg_quick() -> ExperimentSpec:
    """FedAvg at the QUICK scale (Table 1's FA row)."""
    return ExperimentSpec(
        name="fedavg_quick",
        algorithm=AlgorithmSpec("fedavg", {"average_every": 20}),
        data=DataSpec(num_labels=16, samples_per_label=200),
        partition=PartitionSpec(labels_per_client=4, skew=100.0),
        clients=ExperimentSpec.uniform_fleet(4),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=600, batch_size=32))


@PRESETS.register("supervised_quick")
def _supervised_quick() -> ExperimentSpec:
    """Pooled-data supervised upper bound at the QUICK scale."""
    return ExperimentSpec(
        name="supervised_quick",
        algorithm=AlgorithmSpec("supervised", {"scope": "pooled"}),
        data=DataSpec(num_labels=16, samples_per_label=200),
        partition=PartitionSpec(labels_per_client=4, skew=100.0),
        clients=ExperimentSpec.uniform_fleet(4),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=600, batch_size=32))


@PRESETS.register("separate_quick")
def _separate_quick() -> ExperimentSpec:
    """The 'Separate' isolated-clients baseline at the QUICK scale."""
    return ExperimentSpec(
        name="separate_quick",
        algorithm=AlgorithmSpec("supervised", {"scope": "separate"}),
        data=DataSpec(num_labels=16, samples_per_label=200),
        partition=PartitionSpec(labels_per_client=4, skew=100.0),
        clients=ExperimentSpec.uniform_fleet(4),
        optimizer=OptimizerSpec(init_lr=0.05, grad_clip_norm=1.0),
        train=TrainSpec(steps=600, batch_size=32))
