"""Model configuration schema.

One ``ModelConfig`` describes every assigned architecture. Depth is expressed
as *stages*: a stage is a homogeneous repeat-unit (list of ``LayerSpec``)
scanned ``repeats`` times — this keeps HLO size O(unit) for 62..100-layer
models (DESIGN.md §9) while expressing heterogeneous patterns
(gemma3 5 local : 1 global, llama-vision 1 cross : 4 self,
zamba2 shared-attention every 6th block).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a repeat unit."""

    attn: str = "full"  # "full" | "swa" | "cross" | "mamba2" | "none"
    ffn: str = "dense"  # "dense" | "moe" | "moe_dense_parallel" | "none"
    shared_attn: bool = False  # zamba2: append the *shared* attention block
    cross_attn: bool = False  # whisper decoder: extra cross-attn sublayer


@dataclasses.dataclass(frozen=True)
class Stage:
    block: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.block) * self.repeats


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 2048
    num_shared_experts: int = 0  # deepseek: 1 shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub: precomputed patch embeddings (DESIGN.md §5)."""

    num_patches: int = 1024
    embed_dim: int = 1280  # raw vision-encoder hidden; projector is in-model


@dataclasses.dataclass(frozen=True)
class AudioStubConfig:
    """Audio frontend stub: precomputed mel+conv frame embeddings."""

    frame_dim: int = 1280
    decoder_len: int = 448  # whisper max target positions


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style bidirectional encoder consumed via cross-attention."""

    num_layers: int = 32
    # encoder reuses d_model / heads / d_ff of the main config


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: Optional[int] = None  # default d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    stages: Tuple[Stage, ...] = ()
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window_size: int = 1024  # sliding-window width for "swa" layers
    attn_logit_softcap: Optional[float] = None
    # norms / activations
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    tie_embeddings: bool = False
    pos_embed: str = "rope"  # rope | learned | sinusoidal | none
    scale_embeddings: bool = False  # gemma: embed * sqrt(d_model)
    moe_scoring: str = "softmax"  # softmax | sigmoid (deepseek-v3)
    moe_impl: str = "scatter"  # scatter | a2a (expert-parallel all-to-all)
    loss_impl: str = "dense"  # dense | chunked (§Perf lever: no logit materialization)
    loss_chunk: int = 2048
    # substructures
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    vision: Optional[VisionStubConfig] = None
    audio: Optional[AudioStubConfig] = None
    encoder: Optional[EncoderConfig] = None
    # MHD heads (the paper's technique)
    num_aux_heads: int = 0
    # DeepSeek multi-token prediction
    mtp: bool = False
    # training details
    remat: str = "unit"  # "none" | "unit" | "dots"
    max_seq_len: int = 131072
    # citation for the assigned-architecture provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def stage_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    def validate(self) -> "ModelConfig":
        if self.stages and self.stage_layers() != self.num_layers:
            raise ValueError(
                f"{self.name}: stages cover {self.stage_layers()} layers, "
                f"config says num_layers={self.num_layers}"
            )
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: num_heads must be divisible by num_kv_heads")
        return self


def uniform_stages(num_layers: int, spec: LayerSpec) -> Tuple[Stage, ...]:
    """All layers identical: one stage scanning `num_layers` single-layer units."""
    return (Stage(block=(spec,), repeats=num_layers),)


def patterned_stages(
    num_layers: int, pattern: Sequence[LayerSpec]
) -> Tuple[Stage, ...]:
    """Repeat `pattern` as many whole times as fits; remainder = trailing stage."""
    unit = len(pattern)
    reps, rem = divmod(num_layers, unit)
    stages: List[Stage] = []
    if reps:
        stages.append(Stage(block=tuple(pattern), repeats=reps))
    if rem:
        stages.append(Stage(block=tuple(pattern[:rem]), repeats=1))
    return tuple(stages)
