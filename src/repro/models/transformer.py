"""Unified decoder LM covering every assigned architecture family.

Depth is organized as *stages* of scanned repeat-units (config.py). A unit's
parameters are stacked with a leading ``repeats`` dim; the forward pass scans
over them (O(unit) HLO). Heterogeneous layouts — gemma3's 5 local : 1 global,
llama-vision's cross-attention interleave, zamba2's shared attention block,
deepseek's dense-then-MoE split — are all expressed as unit patterns.

Public API (pure functions):
  init_lm(key, cfg)                      -> params
  apply_lm(params, cfg, batch, ...)      -> {"logits", "hidden", "aux_heads", "aux_loss"}
  lm_loss(params, cfg, batch)            -> (loss, metrics)
  init_lm_cache(cfg, batch, cache_len)   -> caches
  decode_step(params, cfg, token, caches, ...) -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import maybe_shard
from repro.models.config import LayerSpec, ModelConfig, Stage
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _attn_dims(cfg: ModelConfig, cross: bool = False) -> L.AttnDims:
    kv_in = None
    if cross and cfg.vision is not None:
        kv_in = cfg.d_model  # vision tokens are projected to d_model first
    return L.AttnDims(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        kv_input_dim=kv_in,
    )


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    if spec.attn in ("full", "swa"):
        if cfg.mla is not None:
            p["attn"] = MLA.init_mla(ks[0], cfg.d_model, cfg.num_heads, cfg.mla, dtype)
        else:
            p["attn"] = L.init_attention(ks[0], _attn_dims(cfg), dtype)
        p["attn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    elif spec.attn == "cross":
        p["attn"] = L.init_attention(ks[0], _attn_dims(cfg, cross=True), dtype)
        p["attn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
        p["cross_gate"] = jnp.zeros((), dtype)  # llama-vision tanh gate
    elif spec.attn == "mamba2":
        p["attn"] = SSM.init_mamba2(ks[0], cfg.d_model, cfg.mamba, dtype)
        p["attn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    elif spec.attn != "none":
        raise ValueError(spec.attn)

    if spec.cross_attn:  # whisper decoder sublayer
        p["xattn"] = L.init_attention(ks[1], _attn_dims(cfg), dtype)
        p["xattn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)

    if spec.ffn == "dense":
        p["ffn"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)
        p["ffn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    elif spec.ffn == "moe":
        p["ffn"] = MOE.init_moe(ks[2], cfg.d_model, cfg.moe, cfg.act, dtype)
        p["ffn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    elif spec.ffn == "moe_dense_parallel":  # arctic: dense residual ∥ MoE
        p["ffn"] = MOE.init_moe(ks[2], cfg.d_model, cfg.moe, cfg.act, dtype)
        p["ffn_dense"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dtype)
        p["ffn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    elif spec.ffn != "none":
        raise ValueError(spec.ffn)
    return p


def _init_unit(key, cfg: ModelConfig, block: Tuple[LayerSpec, ...], dtype):
    keys = jax.random.split(key, len(block))
    return {f"layer{i}": _init_layer(keys[i], cfg, spec, dtype)
            for i, spec in enumerate(block)}


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32):
    cfg.validate()
    n_stages = len(cfg.stages)
    keys = jax.random.split(key, n_stages + 10)
    params: Dict[str, Any] = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
    }
    for si, stage in enumerate(cfg.stages):
        unit_keys = jax.random.split(keys[1 + si], stage.repeats)
        params[f"stage{si}"] = jax.vmap(
            lambda k: _init_unit(k, cfg, stage.block, dtype)
        )(unit_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[n_stages + 1], cfg.d_model,
                                         cfg.vocab_size, dtype)
    if cfg.num_aux_heads:
        params["aux_heads"] = (
            jax.random.normal(keys[n_stages + 2],
                              (cfg.num_aux_heads, cfg.d_model, cfg.vocab_size))
            * (1.0 / math.sqrt(cfg.d_model))
        ).astype(dtype)
    if any(s.shared_attn for st in cfg.stages for s in st.block):
        params["shared_attn"] = L.init_attention(keys[n_stages + 3],
                                                 _attn_dims(cfg), dtype)
        params["shared_attn_norm"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
    if cfg.vision is not None:
        params["vision_proj"] = L.dense_init(keys[n_stages + 4],
                                             cfg.vision.embed_dim,
                                             cfg.d_model, dtype)
    if cfg.audio is not None:
        params["audio_proj"] = L.dense_init(keys[n_stages + 5],
                                            cfg.audio.frame_dim,
                                            cfg.d_model, dtype)
        params["encoder"] = _init_encoder(keys[n_stages + 6], cfg, dtype)
    if cfg.pos_embed == "learned":
        params["pos_embed"] = (jax.random.normal(
            keys[n_stages + 7], (cfg.max_seq_len, cfg.d_model)) * 0.02).astype(dtype)
    if cfg.mtp:
        params["mtp"] = {
            "proj": L.dense_init(keys[n_stages + 8], 2 * cfg.d_model,
                                 cfg.d_model, dtype),
            "norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
            "layer": _init_layer(keys[n_stages + 9], cfg,
                                 LayerSpec(attn="full", ffn="dense"), dtype),
        }
    return params


def _init_encoder(key, cfg: ModelConfig, dtype):
    enc = cfg.encoder
    keys = jax.random.split(key, 2)
    spec = LayerSpec(attn="full", ffn="dense")
    unit_keys = jax.random.split(keys[0], enc.num_layers)
    return {
        "stage0": jax.vmap(lambda k: _init_unit(k, cfg, (spec,), dtype))(unit_keys),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _sinusoidal(T: int, D: int) -> jnp.ndarray:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _layer_forward(lp, cfg: ModelConfig, spec: LayerSpec, x, *,
                   shared_attn_params, cross_src, enc_out, mask_kind_override=None):
    """One layer (full-sequence path). Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    rope = cfg.rope_theta if cfg.pos_embed == "rope" else None

    if spec.attn in ("full", "swa"):
        h = L.norm_apply(lp["attn_norm"], x, cfg.norm)
        if cfg.mla is not None:
            a = MLA.mla_apply(lp["attn"], h, cfg.mla, cfg.num_heads,
                              rope_theta=cfg.rope_theta)
        else:
            mask_kind = mask_kind_override or ("swa" if spec.attn == "swa" else "causal")
            a = L.attention_apply(
                lp["attn"], _attn_dims(cfg), h,
                mask_kind=mask_kind, window=cfg.window_size,
                rope_theta=rope, logit_softcap=cfg.attn_logit_softcap)
        x = x + a
    elif spec.attn == "cross":
        h = L.norm_apply(lp["attn_norm"], x, cfg.norm)
        a = L.attention_apply(
            lp["attn"], _attn_dims(cfg, cross=True), h,
            mask_kind="none", kv_src=cross_src, rope_theta=None)
        x = x + jnp.tanh(lp["cross_gate"]).astype(x.dtype) * a
    elif spec.attn == "mamba2":
        h = L.norm_apply(lp["attn_norm"], x, cfg.norm)
        x = x + SSM.mamba2_apply(lp["attn"], h, cfg.mamba)

    if spec.shared_attn:
        h = L.norm_apply(shared_attn_params["norm"], x, cfg.norm)
        a = L.attention_apply(
            shared_attn_params["attn"], _attn_dims(cfg), h,
            mask_kind="causal", rope_theta=rope)
        x = x + a

    if spec.cross_attn:
        h = L.norm_apply(lp["xattn_norm"], x, cfg.norm)
        a = L.attention_apply(
            lp["xattn"], _attn_dims(cfg), h,
            mask_kind="none", kv_src=enc_out, rope_theta=None)
        x = x + a

    if spec.ffn == "dense":
        h = L.norm_apply(lp["ffn_norm"], x, cfg.norm)
        x = x + L.mlp_apply(lp["ffn"], h, cfg.act)
    elif spec.ffn in ("moe", "moe_dense_parallel"):
        h = L.norm_apply(lp["ffn_norm"], x, cfg.norm)
        if cfg.moe_impl == "a2a":
            from repro.models.moe_a2a import moe_apply_a2a

            y, moe_aux = moe_apply_a2a(lp["ffn"], h, cfg.moe, cfg.act,
                                       scoring=cfg.moe_scoring)
        else:
            y, moe_aux = MOE.moe_apply(lp["ffn"], h, cfg.moe, cfg.act,
                                       scoring=cfg.moe_scoring)
        if spec.ffn == "moe_dense_parallel":
            y = y + L.mlp_apply(lp["ffn_dense"], h, cfg.act)
        x = x + y
        aux = aux + moe_aux
    x = maybe_shard(x, "batch", "seq", "model")
    return x, aux


def _run_stages(params, cfg: ModelConfig, x, stages, prefix, *,
                shared_attn_params=None, cross_src=None, enc_out=None,
                mask_kind_override=None):
    """Scan every stage's stacked units over x. Returns (x, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)

    for si, stage in enumerate(stages):
        stacked = params[f"{prefix}{si}"]

        def unit_fn(carry, unit_params, _stage=stage):
            h, aux_acc = carry
            for li, spec in enumerate(_stage.block):
                h, aux = _layer_forward(
                    unit_params[f"layer{li}"], cfg, spec, h,
                    shared_attn_params=shared_attn_params,
                    cross_src=cross_src, enc_out=enc_out,
                    mask_kind_override=mask_kind_override)
                aux_acc = aux_acc + aux
            return (h, aux_acc), None

        if cfg.remat != "none":
            unit_fn = jax.checkpoint(unit_fn, prevent_cse=False)

        r1 = _nested_factor(stage.repeats) if cfg.remat == "nested" else 0
        if stage.repeats == 1:
            (x, total_aux), _ = unit_fn(
                (x, total_aux), jax.tree.map(lambda a: a[0], stacked))
        elif r1:
            # √-depth remat: outer scan over r1 groups, each group a
            # checkpointed inner scan over r2 units — residual stacks hold
            # r1 + r2 activations instead of r1·r2 (§Perf lever)
            r2 = stage.repeats // r1

            def group_fn(carry, group_params):
                return jax.lax.scan(unit_fn, carry, group_params)

            grouped = jax.tree.map(
                lambda a: a.reshape((r1, r2) + a.shape[1:]), stacked)
            (x, total_aux), _ = jax.lax.scan(
                jax.checkpoint(group_fn, prevent_cse=False),
                (x, total_aux), grouped)
        else:
            (x, total_aux), _ = jax.lax.scan(
                unit_fn, (x, total_aux), stacked)
    return x, total_aux


def _nested_factor(repeats: int) -> int:
    """Largest r1 <= sqrt(repeats) dividing repeats; 0 if not worthwhile."""
    if repeats < 8:
        return 0
    r1 = int(math.sqrt(repeats))
    while r1 > 1 and repeats % r1:
        r1 -= 1
    return r1 if r1 > 1 else 0


def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * math.sqrt(cfg.d_model)
    return x


def _add_positional(params, cfg: ModelConfig, x, offset: int = 0):
    T = x.shape[1]
    if cfg.pos_embed == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], offset, T, axis=0)[None].astype(x.dtype)
    elif cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(T, cfg.d_model)[None].astype(x.dtype)
    return x


def encode_audio(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings (B, T_enc, frame_dim)."""
    x = jnp.einsum("btf,fd->btd", frames, params["audio_proj"],
                   preferred_element_type=jnp.float32).astype(frames.dtype)
    x = x + _sinusoidal(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    x = maybe_shard(x, "batch", "seq", "model")
    enc_stage = (Stage(block=(LayerSpec(attn="full", ffn="dense"),),
                       repeats=cfg.encoder.num_layers),)
    x, _ = _run_stages(params["encoder"], cfg, x, enc_stage, "stage",
                       mask_kind_override="none")
    return L.norm_apply(params["encoder"]["final_norm"], x, cfg.norm)


def _heads(params, cfg: ModelConfig, hidden):
    """Main + aux logits from final hidden states."""
    head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", hidden, head_w,
                        preferred_element_type=jnp.float32)
    logits = maybe_shard(logits, "batch", "seq", "model")
    aux_logits = None
    if cfg.num_aux_heads:
        aux_logits = jnp.einsum("...d,mdv->m...v", hidden, params["aux_heads"],
                                preferred_element_type=jnp.float32)
    return logits, aux_logits


def apply_lm(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Full-sequence forward.

    batch: {"tokens": (B,T)} plus optionally "vision_embeds" (B,P,v_dim)
    or "audio_frames" (B,T_enc,f_dim).
    Returns dict with hidden (B,T,D), logits (B,T,V), aux_heads (m,B,T,V)|None,
    aux_loss scalar, and (if cfg.mtp) mtp_hidden.
    """
    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, tokens)
    x = _add_positional(params, cfg, x)
    x = maybe_shard(x, "batch", "seq", "model")

    cross_src = None
    if cfg.vision is not None:
        v = batch["vision_embeds"]
        cross_src = jnp.einsum("bpe,ed->bpd", v, params["vision_proj"],
                               preferred_element_type=jnp.float32).astype(x.dtype)
    enc_out = None
    if cfg.audio is not None:
        enc_out = encode_audio(params, cfg, batch["audio_frames"])

    shared = None
    if "shared_attn" in params:
        shared = {"attn": params["shared_attn"],
                  "norm": params["shared_attn_norm"]}

    x, aux_loss = _run_stages(params, cfg, x, cfg.stages, "stage",
                              shared_attn_params=shared,
                              cross_src=cross_src, enc_out=enc_out)
    hidden = L.norm_apply(params["final_norm"], x, cfg.norm)
    logits, aux_logits = _heads(params, cfg, hidden)

    out = {"hidden": hidden, "logits": logits, "aux_heads": aux_logits,
           "aux_loss": aux_loss}

    if cfg.mtp:
        # DeepSeek MTP: predict t+2 from [h_t ; emb(tok_{t+1})]
        emb_next = _embed_tokens(params, cfg, jnp.roll(tokens, -1, axis=1))
        mtp_in = jnp.concatenate([hidden, emb_next.astype(hidden.dtype)], axis=-1)
        h = jnp.einsum("...e,ed->...d", mtp_in, params["mtp"]["proj"],
                       preferred_element_type=jnp.float32).astype(hidden.dtype)
        h = L.norm_apply(params["mtp"]["norm"], h, cfg.norm)
        h, _ = _layer_forward(params["mtp"]["layer"], cfg,
                              LayerSpec(attn="full", ffn="dense"), h,
                              shared_attn_params=None, cross_src=None,
                              enc_out=None)
        out["mtp_hidden"] = h
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, valid=None):
    """Mean next-token CE. logits (..., V) fp32; labels int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if valid is not None:
        nll = nll * valid
        return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1.0)
    return jnp.mean(nll)


def _chunked_xent(hidden, head_w, labels, chunk: int):
    """CE without materializing (B, T, V) logits all at once.

    §Perf lever: for 262k vocabs the full logit tensor dominates activation
    memory. Chunking is along TIME — each (B, chunk_t, D) slice keeps the
    batch sharding intact (flat-token chunks would concentrate a chunk on a
    subset of devices and force gathers). Per-chunk remat keeps the scan
    from stacking chunk logits as backward residuals.
    """
    B, T, D = hidden.shape
    n = B * T
    pad = (-T) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    nchunks = hidden.shape[1] // chunk
    hs = hidden.reshape(B, nchunks, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, nchunks, chunk).swapaxes(0, 1)
    valid = (jnp.arange(hidden.shape[1]) < T).reshape(
        nchunks, chunk).astype(jnp.float32)

    def body(acc, xs):
        h, lab, v = xs
        logits = jnp.einsum("bcd,dv->bcv", h, head_w,
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((logz - ll) * v[None, :]), None

    total, _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                            jnp.zeros((), jnp.float32), (hs, ls, valid))
    return total / n


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Next-token loss (tokens shifted internally); returns (loss, metrics)."""
    out = apply_lm(params, cfg, batch)
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    if cfg.loss_impl == "chunked":
        head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ce = _chunked_xent(out["hidden"][:, :-1], head_w, labels, cfg.loss_chunk)
    else:
        ce = softmax_xent(out["logits"][:, :-1].astype(jnp.float32), labels)
    loss = ce + out["aux_loss"]
    metrics = {"ce": ce, "aux_loss": out["aux_loss"]}
    if cfg.mtp:
        head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        mtp_logits = jnp.einsum("btd,dv->btv", out["mtp_hidden"][:, :-2], head_w,
                                preferred_element_type=jnp.float32)
        mtp_ce = softmax_xent(mtp_logits, tokens[:, 2:])
        loss = loss + 0.3 * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serve path)
# ---------------------------------------------------------------------------

def _layer_cache_shape(cfg: ModelConfig, spec: LayerSpec, batch: int,
                       cache_len: int, dtype):
    caches = {}
    if spec.attn in ("full", "swa"):
        # enc-dec (whisper): self-attn cache is decoder-length; cache_len is
        # the encoder frame count (used by the cross-attn cache below)
        self_len = cfg.audio.decoder_len if cfg.audio is not None else cache_len
        if cfg.mla is not None:
            caches["attn"] = MLA.init_mla_cache(batch, self_len, cfg.mla, dtype)
        else:
            length = min(cfg.window_size, self_len) if spec.attn == "swa" else self_len
            caches["attn"] = L.init_kv_cache(batch, length, cfg.num_kv_heads,
                                             cfg.resolved_head_dim, dtype)
    elif spec.attn == "mamba2":
        caches["attn"] = SSM.init_mamba2_cache(batch, cfg.d_model, cfg.mamba, dtype)
    elif spec.attn == "cross":
        caches["attn"] = {
            "k": jnp.zeros((batch, cfg.vision.num_patches, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype),
            "v": jnp.zeros((batch, cfg.vision.num_patches, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype),
        }
    if spec.shared_attn:
        caches["shared_attn"] = L.init_kv_cache(batch, cache_len, cfg.num_kv_heads,
                                                cfg.resolved_head_dim, dtype)
    if spec.cross_attn:
        enc_len = cache_len  # encoder length for whisper decode
        caches["xattn"] = {
            "k": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype),
            "v": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype),
        }
    return caches


def init_lm_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=jnp.bfloat16):
    """Nested cache pytree mirroring the stage structure (stacked per unit)."""
    caches = {}
    for si, stage in enumerate(cfg.stages):
        unit = {f"layer{li}": _layer_cache_shape(cfg, spec, batch, cache_len, dtype)
                for li, spec in enumerate(stage.block)}
        caches[f"stage{si}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (stage.repeats,) + a.shape), unit)
    caches["index"] = jnp.zeros((), jnp.int32)
    return caches


def _cross_decode(attn_params, cfg, x, cache):
    dims = _attn_dims(cfg)
    B = x.shape[0]
    q = jnp.einsum("...d,dh->...h", x, attn_params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(B, 1, dims.num_heads, dims.head_dim)
    out = L.attention_scores(q, cache["k"].astype(x.dtype),
                             cache["v"].astype(x.dtype), None)
    out = out.reshape(B, 1, dims.num_heads * dims.head_dim)
    return jnp.einsum("...h,hd->...d", out, attn_params["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _layer_decode(lp, cfg: ModelConfig, spec: LayerSpec, x, cache, *,
                  shared_attn_params):
    rope = cfg.rope_theta if cfg.pos_embed == "rope" else None
    new_cache = dict(cache)
    if spec.attn in ("full", "swa"):
        h = L.norm_apply(lp["attn_norm"], x, cfg.norm)
        if cfg.mla is not None:
            a, new_cache["attn"] = MLA.mla_decode(
                lp["attn"], h, cache["attn"], cfg.mla, cfg.num_heads,
                rope_theta=cfg.rope_theta)
        else:
            window = cfg.window_size if spec.attn == "swa" else 0
            a, new_cache["attn"] = L.attention_decode(
                lp["attn"], _attn_dims(cfg), h, cache["attn"],
                window=window, rope_theta=rope,
                logit_softcap=cfg.attn_logit_softcap)
        x = x + a
    elif spec.attn == "cross":
        h = L.norm_apply(lp["attn_norm"], x, cfg.norm)
        a = _cross_decode(lp["attn"], cfg, h, cache["attn"])
        x = x + jnp.tanh(lp["cross_gate"]).astype(x.dtype) * a
    elif spec.attn == "mamba2":
        h = L.norm_apply(lp["attn_norm"], x, cfg.norm)
        a, new_cache["attn"] = SSM.mamba2_decode(lp["attn"], h, cache["attn"],
                                                 cfg.mamba)
        x = x + a

    if spec.shared_attn:
        h = L.norm_apply(shared_attn_params["norm"], x, cfg.norm)
        a, new_cache["shared_attn"] = L.attention_decode(
            shared_attn_params["attn"], _attn_dims(cfg), h,
            cache["shared_attn"], rope_theta=rope)
        x = x + a

    if spec.cross_attn:
        h = L.norm_apply(lp["xattn_norm"], x, cfg.norm)
        x = x + _cross_decode(lp["xattn"], cfg, h, cache["xattn"])

    if spec.ffn == "dense":
        h = L.norm_apply(lp["ffn_norm"], x, cfg.norm)
        x = x + L.mlp_apply(lp["ffn"], h, cfg.act)
    elif spec.ffn in ("moe", "moe_dense_parallel"):
        h = L.norm_apply(lp["ffn_norm"], x, cfg.norm)
        y, _ = MOE.moe_apply(lp["ffn"], h, cfg.moe, cfg.act,
                             scoring=cfg.moe_scoring)
        if spec.ffn == "moe_dense_parallel":
            y = y + L.mlp_apply(lp["ffn_dense"], h, cfg.act)
        x = x + y
    return x, new_cache


def prefill_cross_caches(params, cfg: ModelConfig, caches, *,
                         vision_embeds=None, audio_frames=None):
    """Fill cross-attention K/V caches from the modality source.

    Must run once before decode for VLM (vision cross layers) and enc-dec
    (whisper decoder cross sublayers). Returns updated caches.
    """
    cross_src = None
    if vision_embeds is not None:
        cross_src = jnp.einsum("bpe,ed->bpd", vision_embeds,
                               params["vision_proj"],
                               preferred_element_type=jnp.float32
                               ).astype(vision_embeds.dtype)
    enc_out = None
    if audio_frames is not None:
        enc_out = encode_audio(params, cfg, audio_frames)

    dims = _attn_dims(cfg)
    KV, hd = dims.num_kv_heads, dims.head_dim

    def kv_for(stacked_wk, stacked_wv, src):
        # stacked_w*: (R, D_src, KV*hd); src: (B, S, D_src)
        k = jnp.einsum("bsd,rdh->rbsh", src, stacked_wk,
                       preferred_element_type=jnp.float32)
        v = jnp.einsum("bsd,rdh->rbsh", src, stacked_wv,
                       preferred_element_type=jnp.float32)
        R, B, S, _ = k.shape
        return (k.reshape(R, B, S, KV, hd), v.reshape(R, B, S, KV, hd))

    caches = jax.tree.map(lambda x: x, caches)  # shallow copy
    for si, stage in enumerate(cfg.stages):
        for li, spec in enumerate(stage.block):
            lp = params[f"stage{si}"][f"layer{li}"]
            layer_cache = dict(caches[f"stage{si}"][f"layer{li}"])
            if spec.attn == "cross" and cross_src is not None:
                k, v = kv_for(lp["attn"]["wk"], lp["attn"]["wv"], cross_src)
                tgt = layer_cache["attn"]
                layer_cache["attn"] = {**tgt, "k": k.astype(tgt["k"].dtype),
                                       "v": v.astype(tgt["v"].dtype)}
            if spec.cross_attn and enc_out is not None:
                k, v = kv_for(lp["xattn"]["wk"], lp["xattn"]["wv"], enc_out)
                tgt = layer_cache["xattn"]
                layer_cache["xattn"] = {**tgt, "k": k.astype(tgt["k"].dtype),
                                        "v": v.astype(tgt["v"].dtype)}
            stage_cache = dict(caches[f"stage{si}"])
            stage_cache[f"layer{li}"] = layer_cache
            caches[f"stage{si}"] = stage_cache
    return caches


def decode_step(params, cfg: ModelConfig, token, caches):
    """One-token decode. token: (B, 1) int32. Returns (logits (B,1,V), caches)."""
    x = _embed_tokens(params, cfg, token)
    x = _add_positional(params, cfg, x, offset=0) if cfg.pos_embed != "learned" else (
        x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], caches["index"] % cfg.max_seq_len, 1, axis=0
        )[None].astype(x.dtype))
    x = maybe_shard(x, "batch", "seq", "model")

    shared = None
    if "shared_attn" in params:
        shared = {"attn": params["shared_attn"],
                  "norm": params["shared_attn_norm"]}

    new_caches = {"index": caches["index"] + 1}
    for si, stage in enumerate(cfg.stages):
        stacked_p = params[f"stage{si}"]
        stacked_c = caches[f"stage{si}"]

        def unit_fn(h, xs, _stage=stage):
            unit_params, unit_cache = xs
            new_unit_cache = {}
            for li, spec in enumerate(_stage.block):
                h, new_unit_cache[f"layer{li}"] = _layer_decode(
                    unit_params[f"layer{li}"], cfg, spec, h,
                    unit_cache[f"layer{li}"], shared_attn_params=shared)
            return h, new_unit_cache

        if stage.repeats == 1:
            first = lambda a: a[0]
            x, uc = unit_fn(x, (jax.tree.map(first, stacked_p),
                                jax.tree.map(first, stacked_c)))
            new_caches[f"stage{si}"] = jax.tree.map(lambda a: a[None], uc)
        else:
            x, new_caches[f"stage{si}"] = jax.lax.scan(
                unit_fn, x, (stacked_p, stacked_c))

    hidden = L.norm_apply(params["final_norm"], x, cfg.norm)
    logits, _ = _heads(params, cfg, hidden)
    return logits, new_caches
