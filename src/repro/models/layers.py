"""Core neural layers (pure functions: init_* returns params, *_apply runs).

All matmuls accumulate in fp32 (``preferred_element_type``) — MXU-native.
Weight layouts are chosen so the tensor-parallel ('model') axis shards the
*second* dim of up-projections and the *first* dim of down-projections.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import maybe_shard


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str = "rmsnorm", dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def norm_apply(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
        return y.astype(x.dtype)
    if kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str = "silu", dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }
    if act == "silu":  # gated (SwiGLU) variant
        params["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return params


def mlp_apply(params, x, act: str = "silu"):
    up = jnp.einsum("...d,df->...f", x, params["w_up"],
                    preferred_element_type=jnp.float32)
    if act == "silu":
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"],
                          preferred_element_type=jnp.float32)
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    elif act == "relu2":  # squared ReLU (nemotron/minitron)
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(act)
    h = h.astype(x.dtype)
    if h.ndim == 3:
        h = maybe_shard(h, "batch", "seq", "model")
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; full / sliding-window / bidirectional / cross)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    kv_input_dim: Optional[int] = None  # cross-attn: K/V source dim


def init_attention(key, dims: AttnDims, dtype=jnp.float32):
    k = jax.random.split(key, 8)
    kv_in = dims.kv_input_dim or dims.d_model
    H, KV, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
    params = {
        "wq": dense_init(k[0], dims.d_model, H * hd, dtype),
        "wk": dense_init(k[1], kv_in, KV * hd, dtype),
        "wv": dense_init(k[2], kv_in, KV * hd, dtype),
        "wo": dense_init(k[3], H * hd, dims.d_model, dtype),
    }
    if dims.qkv_bias:
        params["bq"] = jnp.zeros((H * hd,), dtype)
        params["bk"] = jnp.zeros((KV * hd,), dtype)
        params["bv"] = jnp.zeros((KV * hd,), dtype)
    if dims.qk_norm:
        params["q_norm"] = init_norm(hd, "rmsnorm", dtype)
        params["k_norm"] = init_norm(hd, "rmsnorm", dtype)
    return params


def _project_qkv(params, dims: AttnDims, x, kv_src, positions, kv_positions,
                 rope_theta: Optional[float]):
    H, KV, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
    q = jnp.einsum("...d,dh->...h", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("...d,dh->...h", kv_src, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("...d,dh->...h", kv_src, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if dims.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(q.shape[:-1] + (H, hd))
    k = k.reshape(k.shape[:-1] + (KV, hd))
    v = v.reshape(v.shape[:-1] + (KV, hd))
    if dims.qk_norm:
        q = norm_apply(params["q_norm"], q, "rmsnorm")
        k = norm_apply(params["k_norm"], k, "rmsnorm")
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kv_positions, rope_theta)
    return q, k, v


def attention_scores(q, k, v, mask, logit_softcap: Optional[float] = None):
    """Reference (XLA-fused) attention. q:(B,T,H,hd) k/v:(B,S,KV,hd).

    The Pallas flash kernel (kernels/flash_attention.py) implements the same
    math blockwise for TPU; this path is the oracle and the CPU/dry-run path.
    """
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV  # queries per kv head
    q = q.reshape(B, T, KV, G, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    if logit_softcap is not None:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, T, H, hd).astype(v.dtype)


# Above this many score entries per (batch, head), attention switches to the
# query-block scan path (flash-style: scores never materialize for the whole
# sequence at once). The Pallas kernel (kernels/flash_attention.py) is the
# TPU twin of this formulation.
BLOCKWISE_SCORE_THRESHOLD = 4_194_304  # 2048 x 2048
BLOCK_Q = 512


def _blockwise_attention(q, k, v, mask_kind: str, window: int,
                         logit_softcap: Optional[float], block_q: int = BLOCK_Q):
    """Scan over query blocks; each block sees full K/V with masking.

    Bounds activation memory to O(block_q · S) per (batch, head) instead of
    O(T · S); with per-unit remat the backward pass recomputes blockwise too.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, T)
    pad = (-T) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (T + pad) // bq
    qb = q.reshape(B, nb, bq, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    kpos = jnp.arange(S)

    def one_block(carry, xs):
        q_i, ib = xs
        scores = jnp.einsum("btkgh,bskh->bkgts", q_i, k,
                            preferred_element_type=jnp.float32)
        scores = scores / math.sqrt(hd)
        if logit_softcap is not None:
            scores = logit_softcap * jnp.tanh(scores / logit_softcap)
        qpos = ib * bq + jnp.arange(bq)
        if mask_kind == "causal":
            m = kpos[None, :] <= qpos[:, None]
        elif mask_kind == "swa":
            m = (kpos[None, :] <= qpos[:, None]) & \
                (kpos[None, :] > qpos[:, None] - window)
        else:
            m = jnp.ones((bq, S), bool)
        scores = jnp.where(m[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return carry, out.astype(v.dtype)

    # checkpoint per block: backward recomputes one block's scores at a time
    # (otherwise scan stacks (nb, ..., bq, S) probs as residuals)
    _, outs = jax.lax.scan(jax.checkpoint(one_block, prevent_cse=False), 0,
                           (qb, jnp.arange(nb, dtype=jnp.int32)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, T + pad, H, hd)
    return out[:, :T]


def make_mask(T: int, S: int, kind: str, window: int = 0,
              q_offset: int = 0) -> Optional[jnp.ndarray]:
    """(1,1,1,T,S) boolean mask. kind: causal | swa | none."""
    if kind == "none":
        return None
    qpos = q_offset + jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    causal = kpos <= qpos
    if kind == "causal":
        m = causal
    elif kind == "swa":
        m = causal & (kpos > qpos - window)
    else:
        raise ValueError(kind)
    return m[None, None, None]


def attention_apply(
    params,
    dims: AttnDims,
    x,
    *,
    mask_kind: str = "causal",
    window: int = 0,
    rope_theta: Optional[float] = 10_000.0,
    kv_src=None,
    positions=None,
    kv_positions=None,
    logit_softcap: Optional[float] = None,
):
    """Self- or cross-attention over full sequences (training / prefill)."""
    B, T = x.shape[0], x.shape[1]
    kv_src = x if kv_src is None else kv_src
    S = kv_src.shape[1]
    if positions is None:
        positions = jnp.arange(T)[None]
    if kv_positions is None:
        kv_positions = jnp.arange(S)[None]
    q, k, v = _project_qkv(params, dims, x, kv_src, positions, kv_positions,
                           rope_theta)
    q = maybe_shard(q, "batch", "seq", "model", "none")
    k = maybe_shard(k, "batch", "seq", "model", "none")
    v = maybe_shard(v, "batch", "seq", "model", "none")
    if T * S >= BLOCKWISE_SCORE_THRESHOLD:
        out = _blockwise_attention(q, k, v, mask_kind, window, logit_softcap)
    else:
        mask = make_mask(T, S, mask_kind, window)
        out = attention_scores(q, k, v, mask, logit_softcap)
    out = out.reshape(B, T, dims.num_heads * dims.head_dim)
    return jnp.einsum("...h,hd->...d", out, params["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def attention_decode(
    params,
    dims: AttnDims,
    x,  # (B, 1, D)
    cache: dict,  # {"k": (B, S, KV, hd), "v": ..., "index": scalar}
    *,
    window: int = 0,
    rope_theta: Optional[float] = 10_000.0,
    logit_softcap: Optional[float] = None,
):
    """One-token decode against a ring/linear KV cache.

    For sliding-window layers the cache length is `window` and indexing is
    modular (ring buffer); for full layers the cache length is max_seq.
    """
    B = x.shape[0]
    S = cache["k"].shape[1]
    idx = cache["index"]  # absolute position of the new token
    positions = jnp.full((B, 1), idx, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, dims, x, x, positions, positions,
                                   rope_theta)
    slot = jnp.mod(idx, S)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kpos_slot = jnp.arange(S)
    # absolute position stored in each slot (ring semantics)
    wraps = (idx - kpos_slot + S) // S  # how many times slot was overwritten after kpos
    abs_pos = idx - jnp.mod(idx - kpos_slot, S)
    valid = (abs_pos >= 0) & (abs_pos <= idx)
    if window:
        valid &= abs_pos > idx - window
    mask = valid[None, None, None, None, :]
    KV, hd = dims.num_kv_heads, dims.head_dim
    out = attention_scores(q, k, v, mask, logit_softcap)
    out = out.reshape(B, 1, dims.num_heads * dims.head_dim)
    y = jnp.einsum("...h,hd->...d", out, params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = {"k": k, "v": v, "index": idx + 1}
    return y, new_cache


def init_kv_cache(batch: int, length: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, length, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, length, num_kv_heads, head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# causal conv1d (mamba2 frontend)
# ---------------------------------------------------------------------------

def init_causal_conv1d(key, channels: int, width: int, dtype=jnp.float32):
    std = 1.0 / math.sqrt(width)
    return {
        "w": (jax.random.normal(key, (width, channels)) * std).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d_apply(params, x):
    """Depthwise causal conv. x: (B, T, C) -> (B, T, C)."""
    width = params["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * params["w"][i][None, None, :]
        for i in range(width)
    )
    return out + params["b"][None, None, :]


def causal_conv1d_step(params, x_t, conv_state):
    """Single decode step. x_t: (B, C); conv_state: (B, width-1, C)."""
    width = params["w"].shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window, params["w"]) + params["b"]
    return out, window[:, 1:, :]
