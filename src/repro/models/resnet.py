"""ResNet family — the paper's client models (ResNet-18/34, He et al. 2016).

Functional JAX implementation with GroupNorm instead of BatchNorm: BN's
running statistics are ill-defined for non-IID decentralized clients (a
well-known FL issue), and GN keeps every client step pure/stateless. This
substitution is recorded in DESIGN.md §7.

The MHD interface every client model implements:
    apply(params, images) -> {"embedding": (B, E), "logits": (B, C),
                              "aux_logits": (m, B, C) | None}
with ``embedding`` the pre-logits feature ξ_i(x) used by embedding
distillation (Eq. 2) and aux heads the MHD chain (Eq. 5).

``tiny`` presets keep CPU experiments fast while preserving the
ResNet-18-vs-34 capacity ordering studied in §4.5 of the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet18"
    stage_sizes: Tuple[int, ...] = (2, 2, 2, 2)  # resnet18; resnet34=(3,4,6,3)
    width: int = 64
    num_classes: int = 1000
    num_aux_heads: int = 0
    groups: int = 8  # GroupNorm groups
    stem_stride: int = 1  # 1 for small images, 2 (+pool) for 224px
    source: str = "He et al., CVPR 2016 [14 in paper]"

    @property
    def embed_dim(self) -> int:
        return self.width * 8


def resnet18(num_classes: int, num_aux_heads: int = 0, width: int = 64):
    return ResNetConfig(name="resnet18", stage_sizes=(2, 2, 2, 2), width=width,
                        num_classes=num_classes, num_aux_heads=num_aux_heads)


def resnet34(num_classes: int, num_aux_heads: int = 0, width: int = 64):
    return ResNetConfig(name="resnet34", stage_sizes=(3, 4, 6, 3), width=width,
                        num_classes=num_classes, num_aux_heads=num_aux_heads)


def resnet_tiny(num_classes: int, num_aux_heads: int = 0, width: int = 8,
                stages: Tuple[int, ...] = (1, 1, 1, 1), name: str = "resnet_tiny"):
    """CPU-scale stand-in preserving the ResNet block structure."""
    return ResNetConfig(name=name, stage_sizes=stages, width=width,
                        num_classes=num_classes, num_aux_heads=num_aux_heads,
                        groups=4)


def resnet_tiny34(num_classes: int, num_aux_heads: int = 0, width: int = 8):
    """Deeper tiny variant: plays ResNet-34's role against resnet_tiny."""
    return resnet_tiny(num_classes, num_aux_heads, width,
                       stages=(2, 2, 2, 2), name="resnet_tiny34")


# ---------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    std = math.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, (kh, kw, cin, cout)) * std).astype(dtype)


def _conv(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_init(c: int, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _gn(params, x, groups: int, eps: float = 1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(B, H, W, C)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def _init_block(key, cin, cout, dtype):
    k = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(k[0], 3, 3, cin, cout, dtype),
        "gn1": _gn_init(cout, dtype),
        "conv2": _conv_init(k[1], 3, 3, cout, cout, dtype),
        "gn2": _gn_init(cout, dtype),
    }
    if cin != cout:
        p["proj"] = _conv_init(k[2], 1, 1, cin, cout, dtype)
        p["gn_proj"] = _gn_init(cout, dtype)
    return p


def _block(params, x, groups: int, stride: int):
    y = _conv(x, params["conv1"], stride)
    y = jax.nn.relu(_gn(params["gn1"], y, groups))
    y = _conv(y, params["conv2"], 1)
    y = _gn(params["gn2"], y, groups)
    if "proj" in params:
        x = _gn(params["gn_proj"], _conv(x, params["proj"], stride), groups)
    elif stride != 1:
        x = x[:, ::stride, ::stride, :]
    return jax.nn.relu(x + y)


def init_resnet(key, cfg: ResNetConfig, in_channels: int = 3,
                dtype=jnp.float32):
    keys = jax.random.split(key, 4 + sum(cfg.stage_sizes))
    params: Dict[str, Any] = {
        "stem": _conv_init(keys[0], 3, 3, in_channels, cfg.width, dtype),
        "stem_gn": _gn_init(cfg.width, dtype),
    }
    ki = 1
    cin = cfg.width
    for si, n_blocks in enumerate(cfg.stage_sizes):
        cout = cfg.width * (2 ** si)
        for bi in range(n_blocks):
            params[f"s{si}b{bi}"] = _init_block(keys[ki], cin, cout, dtype)
            ki += 1
            cin = cout
    emb = cfg.embed_dim
    params["head"] = (jax.random.normal(keys[ki], (emb, cfg.num_classes))
                      / math.sqrt(emb)).astype(dtype)
    params["head_b"] = jnp.zeros((cfg.num_classes,), dtype)
    if cfg.num_aux_heads:
        params["aux_heads"] = (
            jax.random.normal(keys[ki + 1],
                              (cfg.num_aux_heads, emb, cfg.num_classes))
            / math.sqrt(emb)).astype(dtype)
        params["aux_heads_b"] = jnp.zeros((cfg.num_aux_heads, cfg.num_classes),
                                          dtype)
    return params


def apply_resnet(params, cfg: ResNetConfig, images) -> Dict[str, Any]:
    x = _conv(images, params["stem"], cfg.stem_stride)
    x = jax.nn.relu(_gn(params["stem_gn"], x, cfg.groups))
    if cfg.stem_stride == 2:
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    for si, n_blocks in enumerate(cfg.stage_sizes):
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            x = _block(params[f"s{si}b{bi}"], x, cfg.groups, stride)
    embedding = jnp.mean(x, axis=(1, 2))  # (B, E) — ξ_i(x) for Eq. (2)
    logits = embedding @ params["head"] + params["head_b"]
    aux_logits = None
    if cfg.num_aux_heads:
        aux_logits = (jnp.einsum("be,mec->mbc", embedding, params["aux_heads"])
                      + params["aux_heads_b"][:, None, :])
    return {"embedding": embedding, "logits": logits, "aux_logits": aux_logits}
