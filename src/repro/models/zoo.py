"""Model zoo: a uniform bundle interface over every architecture family.

A ``ModelBundle`` is what the trainer, server, dry-run and MHD runtime see —
they never import family-specific code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.common.registry import Registry
from repro.models import resnet as RN
from repro.models import transformer as TF
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    name: str
    config: Any  # ModelConfig | ResNetConfig
    init: Callable[[Any], Any]  # key -> params
    apply: Callable[..., Dict[str, Any]]  # (params, batch) -> outputs
    loss: Callable[..., Any]  # (params, batch) -> (loss, metrics)
    init_cache: Optional[Callable[..., Any]] = None  # (batch, cache_len) -> caches
    decode_step: Optional[Callable[..., Any]] = None  # (params, token, caches)

    @property
    def is_lm(self) -> bool:
        return isinstance(self.config, ModelConfig)


def build_bundle(cfg: Union[ModelConfig, RN.ResNetConfig],
                 dtype=jnp.float32) -> ModelBundle:
    if isinstance(cfg, RN.ResNetConfig):
        return _resnet_bundle(cfg, dtype)
    return _lm_bundle(cfg, dtype)


def _resnet_bundle(cfg: RN.ResNetConfig, dtype) -> ModelBundle:
    def init(key):
        return RN.init_resnet(key, cfg, dtype=dtype)

    def apply(params, batch):
        return RN.apply_resnet(params, cfg, batch["images"])

    def loss(params, batch):
        out = apply(params, batch)
        ce = TF.softmax_xent(out["logits"].astype(jnp.float32), batch["labels"])
        acc = jnp.mean(
            (jnp.argmax(out["logits"], -1) == batch["labels"]).astype(jnp.float32))
        return ce, {"ce": ce, "acc": acc}

    return ModelBundle(name=cfg.name, config=cfg, init=init, apply=apply,
                       loss=loss)


def _lm_bundle(cfg: ModelConfig, dtype) -> ModelBundle:
    cfg.validate()

    def init(key):
        return TF.init_lm(key, cfg, dtype=dtype)

    def apply(params, batch):
        return TF.apply_lm(params, cfg, batch)

    def loss(params, batch):
        return TF.lm_loss(params, cfg, batch)

    def init_cache(batch, cache_len, cache_dtype=jnp.bfloat16):
        return TF.init_lm_cache(cfg, batch, cache_len, cache_dtype)

    def decode_step(params, token, caches):
        return TF.decode_step(params, cfg, token, caches)

    return ModelBundle(name=cfg.name, config=cfg, init=init, apply=apply,
                       loss=loss, init_cache=init_cache,
                       decode_step=decode_step)
