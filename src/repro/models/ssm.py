"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

TPU adaptation (DESIGN.md §3): instead of the CUDA selective-scan, the
sequence is processed in chunks — intra-chunk interactions are a dense
(L_c × L_c) masked matmul (MXU-friendly), inter-chunk state is carried by a
``lax.scan`` over chunks. The Pallas kernel (kernels/ssd_scan.py) fuses the
intra-chunk compute per (chunk, head) tile in VMEM; this module provides the
pure-jnp implementation used on CPU and as the kernel oracle.

Scalar-identities follow the Mamba2 paper: per head h with state N and head
dim P,   h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_tᵀ,   y_t = C_tᵀ h_t + D x_t.
ngroups = 1 (B, C shared across heads), as in the released models.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import maybe_shard
from repro.models.config import MambaConfig
from repro.models.layers import (
    causal_conv1d_apply,
    causal_conv1d_step,
    dense_init,
    init_causal_conv1d,
    init_norm,
    norm_apply,
)


def init_mamba2(key, d_model: int, cfg: MambaConfig, dtype=jnp.float32):
    k = jax.random.split(key, 6)
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    N = cfg.d_state
    conv_ch = d_in + 2 * N  # x, B, C all pass through the causal conv
    # dt_bias init so that softplus(dt_bias) spans ~[1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(k[3], (H,))
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_proj": dense_init(k[0], d_model, 2 * d_in + 2 * N + H, dtype),
        "conv": init_causal_conv1d(k[1], conv_ch, cfg.d_conv, dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": init_norm(d_in, "rmsnorm", dtype),
        "out_proj": dense_init(k[2], d_in, d_model, dtype),
    }


def _split_in_proj(z_xbc_dt, d_in: int, N: int, H: int):
    z = z_xbc_dt[..., :d_in]
    xbc = z_xbc_dt[..., d_in : 2 * d_in + 2 * N]
    dt = z_xbc_dt[..., 2 * d_in + 2 * N :]
    return z, xbc, dt


def ssd_reference(x, dt, A, B, C, D, chunk_size: int = 0):
    """Sequential-scan oracle.

    x: (Bt, T, H, P); dt: (Bt, T, H); A: (H,); B, C: (Bt, T, N); D: (H,)
    returns y: (Bt, T, H, P), final_state: (Bt, H, P, N)
    """
    Bt, T, H, P = x.shape
    N = B.shape[-1]
    decay = jnp.exp(dt * A[None, None, :])  # (Bt, T, H)

    def step(h, inputs):
        x_t, dt_t, dec_t, B_t, C_t = inputs
        # h: (Bt, H, P, N)
        h = h * dec_t[:, :, None, None] + (
            (dt_t[:, :, None] * x_t)[..., None] * B_t[:, None, None, :]
        )
        y_t = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y_t

    init = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (
        x.astype(jnp.float32).swapaxes(0, 1),
        dt.swapaxes(0, 1),
        decay.swapaxes(0, 1),
        B.astype(jnp.float32).swapaxes(0, 1),
        C.astype(jnp.float32).swapaxes(0, 1),
    )
    h_final, ys = jax.lax.scan(step, init, xs)
    y = ys.swapaxes(0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_chunked(x, dt, A, B, C, D, chunk_size: int = 64):
    """Chunked SSD (training path): O(T·L_c) with MXU-dense intra-chunk math."""
    Bt, T, H, P = x.shape
    N = B.shape[-1]
    L = chunk_size
    assert T % L == 0, f"seq {T} not divisible by chunk {L}"
    nc = T // L

    xs = x.astype(jnp.float32).reshape(Bt, nc, L, H, P)
    dts = dt.reshape(Bt, nc, L, H)
    Bs = B.astype(jnp.float32).reshape(Bt, nc, L, N)
    Cs = C.astype(jnp.float32).reshape(Bt, nc, L, N)

    a = dts * A[None, None, None, :]  # (Bt, nc, L, H) log-decay increments
    s = jnp.cumsum(a, axis=2)  # inclusive cumulative log decay within chunk
    total = s[:, :, -1, :]  # (Bt, nc, H)

    # intra-chunk: M[t, u] = C_t·B_u · exp(s_t - s_u) · dt_u   for u <= t
    CB = jnp.einsum("bcln,bcmn->bclm", Cs, Bs)  # (Bt, nc, L, L)
    seg = s[:, :, :, None, :] - s[:, :, None, :, :]  # (Bt, nc, L, L, H)
    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, None, :, :, None]
    # mask BEFORE exp: upper-triangle seg is positive and overflows, and
    # grad-through-where of an inf produces NaN
    gate = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
    M = CB[..., None] * gate * dts[:, :, None, :, :]  # (Bt,nc,L,L,H)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", M, xs)

    # chunk-end states: G = Σ_u exp(total - s_u) dt_u B_u x_uᵀ
    w = jnp.exp(total[:, :, None, :] - s) * dts  # (Bt, nc, L, H)
    G = jnp.einsum("bclh,bcln,bclhp->bchpn", w, Bs, xs)  # (Bt,nc,H,P,N)

    # inter-chunk recurrence over nc chunks
    def step(h, inputs):
        G_c, tot_c = inputs  # (Bt,H,P,N), (Bt,H)
        h_out = h  # state entering this chunk
        h = h * jnp.exp(tot_c)[:, :, None, None] + G_c
        return h, h_out

    init = jnp.zeros((Bt, H, P, N), jnp.float32)
    h_final, h_starts = jax.lax.scan(
        step, init, (G.swapaxes(0, 1), total.swapaxes(0, 1))
    )
    h_starts = h_starts.swapaxes(0, 1)  # (Bt, nc, H, P, N)

    # inter-chunk contribution: y += C_t · (exp(s_t) h_start)
    y_inter = jnp.einsum(
        "bcln,bclh,bchpn->bclhp", Cs, jnp.exp(s), h_starts
    )
    y = (y_intra + y_inter).reshape(Bt, T, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_final


def mamba2_apply(params, x, cfg: MambaConfig, *, use_chunked: bool = True):
    """Full-sequence forward. x: (B, T, D) -> (B, T, D)."""
    B_, T, D_model = x.shape
    d_in = cfg.d_inner(D_model)
    H = cfg.num_heads(D_model)
    N = cfg.d_state

    zxd = jnp.einsum("...d,de->...e", x, params["in_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    z, xbc, dt_raw = _split_in_proj(zxd, d_in, N, H)
    xbc = jax.nn.silu(causal_conv1d_apply(params["conv"], xbc))
    xc = xbc[..., :d_in].reshape(B_, T, H, cfg.head_dim)
    Bmat = xbc[..., d_in : d_in + N]
    Cmat = xbc[..., d_in + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    ssd = ssd_chunked if (use_chunked and T % cfg.chunk_size == 0) else ssd_reference
    y, _ = ssd(xc, dt, A, Bmat, Cmat, params["D"],
               chunk_size=cfg.chunk_size)
    y = y.reshape(B_, T, d_in)
    y = norm_apply(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    return jnp.einsum("...e,ed->...d", y, params["out_proj"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def init_mamba2_cache(batch: int, d_model: int, cfg: MambaConfig,
                      dtype=jnp.float32):
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    return {
        "ssm": jnp.zeros((batch, H, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in + 2 * cfg.d_state), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def mamba2_decode(params, x, cache, cfg: MambaConfig):
    """Single-token step. x: (B, 1, D)."""
    B_, _, D_model = x.shape
    d_in = cfg.d_inner(D_model)
    H = cfg.num_heads(D_model)
    N = cfg.d_state

    zxd = jnp.einsum("btd,de->bte", x, params["in_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)[:, 0]
    z, xbc, dt_raw = _split_in_proj(zxd, d_in, N, H)
    xbc, conv_state = causal_conv1d_step(params["conv"], xbc, cache["conv"])
    xbc = jax.nn.silu(xbc)
    xc = xbc[..., :d_in].reshape(B_, H, cfg.head_dim).astype(jnp.float32)
    Bmat = xbc[..., d_in : d_in + N].astype(jnp.float32)
    Cmat = xbc[..., d_in + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (B,H)

    h = cache["ssm"] * decay[:, :, None, None] + (
        (dt[:, :, None] * xc)[..., None] * Bmat[:, None, None, :]
    )
    y = jnp.einsum("bhpn,bn->bhp", h, Cmat) + xc * params["D"][None, :, None]
    y = y.reshape(B_, d_in)
    y = norm_apply(params["norm"],
                   (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype))
    out = jnp.einsum("be,ed->bd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = {"ssm": h, "conv": conv_state, "index": cache["index"] + 1}
    return out[:, None, :], new_cache
