"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Queries and keys/values are low-rank-compressed; only the compressed KV
latent ``c_kv`` (kv_lora_rank) plus a small shared RoPE key (qk_rope_head_dim)
are cached at decode time — that 576-dim/position cache is why
deepseek-v3-671b participates in the ``long_500k`` shape (DESIGN.md §6).

Two paths:
  * ``mla_apply``  — training / prefill: materialize per-head K,V.
  * ``mla_decode`` — absorbed decode: queries are mapped into the latent
    space (W_uk absorbed into q), attention runs against the latent cache,
    and W_uv is applied after the attention reduction. No per-head KV is
    ever materialized.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import maybe_shard
from repro.models.config import MLAConfig
from repro.models.layers import apply_rope, dense_init, init_norm, norm_apply


def init_mla(key, d_model: int, num_heads: int, cfg: MLAConfig, dtype=jnp.float32):
    k = jax.random.split(key, 8)
    H = num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "w_dq": dense_init(k[0], d_model, cfg.q_lora_rank, dtype),
        "q_norm": init_norm(cfg.q_lora_rank, "rmsnorm", dtype),
        "w_uq": dense_init(k[1], cfg.q_lora_rank, H * (dn + dr), dtype),
        "w_dkv": dense_init(k[2], d_model, cfg.kv_lora_rank + dr, dtype),
        "kv_norm": init_norm(cfg.kv_lora_rank, "rmsnorm", dtype),
        "w_uk": (jax.random.normal(k[3], (cfg.kv_lora_rank, H, dn))
                 / math.sqrt(cfg.kv_lora_rank)).astype(dtype),
        "w_uv": (jax.random.normal(k[4], (cfg.kv_lora_rank, H, dv))
                 / math.sqrt(cfg.kv_lora_rank)).astype(dtype),
        "wo": dense_init(k[5], H * dv, d_model, dtype),
    }


def _compress(params, cfg: MLAConfig, x, positions, rope_theta):
    """Shared front: compressed q (split nope/rope) + latent kv + roped k_rope."""
    H_dims = params["w_uq"].shape[1]
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    H = H_dims // (dn + dr)

    c_q = jnp.einsum("...d,dr->...r", x, params["w_dq"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    c_q = norm_apply(params["q_norm"], c_q)
    q = jnp.einsum("...r,rh->...h", c_q, params["w_uq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(q.shape[:-1] + (H, dn + dr))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    ckv_full = jnp.einsum("...d,dr->...r", x, params["w_dkv"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
    c_kv = norm_apply(params["kv_norm"], ckv_full[..., : cfg.kv_lora_rank])
    k_rope = ckv_full[..., cfg.kv_lora_rank :]  # (..., dr) shared across heads
    k_rope = apply_rope(k_rope[..., None, :], positions, rope_theta)[..., 0, :]
    return q_nope, q_rope, c_kv, k_rope


def _blockwise_mla(q_nope, q_rope, k_nope, k_rope, v, scale, block_q):
    """Query-block scan for MLA prefill/train (bounded score memory)."""
    B, T, H, dn = q_nope.shape
    S = k_nope.shape[1]
    bq = min(block_q, T)
    pad = (-T) % bq
    if pad:
        q_nope = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (T + pad) // bq
    qn = q_nope.reshape(B, nb, bq, H, dn).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(B, nb, bq, H, -1).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(S)

    def one_block(carry, xs):
        qn_i, qr_i, ib = xs
        scores = (
            jnp.einsum("bthd,bshd->bhts", qn_i, k_nope,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bthd,bsd->bhts", qr_i, k_rope,
                         preferred_element_type=jnp.float32)
        ) * scale
        qpos = ib * bq + jnp.arange(bq)
        m = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(m[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return carry, out.astype(v.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(one_block, prevent_cse=False), 0,
                           (qn, qr, jnp.arange(nb, dtype=jnp.int32)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, T + pad, H, -1)
    return out[:, :T]


def mla_apply(params, x, cfg: MLAConfig, num_heads: int, *,
              rope_theta: float = 10_000.0, positions=None):
    """Training / prefill path: (B, T, D) -> (B, T, D), causal."""
    B, T, D = x.shape
    if positions is None:
        positions = jnp.arange(T)[None]
    q_nope, q_rope, c_kv, k_rope = _compress(params, cfg, x, positions, rope_theta)
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    H = num_heads

    k_nope = jnp.einsum("btr,rhd->bthd", c_kv, params["w_uk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btr,rhd->bthd", c_kv, params["w_uv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope = maybe_shard(q_nope, "batch", "seq", "model", "none")
    k_nope = maybe_shard(k_nope, "batch", "seq", "model", "none")

    scale = 1.0 / math.sqrt(dn + dr)
    from repro.models.layers import BLOCKWISE_SCORE_THRESHOLD, BLOCK_Q

    if T * T >= BLOCKWISE_SCORE_THRESHOLD:
        out = _blockwise_mla(q_nope, q_rope, k_nope, k_rope, v, scale, BLOCK_Q)
    else:
        scores = (
            jnp.einsum("bthd,bshd->bhts", q_nope, k_nope,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope,
                         preferred_element_type=jnp.float32)
        ) * scale
        causal = (jnp.arange(T)[None, :] <= jnp.arange(T)[:, None])[None, None]
        scores = jnp.where(causal, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(B, T, H * dv)
    return jnp.einsum("...h,hd->...d", out, params["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def init_mla_cache(batch: int, length: int, cfg: MLAConfig, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def mla_decode(params, x, cache, cfg: MLAConfig, num_heads: int, *,
               rope_theta: float = 10_000.0):
    """Absorbed one-token decode. x: (B, 1, D)."""
    B = x.shape[0]
    S = cache["c_kv"].shape[1]
    idx = cache["index"]
    positions = jnp.full((B, 1), idx, dtype=jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _compress(
        params, cfg, x, positions, rope_theta
    )
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    H = num_heads

    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, idx, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, idx, 0))

    # absorb W_uk into the query: q_lat (B, 1, H, R)
    q_lat = jnp.einsum("bthd,rhd->bthr", q_nope, params["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(dn + dr)
    scores = (
        jnp.einsum("bthr,bsr->bhts", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = (jnp.arange(S) <= idx)[None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # attend in latent space, then decompress with W_uv
    o_lat = jnp.einsum("bhts,bsr->bthr", probs, c_kv.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bthr,rhd->bthd", o_lat.astype(x.dtype), params["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(B, 1, H * dv)
    y = jnp.einsum("...h,hd->...d", out, params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"c_kv": c_kv, "k_rope": k_rope, "index": idx + 1}
