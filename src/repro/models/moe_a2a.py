"""Expert-parallel MoE with explicit all-to-all dispatch (§Perf, Pair B).

The portable scatter-based dispatch (moe.py) lets XLA SPMD partition a
global scatter — which replicates the (N·k, D) update stream across the
expert ('model') axis and is catastrophically collective-bound for
256-expert configs (EXPERIMENTS.md §Roofline: deepseek train_4k baseline
collective term ≈ 1750 s/step-equivalent).

This module hand-writes the canonical expert-parallel schedule in a fully
manual ``jax.shard_map`` over every mesh axis:

  1. every device routes its LOCAL tokens (cumsum/scatter/gather never
     cross devices) into a capacity-bounded (E, C_dev, D) slot buffer;
  2. one all-to-all over 'model' swaps expert-major slots — per-device
     traffic = tokens_dev · k · D · capacity_factor per direction,
     independent of E;
  3. local experts (E_loc = E/|model|) run as a batched einsum; expert
     weights arrive D-sharded over 'data' (FSDP) and are all-gathered
     per layer (transpose = reduce-scatter for the grads);
  4. the inverse all-to-all returns slots; each device combines its own
     tokens' top-k contributions.

A custom-vjp identity casts cotangents crossing the a2a boundary to bf16 —
otherwise the backward all-to-alls carry f32 (2× ICI traffic).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import mlp_apply
from repro.models.moe import load_balance_loss, router_topk

MODEL_AXIS = "model"


def _mesh_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if not mesh.axis_names:
            return {}
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return {}


@jax.custom_vjp
def _bf16_grad_boundary(x):
    return x


def _bf16_fwd(x):
    return x, None


def _bf16_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_bf16_grad_boundary.defvjp(_bf16_fwd, _bf16_bwd)


def moe_apply_a2a(params, x, cfg: MoEConfig, act: str = "silu",
                  scoring: str = "softmax") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in replacement for moe.moe_apply when a 'model' mesh axis exists
    (falls back to the scatter implementation otherwise — CPU tests)."""
    sizes = _mesh_axes()
    n_model = sizes.get(MODEL_AXIS, 1)
    token_axes = tuple(a for a in ("pod", "data", MODEL_AXIS) if a in sizes)
    n_tok_shards = 1
    for a in token_axes:
        n_tok_shards *= sizes[a]
    data_axes = tuple(a for a in ("pod", "data") if a in sizes)

    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    E, K = cfg.num_experts, cfg.top_k

    if (n_model <= 1 or E % n_model != 0 or xf.shape[0] % n_tok_shards != 0):
        from repro.models.moe import moe_apply

        return moe_apply(params, x, cfg, act, scoring)

    N_dev = xf.shape[0] // n_tok_shards  # tokens per device
    C = max(int(math.ceil(N_dev * K / E * cfg.capacity_factor)), 1)
    E_loc = E // n_model

    from jax.sharding import PartitionSpec as P

    wg_spec = P(MODEL_AXIS, data_axes if data_axes else None, None)
    wd_spec = P(MODEL_AXIS, None, data_axes if data_axes else None)

    @partial(jax.shard_map,
             in_specs=(P(token_axes, None), P(None, None),
                       wg_spec, wg_spec, wd_spec),
             out_specs=(P(token_axes, None), P(token_axes)),
             axis_names=set(sizes), check_vma=False)
    def local_moe(xt, router_w, w_gate, w_up, w_down):
        # xt: (N_dev, D) — everything below is device-local except the two
        # all-to-alls and the FSDP weight gathers.
        if data_axes:
            w_gate_f = jax.lax.all_gather(w_gate, data_axes, axis=1,
                                          tiled=True)
            w_up_f = jax.lax.all_gather(w_up, data_axes, axis=1, tiled=True)
            w_down_f = jax.lax.all_gather(w_down, data_axes, axis=2,
                                          tiled=True)
        else:
            w_gate_f, w_up_f, w_down_f = w_gate, w_up, w_down

        logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        weights, ids, probs = router_topk(logits, K, scoring)
        aux = load_balance_loss(probs, ids, E)

        flat_ids = ids.reshape(-1)
        onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        flat_pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
        keep = flat_pos < C
        flat_pos_c = jnp.minimum(flat_pos, C - 1)

        upd = jnp.repeat(xt, K, axis=0) * keep[:, None].astype(xt.dtype)
        buf = jnp.zeros((E, C, D), dtype=xt.dtype)
        buf = buf.at[flat_ids, flat_pos_c].add(upd, mode="drop")

        # dispatch a2a over the expert axis
        buf = _bf16_grad_boundary(buf.reshape(n_model, E_loc, C, D))
        recv = jax.lax.all_to_all(buf, MODEL_AXIS, split_axis=0,
                                  concat_axis=0, tiled=False)
        recv = recv.transpose(1, 0, 2, 3).reshape(E_loc, n_model * C, D)

        gate = jnp.einsum("ecd,edf->ecf", recv, w_gate_f,
                          preferred_element_type=jnp.float32)
        up = jnp.einsum("ecd,edf->ecf", recv, w_up_f,
                        preferred_element_type=jnp.float32)
        h = (jax.nn.silu(gate) * up).astype(recv.dtype)
        out = jnp.einsum("ecf,efd->ecd", h, w_down_f,
                         preferred_element_type=jnp.float32).astype(recv.dtype)

        # inverse a2a: slots back to their source devices
        out = out.reshape(E_loc, n_model, C, D).transpose(1, 0, 2, 3)
        out = _bf16_grad_boundary(out)
        back = jax.lax.all_to_all(out, MODEL_AXIS, split_axis=0,
                                  concat_axis=0, tiled=False)
        out_buf = back.reshape(E, C, D)

        gathered = out_buf[flat_ids, flat_pos_c]
        w = (weights.reshape(-1) * keep.astype(jnp.float32)).astype(xt.dtype)
        y = (gathered * w[:, None]).reshape(N_dev, K, D).sum(axis=1)
        return y, aux[None]

    y, aux = local_moe(xf, params["router"].astype(jnp.float32),
                       params["w_gate"], params["w_up"], params["w_down"])
    aux_loss = jnp.mean(aux) * cfg.router_aux_weight

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xf, act=act)

    return y.reshape(orig_shape), aux_loss
