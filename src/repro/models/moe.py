"""Token-choice Mixture-of-Experts with capacity-bounded scatter dispatch.

Design (DESIGN.md §4):
  * router in fp32; top-k softmax (or sigmoid, DeepSeek-v3 style) gating
  * dispatch: each (token, choice) pair is scattered into a per-expert slot
    buffer ``(E, C, D)`` — C is the capacity; overflowing pairs are dropped
    (their combine weight is zeroed), exactly like Switch/GShard capacity.
    This avoids the (T, E, C) one-hot dispatch tensor entirely.
  * expert FFN: batched einsum over the expert dimension (sharded on the
    'model'/'expert' mesh axis); slots sharded on 'data'.
  * combine: gather back + weighted sum over k choices.
  * aux load-balance loss (Switch-style): E * Σ_e f_e · P_e.

The explicit all-to-all expert-parallel variant (shard_map) lives in
``moe_a2a.py`` and is a §Perf lever; this module is the portable baseline
that also runs on CPU for tests and small experiments.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import maybe_shard
from repro.models.config import MoEConfig
from repro.models.layers import dense_init, init_mlp, mlp_apply


def init_moe(key, d_model: int, cfg: MoEConfig, act: str = "silu",
             dtype=jnp.float32):
    k = jax.random.split(key, 6)
    E, F = cfg.num_experts, cfg.d_ff_expert
    std = 1.0 / math.sqrt(d_model)
    params = {
        "router": dense_init(k[0], d_model, E, jnp.float32),
        "w_gate": (jax.random.normal(k[1], (E, d_model, F)) * std).astype(dtype),
        "w_up": (jax.random.normal(k[2], (E, d_model, F)) * std).astype(dtype),
        "w_down": (jax.random.normal(k[3], (E, F, d_model)) / math.sqrt(F)).astype(dtype),
    }
    if cfg.num_shared_experts:
        params["shared"] = init_mlp(
            k[4], d_model, cfg.num_shared_experts * F, act=act, dtype=dtype
        )
    return params


def router_topk(logits, top_k: int, scoring: str = "softmax"):
    """Return (weights (N,k), ids (N,k), probs (N,E)) — weights sum<=1 per token."""
    if scoring == "softmax":
        probs = jax.nn.softmax(logits, axis=-1)
        weights, ids = jax.lax.top_k(probs, top_k)
    elif scoring == "sigmoid":  # DeepSeek-v3: sigmoid scores, renormalized over top-k
        scores = jax.nn.sigmoid(logits)
        weights, ids = jax.lax.top_k(scores, top_k)
        weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-20)
        probs = scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-20)
    else:
        raise ValueError(scoring)
    return weights, ids, probs


def load_balance_loss(probs, ids, num_experts: int) -> jnp.ndarray:
    """Switch-Transformer aux loss: E · Σ_e f_e P_e (top-1 dispatch fraction)."""
    top1 = ids[..., 0]
    f = jnp.mean(jax.nn.one_hot(top1, num_experts, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def moe_apply(
    params,
    x,  # (B, T, D) or (N, D)
    cfg: MoEConfig,
    act: str = "silu",
    scoring: str = "softmax",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output matching x's shape, aux_loss scalar)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    E, K = cfg.num_experts, cfg.top_k
    C = max(int(math.ceil(N * K / E * cfg.capacity_factor)), 1)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    weights, ids, probs = router_topk(logits, K, scoring)
    aux = load_balance_loss(probs, ids, E) * cfg.router_aux_weight

    # slot assignment: position of each (token, choice) within its expert
    flat_ids = ids.reshape(-1)  # (N*K,) token-major
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (N*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # running count per expert
    flat_pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]  # (N*K,)
    keep = flat_pos < C
    flat_pos_c = jnp.minimum(flat_pos, C - 1)

    # dispatch: (E, C, D) slot buffer, dropped pairs contribute zeros
    upd = jnp.repeat(xf, K, axis=0) * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((E, C, D), dtype=xf.dtype)
    buf = buf.at[flat_ids, flat_pos_c].add(upd, mode="drop")
    buf = maybe_shard(buf, "expert", "batch", "none")

    # expert FFN (SwiGLU)
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"],
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(buf.dtype)
    h = maybe_shard(h, "expert", "batch", "none")
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"],
                         preferred_element_type=jnp.float32).astype(buf.dtype)

    # combine: gather back each pair's expert output, weight, sum over k
    gathered = out_buf[flat_ids, flat_pos_c]  # (N*K, D)
    w = (weights.reshape(-1) * keep.astype(jnp.float32)).astype(xf.dtype)
    y = (gathered * w[:, None]).reshape(N, K, D).sum(axis=1)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xf, act=act)

    return y.reshape(orig_shape), aux
