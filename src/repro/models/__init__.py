from repro.models.config import (
    LayerSpec,
    Stage,
    ModelConfig,
    MoEConfig,
    MLAConfig,
    MambaConfig,
    VisionStubConfig,
    AudioStubConfig,
    EncoderConfig,
    uniform_stages,
    patterned_stages,
)
from repro.models.zoo import ModelBundle, build_bundle

__all__ = [
    "LayerSpec",
    "Stage",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "MambaConfig",
    "VisionStubConfig",
    "AudioStubConfig",
    "EncoderConfig",
    "uniform_stages",
    "patterned_stages",
    "ModelBundle",
    "build_bundle",
]
