"""repro.fleet — the elastic fleet runtime.

Three pieces layered over the comm/launch stack:

  * `snapshot` — versioned full-fleet checkpoints (params + opt state,
    scheduler clocks, bus mailboxes + per-client clocks, comm-meter
    books, data-stream positions, pool rngs/windows, in-process
    transport in-flight) with per-client and per-process restore units.
  * `events` — a scripted churn timeline (kill / restart-from-snapshot /
    join / rewire) and the `ChurnDriver` that applies it to a live
    trainer.
  * `membership` — the deterministic passive view of that timeline:
    liveness, configuration epochs, and the dynamic graph the bus and
    trainer consult instead of a frozen adjacency.

Surfaced declaratively through `repro.exp` (`ChurnSpec`,
``TrainSpec.snapshot_every``, ``ExperimentSpec.init_scheme``); see
docs/elastic_fleets.md.
"""
from repro.fleet.events import (
    ChurnDriver,
    ChurnEvent,
    Join,
    Kill,
    Restart,
    Rewire,
    events_from_spec,
)
from repro.fleet.membership import Membership
from repro.fleet.snapshot import (
    SNAPSHOT_VERSION,
    latest_step,
    load_client_params,
    restore_clients,
    restore_fleet,
    save_fleet,
    snapshot_steps,
)

__all__ = [
    "ChurnDriver",
    "ChurnEvent",
    "Join",
    "Kill",
    "Membership",
    "Restart",
    "Rewire",
    "SNAPSHOT_VERSION",
    "events_from_spec",
    "latest_step",
    "load_client_params",
    "restore_clients",
    "restore_fleet",
    "save_fleet",
    "snapshot_steps",
]
