"""Scripted client-churn events and the driver that applies them.

Real decentralized deployments — the setting the paper targets — have
peers that crash, restart from their last checkpoint, join late, and
rewire. This module gives those behaviors a deterministic, scriptable
form: a churn *timeline* is a list of events

  * `Kill(client, step)`          — the client's process dies before wall
    step ``step``: it stops stepping/publishing, and its volatile state
    (mailbox, pending pulls, teacher pool) is lost.
  * `Restart(client, step, from_snapshot)` — the client comes back at
    ``step``: from its latest fleet snapshot (`repro.fleet.snapshot` —
    params, optimizer, pool, mailbox, stream positions all restored), or
    as a fresh process (``from_snapshot=False`` — re-initialized params,
    rewound private stream).
  * `Join(client, step, arch)`    — a late joiner: the client exists in
    the fleet spec but is dead until ``step``. ``arch`` is documentation
    (the fleet's `ClientSpec` list owns the architecture).
  * `Rewire(step, edges)`         — the communication graph becomes
    ``edges`` from ``step`` on (a full adjacency, `core/graph.py`
    convention: ``edges[i]`` = who client i receives from).

`repro.fleet.membership.Membership` turns the same timeline into the
*passive* view (who is alive when, which graph applies); `ChurnDriver`
applies the *active* side to a live trainer — each event exactly once,
at its step, before the step executes. The two are kept consistent by
construction: both consume the same event list.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Kill:
    client: int
    step: int


@dataclasses.dataclass(frozen=True)
class Restart:
    client: int
    step: int
    from_snapshot: bool = True


@dataclasses.dataclass(frozen=True)
class Join:
    client: int
    step: int
    arch: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Rewire:
    step: int
    edges: Tuple[Tuple[int, ...], ...]


ChurnEvent = Union[Kill, Restart, Join, Rewire]

_KINDS = {"kill": Kill, "restart": Restart, "join": Join, "rewire": Rewire}


def events_from_spec(churn: Any) -> List[ChurnEvent]:
    """Build runtime events from a `repro.exp.spec.ChurnSpec`-shaped
    object (duck-typed: ``.events`` of records with ``kind``/``step``/
    ``client``/``from_snapshot``/``arch``/``edges``) — `repro.fleet`
    never imports `repro.exp`."""
    out: List[ChurnEvent] = []
    for ev in churn.events:
        kind = ev.kind
        if kind == "kill":
            out.append(Kill(int(ev.client), int(ev.step)))
        elif kind == "restart":
            out.append(Restart(int(ev.client), int(ev.step),
                               bool(ev.from_snapshot)))
        elif kind == "join":
            out.append(Join(int(ev.client), int(ev.step), ev.arch))
        elif kind == "rewire":
            out.append(Rewire(int(ev.step),
                              tuple(tuple(int(j) for j in nbrs)
                                    for nbrs in ev.edges)))
        else:
            raise ValueError(f"unknown churn event kind {kind!r}; "
                             f"known: {sorted(_KINDS)}")
    return out


def sort_events(events: Sequence[ChurnEvent]) -> List[ChurnEvent]:
    """Stable sort by step — same-step events apply in script order
    (so ``kill(c, t)`` followed by ``restart(c, t)`` is a state swap)."""
    return sorted(events, key=lambda e: e.step)


class ChurnDriver:
    """Applies a churn timeline to a live `DecentralizedTrainer`.

    Call ``before_step(t)`` once per wall step, *before* the step runs:
    every not-yet-applied event with ``event.step <= t`` fires in timeline
    order. Events for clients this process does not drive
    (``trainer.local_ids``) are skipped — in a multi-process fleet each
    rank reacts only to its own clients' churn, while `Membership` gives
    every rank the same graph/liveness view.

    ``start_step`` fast-forwards the timeline after a snapshot restore:
    events strictly before it are considered already applied.
    """

    def __init__(self, trainer: Any, events: Sequence[ChurnEvent],
                 snapshot_dir: Optional[str] = None, start_step: int = 0):
        self.trainer = trainer
        self.events = sort_events(events)
        self.snapshot_dir = snapshot_dir
        self._idx = 0
        while self._idx < len(self.events) and \
                self.events[self._idx].step < start_step:
            self._idx += 1
        self.applied: List[str] = []

    def before_step(self, t: int) -> List[str]:
        """Fire due events; returns human-readable descriptions of what
        was applied (also appended to ``self.applied``)."""
        fired: List[str] = []
        while self._idx < len(self.events) and \
                self.events[self._idx].step <= t:
            ev = self.events[self._idx]
            self._idx += 1
            desc = self._apply(ev, t)
            if desc:
                fired.append(desc)
                self.applied.append(desc)
        return fired

    def _apply(self, ev: ChurnEvent, t: int) -> Optional[str]:
        tr = self.trainer
        if isinstance(ev, Rewire):
            # passive: the Membership graph view flips on its own
            return f"rewire@{ev.step}"
        if ev.client not in tr.local_ids:
            return None
        if isinstance(ev, Kill):
            tr.deactivate_client(ev.client)
            return f"kill(c{ev.client})@{ev.step}"
        if isinstance(ev, Restart):
            if ev.from_snapshot:
                from repro.fleet.snapshot import restore_clients

                if not self.snapshot_dir:
                    raise ValueError(
                        f"restart of client {ev.client} from snapshot "
                        "needs a snapshot_dir")
                steps = restore_clients(self.snapshot_dir, tr,
                                        [ev.client], step=t)
                tr.activate_client(ev.client)
                return (f"restart(c{ev.client})@{ev.step} from "
                        f"snapshot step {steps[ev.client]}")
            tr.reinit_client(ev.client)
            tr.activate_client(ev.client)
            return f"restart(c{ev.client})@{ev.step} fresh"
        if isinstance(ev, Join):
            if tr.clients[ev.client].params is None:
                tr.reinit_client(ev.client)
            tr.activate_client(ev.client)
            return f"join(c{ev.client})@{ev.step}"
        raise TypeError(f"unknown churn event {ev!r}")
