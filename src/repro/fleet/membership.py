"""Membership: the passive view of a churn timeline — who is alive at
which wall step, and which communication graph applies.

A `Membership` is built from the fleet's *base* graph plus the scripted
churn events (`repro.fleet.events`) and is fully deterministic: every
process in a fleet computes the identical view from the spec, with no
coordination. Time is measured in wall steps (the synchronous trainer's
global step, or the async scheduler's wall tick).

Liveness
  A client is alive from step 0 unless it has a `Join` event (then it is
  dead until its join step). `Kill`/`Restart` toggle liveness from their
  step on: a client killed at T does not step at T; one restarted at T
  steps at T.

Epochs
  ``epoch(step)`` counts the events in effect by ``step`` — a monotone
  version number for the fleet's configuration. Any two processes that
  agree on the step agree on the epoch, so it doubles as a cheap
  consistency stamp in logs and metrics.

Graph view
  ``graph_view(step)`` is a `core.graph.GraphFn`-compatible callable:
  the latest `Rewire` edges (or the base graph), with edges *from* dead
  clients removed — a dead client publishes nothing, and keeping it as a
  pull candidate would waste pulls on a silent peer. Edges *toward* dead
  clients are kept: senders still offer mail to them (they cannot know
  the peer died), and the bus tombstones the delivery — the metered
  offered-vs-delivered gap that makes churn costs visible
  (`CommMeter.record_tombstone`). This mirrors the real-socket behavior,
  where sends to a dead peer fail on the sender's side.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Sequence, Tuple, Union

from repro.core.graph import (Adjacency, GraphFn, as_graph_fn,
                              validate_adjacency)
from repro.fleet.events import (ChurnEvent, Join, Kill, Restart, Rewire,
                                sort_events)


class Membership:
    def __init__(self, base_graph: Union[Adjacency, GraphFn],
                 num_clients: int,
                 events: Sequence[ChurnEvent] = ()):
        if not callable(base_graph):
            validate_adjacency(base_graph)
        self.base_fn = as_graph_fn(base_graph)
        self.num_clients = int(num_clients)
        self.events = sort_events(events)
        self._validate_events()

        # per-client liveness timeline: [(step, alive)] in apply order;
        # scanning for the last change with change_step <= t answers
        # is_alive in O(#events-for-client)
        self._status: Dict[int, List[Tuple[int, bool]]] = {
            i: [(0, True)] for i in range(self.num_clients)}
        for ev in self.events:
            if isinstance(ev, Join):
                self._status[ev.client][0] = (0, False)
        for ev in self.events:
            if isinstance(ev, Kill):
                self._status[ev.client].append((ev.step, False))
            elif isinstance(ev, (Restart, Join)):
                self._status[ev.client].append((ev.step, True))

        self._rewires: List[Tuple[int, Adjacency]] = []
        for ev in self.events:
            if isinstance(ev, Rewire):
                adj = [tuple(int(j) for j in nbrs) for nbrs in ev.edges]
                if len(adj) != self.num_clients:
                    raise ValueError(
                        f"rewire@{ev.step} has {len(adj)} rows for a "
                        f"{self.num_clients}-client fleet")
                validate_adjacency(adj)
                self._rewires.append((ev.step, adj))

    def _validate_events(self) -> None:
        """Reject incoherent scripts: out-of-range clients, double joins,
        kill of a dead client, restart/join of an alive one."""
        has_join = {ev.client for ev in self.events
                    if isinstance(ev, Join)}
        if len(has_join) != sum(1 for ev in self.events
                                if isinstance(ev, Join)):
            raise ValueError("a client joins twice in the churn script")
        alive: Dict[int, bool] = {}
        for ev in self.events:
            if isinstance(ev, Rewire):
                continue
            if not (0 <= ev.client < self.num_clients):
                raise ValueError(
                    f"churn event {ev} names client {ev.client} outside "
                    f"a {self.num_clients}-client fleet")
            cur = alive.get(ev.client, ev.client not in has_join)
            if isinstance(ev, Kill) and not cur:
                raise ValueError(f"kill of already-dead client "
                                 f"{ev.client} at step {ev.step}")
            if isinstance(ev, (Restart, Join)) and cur:
                raise ValueError(
                    f"{type(ev).__name__.lower()} of alive client "
                    f"{ev.client} at step {ev.step} (missing kill?)")
            alive[ev.client] = not isinstance(ev, Kill)

    # -- liveness ---------------------------------------------------------

    def is_alive(self, client: int, step: int) -> bool:
        alive = True
        for change_step, state in self._status[int(client)]:
            if change_step <= step:
                alive = state
            else:
                break
        return alive

    def alive(self, step: int) -> FrozenSet[int]:
        return frozenset(i for i in range(self.num_clients)
                         if self.is_alive(i, step))

    def epoch(self, step: int) -> int:
        """Number of churn events in effect by ``step`` — the fleet's
        monotone configuration version."""
        return sum(1 for ev in self.events if ev.step <= step)

    # -- graph view -------------------------------------------------------

    def graph_view(self, step: int) -> Adjacency:
        """The effective adjacency at ``step``: latest rewire (or base),
        minus edges from dead sources; edges toward dead destinations
        stay (their mail becomes metered tombstoned losses)."""
        adj = None
        for rw_step, edges in self._rewires:
            if rw_step <= step:
                adj = edges
        if adj is None:
            adj = self.base_fn(step)
        live = self.alive(step)
        return [tuple(j for j in nbrs if j in live) for nbrs in adj]
