"""Versioned full-fleet snapshots: everything a process needs to resume
a decentralized run bit-for-bit.

A plain parameter checkpoint (`checkpoint/io`) is not enough to resume a
*fleet*: the run's determinism also lives in the shared pull rng, each
client's pool rng and pool contents (decoded prediction windows), the
private-batch iterator positions, the bus mailboxes and per-client
logical clocks, pending pulls, the comm meter's books, the scheduler's
wall/local clocks, and (for in-process transports) the in-flight mail.
`save_fleet` captures all of it; `restore_fleet` rebuilds it into a
freshly constructed trainer so that stepping on is bitwise-identical to
never having stopped (asserted in tests/test_fleet.py, for all four
trainers: MHD sync/async, FedMD, FedAvg, supervised).

Layout — one directory per snapshot step, one file per *unit of
restore*::

    <dir>/step_{step:010d}/
        client_{cid}.npz   # one client's slice: params, opt state, pool
                           # (rng + entries), private stream, mailbox +
                           # clock, pending pulls
        proc_{tag}.npz     # one process's slice: shared pull rng, meter
                           # books, scheduler clocks, transport in-flight

The per-client/per-process split is what makes fleets *elastic*: a
multi-process gossip rank saves only its own clients and its own process
file (``tag="r{rank}"``) with no cross-process coordination, and a
restarted client can be restored alone into a live trainer
(`restore_clients`) while its peers keep running. The process file is
written last, so its presence marks a complete snapshot for that
process.

Files are pickle-free: nested state is JSON with numpy arrays and raw
``bytes`` (mail payloads) lifted into npz members (`_save_state` /
`_load_state`). Every file carries ``SNAPSHOT_VERSION``; restore refuses
a version it does not understand rather than misreading it.

Real-socket fleets quiesce before capture: `save_fleet` calls the
transport's ``quiesce()`` (when it has one) to drain kernel-buffered
frames into the parsed hold-back queues, which ``state_dict()`` then
snapshots alongside the wire counters — so a socket fleet snapshots with
empty in-flight state instead of documented losses. The only thing a
snapshot still cannot capture is a frame a remote peer had not finished
*writing* at the quiesce; its partial bytes are metered in the
transport's ``undrained_bytes`` counter and the staleness machinery
absorbs the gap — the same contract as a dropped message.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

SNAPSHOT_VERSION = 1

_STEP_RE = re.compile(r"^step_(\d+)$")


# -- pickle-free structured state <-> npz ------------------------------------


def _encode(obj: Any, arrays: List[np.ndarray],
            blobs: List[bytes]) -> Any:
    """JSON-ify ``obj``, lifting ndarrays/bytes into side tables."""
    if isinstance(obj, dict):
        return {str(k): _encode(v, arrays, blobs) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v, arrays, blobs) for v in obj]
    if isinstance(obj, (bytes, bytearray)):
        blobs.append(bytes(obj))
        return {"__blob__": len(blobs) - 1}
    if isinstance(obj, np.ndarray):
        arrays.append(obj)
        return {"__array__": len(arrays) - 1}
    if isinstance(obj, jax.Array):
        arrays.append(np.asarray(obj))
        return {"__array__": len(arrays) - 1}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(f"cannot snapshot a {type(obj).__name__}: {obj!r}")


def _decode(obj: Any, arrays: Dict[str, np.ndarray],
            blobs: List[bytes]) -> Any:
    if isinstance(obj, dict):
        if set(obj) == {"__blob__"}:
            return blobs[int(obj["__blob__"])]
        if set(obj) == {"__array__"}:
            return arrays[f"a{int(obj['__array__'])}"]
        return {k: _decode(v, arrays, blobs) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v, arrays, blobs) for v in obj]
    return obj


def _save_state(path: str, state: Any) -> None:
    """Atomic write of one nested state structure to ``path`` (.npz)."""
    arrays: List[np.ndarray] = []
    blobs: List[bytes] = []
    meta = _encode(state, arrays, blobs)
    buf = b"".join(blobs)
    offsets = np.cumsum([0] + [len(b) for b in blobs]).astype(np.int64)
    members = {f"a{i}": a for i, a in enumerate(arrays)}
    members["meta"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    members["blob_buf"] = np.frombuffer(buf, dtype=np.uint8)
    members["blob_offsets"] = offsets
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **members)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def _load_state(path: str) -> Any:
    with np.load(path) as data:
        members = {k: data[k] for k in data.files}
    meta = json.loads(bytes(members["meta"].tobytes()).decode("utf-8"))
    buf = members["blob_buf"].tobytes()
    offsets = members["blob_offsets"]
    blobs = [buf[int(offsets[i]):int(offsets[i + 1])]
             for i in range(len(offsets) - 1)]
    return _decode(meta, members, blobs)


# -- pytree helpers ----------------------------------------------------------


def _flat(tree: Any) -> Dict[str, np.ndarray]:
    from repro.common.pytree import flatten_with_paths

    return {k: np.asarray(v) for k, v in flatten_with_paths(tree).items()}


def _unflatten_like(flat: Dict[str, np.ndarray], target: Any) -> Any:
    """Load a ``{path: array}`` dict back into ``target``'s structure —
    the same contract as `checkpoint.io.load_pytree`, file-free."""
    from repro.common.pytree import _path_str

    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    want = set()
    leaves = []
    for path_keys, leaf in paths:
        key = "/".join(_path_str(p) for p in path_keys)
        want.add(key)
        if key not in flat:
            raise ValueError(f"snapshot is missing leaf {key!r}")
        arr = np.asarray(flat[key])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    extra = set(flat) - want
    if extra:
        raise ValueError(f"snapshot has extra leaves {sorted(extra)[:5]}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


# -- directory layout --------------------------------------------------------


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:010d}")


def snapshot_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str,
                at_or_before: Optional[int] = None) -> Optional[int]:
    steps = [s for s in snapshot_steps(directory)
             if at_or_before is None or s <= at_or_before]
    return steps[-1] if steps else None


def _check_version(state: Dict[str, Any], path: str) -> None:
    v = state.get("version")
    if v != SNAPSHOT_VERSION:
        raise ValueError(
            f"snapshot {path} has version {v!r}; this build reads "
            f"version {SNAPSHOT_VERSION}")


# -- trainer-kind dispatch ---------------------------------------------------
#
# "decentralized" — core.runtime.DecentralizedTrainer (pools, bus, shared
# pull rng, optional AsyncScheduler clocks).
# "list" — the stepwise baselines (FedMD, FedAvg, supervised): parallel
# params/opt/iterator lists, no comm state.


def _trainer_kind(trainer: Any) -> str:
    if hasattr(trainer, "graph_fn") and hasattr(trainer, "local_ids"):
        return "decentralized"
    if hasattr(trainer, "iters"):
        return "list"
    raise TypeError(
        f"don't know how to snapshot a {type(trainer).__name__}")


def _list_slots(trainer: Any) -> Tuple[List[Any], List[Any], List[Any]]:
    params = (trainer.client_params if hasattr(trainer, "client_params")
              else trainer.params)
    return params, trainer.opt_states, trainer.iters


# -- client slices -----------------------------------------------------------


def _decentralized_client_state(trainer: Any, cid: int) -> Dict[str, Any]:
    c = trainer.clients[cid]
    if c.params is None:
        raise ValueError(f"client {cid} has no materialized state to save")
    entries = []
    for e in c.pool.entries:
        rec: Dict[str, Any] = {"client_id": int(e.client_id),
                               "step": int(e.step)}
        if trainer.exchange == "params":
            rec["params"] = _flat(e.params)
        else:
            rec["t0"] = int(e.params.t0)
            rec["outs"] = {k: np.asarray(v)
                           for k, v in e.params.outs.items()}
        entries.append(rec)
    state: Dict[str, Any] = {
        "version": SNAPSHOT_VERSION,
        "client_id": int(cid),
        "exchange": trainer.exchange,
        "params": _flat(c.params),
        "opt": _flat(c.opt_state),
        "iter": c.private_iter.state_dict(),
        "pool": {"rng": c.pool.rng.bit_generator.state,
                 "entries": entries},
    }
    if trainer.exchange != "params":
        state["mail"] = trainer.bus.client_state(cid)
        state["pending"] = {str(src): int(rnd) for src, rnd
                            in trainer._pending[cid].items()}
    return state


def _restore_decentralized_client(trainer: Any, cid: int,
                                  state: Dict[str, Any]) -> None:
    from repro.checkpoint.pool import PoolEntry
    from repro.comm.bus import PredictionWindow

    if state["exchange"] != trainer.exchange:
        raise ValueError(
            f"snapshot of client {cid} used exchange "
            f"{state['exchange']!r}; trainer runs {trainer.exchange!r}")
    c = trainer.clients[cid]
    if c.params is None:
        raise ValueError(
            f"client {cid} was not materialized in this process "
            "(init_scheme='per_client' non-local client?)")
    c.params = _unflatten_like(state["params"], c.params)
    c.opt_state = _unflatten_like(state["opt"], c.opt_state)
    c.private_iter.load_state_dict(state["iter"])
    c.pool.rng.bit_generator.state = state["pool"]["rng"]
    c.pool.entries = []
    for rec in state["pool"]["entries"]:
        if trainer.exchange == "params":
            target = trainer.clients[int(rec["client_id"])].params
            payload = _unflatten_like(rec["params"], target)
        else:
            payload = PredictionWindow(
                int(rec["t0"]),
                {k: np.asarray(v) for k, v in rec["outs"].items()})
        c.pool.entries.append(
            PoolEntry(int(rec["client_id"]), payload, int(rec["step"])))
    if trainer.exchange != "params":
        trainer.bus.load_client_state(cid, state["mail"])
        trainer._pending[cid] = {int(src): int(rnd) for src, rnd
                                 in state["pending"].items()}


def _list_client_state(trainer: Any, idx: int) -> Dict[str, Any]:
    params, opts, iters = _list_slots(trainer)
    return {
        "version": SNAPSHOT_VERSION,
        "client_id": int(idx),
        "exchange": "none",
        "params": _flat(params[idx]),
        "opt": _flat(opts[idx]),
        "iter": iters[idx].state_dict(),
    }


def _restore_list_client(trainer: Any, idx: int,
                         state: Dict[str, Any]) -> None:
    params, opts, iters = _list_slots(trainer)
    params[idx] = _unflatten_like(state["params"], params[idx])
    opts[idx] = _unflatten_like(state["opt"], opts[idx])
    iters[idx].load_state_dict(state["iter"])


# -- public API --------------------------------------------------------------


def default_tag(trainer: Any) -> str:
    """The process tag: "all" for a whole-fleet trainer, "r3" / "r1_2"
    for a process driving a subset."""
    if _trainer_kind(trainer) != "decentralized":
        return "all"
    if trainer.local_ids == list(range(len(trainer.clients))):
        return "all"
    return "r" + "_".join(str(i) for i in trainer.local_ids)


def save_fleet(directory: str, step: int, trainer: Any,
               scheduler: Optional[Any] = None,
               process_tag: Optional[str] = None) -> str:
    """Snapshot everything this process owns at ``step``: one
    ``client_{cid}.npz`` per *active local* client, then the process
    file. Returns the snapshot's step directory."""
    kind = _trainer_kind(trainer)
    tag = default_tag(trainer) if process_tag is None else process_tag
    d = _step_dir(directory, step)
    os.makedirs(d, exist_ok=True)

    proc: Dict[str, Any] = {"version": SNAPSHOT_VERSION, "step": int(step),
                            "kind": kind, "tag": tag}
    if kind == "decentralized":
        saved = [c.client_id for c in trainer.local]
        for cid in saved:
            _save_state(os.path.join(d, f"client_{cid}.npz"),
                        _decentralized_client_state(trainer, cid))
        proc.update({
            "clients": saved,
            "exchange": trainer.exchange,
            "rng": trainer.rng.bit_generator.state,
            "scheduler": (None if scheduler is None
                          else scheduler.state_dict()),
            "meter": (None if trainer.meter is None
                      else trainer.meter.state_dict()),
        })
        transport_state = None
        if trainer.exchange != "params":
            transport = trainer.bus.transport
            if hasattr(transport, "quiesce"):
                # socket transports: pull kernel-buffered frames into the
                # parsed hold-back queues so the state_dict below captures
                # them instead of losing them with the process; whatever
                # still can't be drained (a peer's half-written frame) is
                # metered in the transport's undrained_bytes counter
                transport.quiesce()
            transport_state = transport.state_dict()
        proc["transport"] = transport_state
    else:
        params, _, _ = _list_slots(trainer)
        saved = list(range(len(params)))
        for i in saved:
            _save_state(os.path.join(d, f"client_{i}.npz"),
                        _list_client_state(trainer, i))
        proc["clients"] = saved
    # the process file last: its presence marks a complete snapshot
    _save_state(os.path.join(d, f"proc_{tag}.npz"), proc)
    return d


def restore_fleet(directory: str, trainer: Any,
                  scheduler: Optional[Any] = None,
                  step: Optional[int] = None,
                  process_tag: Optional[str] = None) -> int:
    """Restore a freshly constructed trainer (and optional scheduler) to
    a snapshot: process state plus every client the snapshot's process
    saved. Returns the restored step."""
    kind = _trainer_kind(trainer)
    tag = default_tag(trainer) if process_tag is None else process_tag
    if step is None:
        step = _latest_with(directory, f"proc_{tag}.npz")
        if step is None:
            raise FileNotFoundError(
                f"no snapshot with proc_{tag}.npz under {directory}")
    path = os.path.join(_step_dir(directory, step), f"proc_{tag}.npz")
    proc = _load_state(path)
    _check_version(proc, path)
    if proc["kind"] != kind:
        raise ValueError(f"snapshot {path} is of a {proc['kind']} "
                         f"trainer; got a {kind} trainer")

    saved = [int(c) for c in proc["clients"]]
    if kind == "decentralized":
        trainer.rng.bit_generator.state = proc["rng"]
        if proc["scheduler"] is not None:
            if scheduler is None:
                raise ValueError(
                    "snapshot carries async scheduler clocks; pass the "
                    "scheduler to restore them")
            scheduler.load_state_dict(proc["scheduler"])
        if proc["meter"] is not None and trainer.meter is not None:
            trainer.meter.load_state_dict(proc["meter"])
        if proc["transport"] is not None and trainer.exchange != "params":
            trainer.bus.transport.load_state_dict(proc["transport"])
        for cid in saved:
            cpath = os.path.join(_step_dir(directory, step),
                                 f"client_{cid}.npz")
            state = _load_state(cpath)
            _check_version(state, cpath)
            _restore_decentralized_client(trainer, cid, state)
        # liveness at snapshot time: saved clients were alive; local
        # clients missing from the snapshot were dead
        for cid in trainer.local_ids:
            if cid in saved:
                trainer._dead.discard(cid)
            else:
                trainer._dead.add(cid)
        trainer.local = [trainer.clients[i] for i in trainer.local_ids
                         if i not in trainer._dead]
    else:
        for i in saved:
            cpath = os.path.join(_step_dir(directory, step),
                                 f"client_{i}.npz")
            state = _load_state(cpath)
            _check_version(state, cpath)
            _restore_list_client(trainer, i, state)
    return int(proc["step"])


def restore_clients(directory: str, trainer: Any, clients: Sequence[int],
                    step: Optional[int] = None) -> Dict[int, int]:
    """Restore individual clients' slices into a *live* trainer — the
    restart path of client churn. Each client is restored from the
    newest snapshot at or before ``step`` that contains its file (a
    client dead at snapshot time has no file there). Process-shared
    state (pull rng, meter, transport) is untouched: it belongs to the
    survivors. Returns ``{client_id: restored_step}``."""
    out: Dict[int, int] = {}
    for cid in clients:
        cid = int(cid)
        found = None
        for s in reversed(snapshot_steps(directory)):
            if step is not None and s > step:
                continue
            path = os.path.join(_step_dir(directory, s),
                                f"client_{cid}.npz")
            if os.path.exists(path):
                found = (s, path)
                break
        if found is None:
            raise FileNotFoundError(
                f"no snapshot of client {cid} at or before step {step} "
                f"under {directory}")
        s, path = found
        state = _load_state(path)
        _check_version(state, path)
        if _trainer_kind(trainer) == "decentralized":
            _restore_decentralized_client(trainer, cid, state)
        else:
            _restore_list_client(trainer, cid, state)
        out[cid] = s
    return out


def _latest_with(directory: str, filename: str) -> Optional[int]:
    for s in reversed(snapshot_steps(directory)):
        if os.path.exists(os.path.join(_step_dir(directory, s), filename)):
            return s
    return None


def load_client_params(directory: str, cid: int, like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Read one client's parameters out of a fleet snapshot without a
    trainer — the serving path (`repro.serve.Router`): a finished gossip
    run's snapshot directory is directly servable. ``like`` supplies the
    target pytree structure (a freshly initialized bundle's params).
    Returns ``(params, snapshot_step)``; ``step=None`` picks the newest
    snapshot containing ``client_{cid}.npz``."""
    if step is None:
        step = _latest_with(directory, f"client_{cid}.npz")
        if step is None:
            raise FileNotFoundError(
                f"no snapshot of client {cid} under {directory}")
    path = os.path.join(_step_dir(directory, step), f"client_{cid}.npz")
    state = _load_state(path)
    _check_version(state, path)
    return _unflatten_like(state["params"], like), int(step)
