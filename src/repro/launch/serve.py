"""Serving launcher: batched greedy decoding with KV/state caches.

Runs a reduced architecture end-to-end on CPU (prefill + N decode steps for
a batch of requests); on TPU the same step functions are lowered with the
production shardings (see dryrun.py decode shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def prefill_into_cache(bundle, cfg, params, tokens, cache_len):
    """Run the prompt through decode_step token-by-token (cache warmup).

    A production server uses a fused prefill kernel; token-stepping keeps the
    CPU example simple and exercises exactly the serve_step the dry-run
    lowers. Returns (caches, last_logits).
    """
    B, T = tokens.shape
    caches = bundle.init_cache(B, cache_len, jnp.float32)
    step = jax.jit(bundle.decode_step)
    logits = None
    for t in range(T):
        logits, caches = step(params, tokens[:, t:t + 1], caches)
    return caches, logits


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="gemma3-12b")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro.configs import get_reduced
    from repro.models.zoo import build_bundle

    cfg = get_reduced(args.arch)
    if cfg.family == "audio":
        raise SystemExit("use the whisper example for enc-dec serving")
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len), dtype=np.int32))

    cache_len = args.prompt_len + args.gen
    t0 = time.time()
    caches, logits = prefill_into_cache(bundle, cfg, params, prompts, cache_len)
    prefill_s = time.time() - t0

    step = jax.jit(bundle.decode_step)
    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    decode_s = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill {prefill_s:.2f}s, decode {decode_s:.2f}s "
          f"({args.gen*args.batch/max(decode_s,1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  req{b}: {gen[b][:12].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
