"""Serving launcher — thin CLI over `repro.serve`.

Two modes:

  * decode demo (default): a continuous-batching greedy-decode run over
    one reduced zoo LM — mixed-length requests admitted/retired without
    draining the batch, with the fused prefill.

        PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \\
            --batch 4 --prompt-len 32 --gen 16

    ``--no-reduced`` lowers the full production config instead of the
    CPU-reduced shape (slow off-TPU; the flag exists so it *can* be
    disabled — it used to be a no-op ``store_true`` with default=True).

  * fleet scenario (``--preset``): the full train→snapshot→serve→
    feed-back loop of `repro.serve.run_serve_scenario`:

        PYTHONPATH=src python -m repro.launch.serve --preset serve_loop
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np


def _demo(args) -> int:
    import jax

    from repro.configs import get_config, get_reduced
    from repro.models.zoo import build_bundle
    from repro.serve import ContinuousBatchingEngine, ServeRequest

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("use the whisper example for enc-dec serving")
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    engine = ContinuousBatchingEngine(
        bundle, params, num_slots=args.batch,
        cache_len=args.prompt_len + args.gen, admission=args.admission)
    for rid in range(args.batch * 2):
        # mixed lengths: request i generates between gen/2 and gen tokens
        gen = args.gen - (rid % max(args.gen // 2, 1))
        engine.submit(ServeRequest(
            request_id=rid, kind="generate",
            prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=max(gen, 1)))
    t0 = time.time()
    responses = engine.run()
    wall = time.time() - t0

    total_tokens = sum(len(r.tokens) for r in responses)
    print(f"arch={cfg.name} slots={args.batch} prompt={args.prompt_len} "
          f"admission={engine.admission}")
    print(f"{len(responses)} requests, {total_tokens} tokens in "
          f"{wall:.2f}s ({total_tokens / max(wall, 1e-9):.1f} tok/s, "
          f"occupancy {engine.occupancy():.0%})")
    print("sample generations (token ids):")
    for r in sorted(responses, key=lambda r: r.request_id)[:2]:
        print(f"  req{r.request_id}: {r.tokens[:12]} "
              f"(admit tick {r.admit_tick}, finish {r.finish_tick})")
    return 0


def _scenario(args) -> int:
    from repro.exp import get_preset
    from repro.serve import run_serve_scenario

    spec = get_preset(args.preset)
    if spec.serve.requests <= 0:
        raise SystemExit(f"preset {args.preset!r} has no serve block "
                         "(serve.requests == 0)")
    workdir = args.workdir or tempfile.mkdtemp(prefix="serve_")
    out = run_serve_scenario(spec, workdir)
    print(f"preset={args.preset} workdir={workdir}")
    for k in sorted(out.metrics):
        print(f"  {k} = {out.metrics[k]:.4g}")
    print(out.front.cache.ledger.format_table())
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="gemma3-12b")
    p.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="CPU-reduced config (--no-reduced = full shape)")
    p.add_argument("--batch", type=int, default=4,
                   help="engine slots (concurrent decode lanes)")
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--admission", default="continuous",
                   choices=("continuous", "static"))
    p.add_argument("--preset", default=None,
                   help="run the fleet serve scenario of this preset "
                        "instead of the decode demo")
    p.add_argument("--workdir", default=None,
                   help="scenario snapshot/artifact dir (default: tmp)")
    args = p.parse_args(argv)

    return _scenario(args) if args.preset else _demo(args)


if __name__ == "__main__":
    raise SystemExit(main())
