"""Multi-process gossip launcher: one OS process per client over TCP.

The paper's agents are independent learners exchanging predictions over
a network; this launcher makes that literal on one host. Given an
`ExperimentSpec` with ``transport.kind == "socket"`` and a decentralized
algorithm, ``launch_gossip(spec)`` spawns one OS process per client.
Each child:

  1. builds a `SocketTransport` hosting only its own client (binding an
     OS-assigned port) and reports the port to the launcher, which
     gathers the full port map and broadcasts it back — a race-free
     rendezvous, no pre-allocated ports needed;
  2. opens its outgoing per-edge connections from the communication
     graph (with retries, so processes may start in any order);
  3. constructs the trainer restricted to its client
     (``Bindings.local_clients``) — model init consumes the same rng
     stream in every process, so client i's params are identical no
     matter which process materializes them — and drives an
     AsyncScheduler-style local loop: its local step count is its own
     clock, public batches are sampled from the shared deterministic
     `PublicPool` indices, publishes happen every S_P *local* steps, and
     the socket is drained every step. Heterogeneous step rates are real
     wall-clock speed differences between processes (``throttle_ms``
     makes a deliberate straggler), not simulation ticks.

With ``schedule.mode == "scoreboard"`` each child additionally gates
every local step through a `core.scheduler.GossipPacer` — the
per-process reduction of the scoreboard runtime: ``schedule.pace_ms``
replaces the post-step throttle sleep (a paced client sleeps *before*
issuing, so transport drains overlap the wait), and ``schedule.runahead``
is the backpressure credit — a child more than that many local steps
ahead of its slowest in-neighbor's freshest mail waits, pumping the
socket, instead of racing ahead against ever-staler teachers. Fast ranks
never block on a straggler's *tick* (there is no global tick), only on
its published progress. See ``docs/async_runtime.md``.

Every child reports its metrics (loss, distillation activity, offered /
delivered meter books) through a pipe; the launcher aggregates them.
A *finish* barrier keeps every child draining its socket through the bus
(metered) until all peers have sent their last frame — so a fast
client's exit never truncates a slow one's run, and on a lossless
localhost wire the fleet's delivered book equals its offered book — and
an *exit* barrier holds sockets open until every result is collected.
A hard ``timeout`` tears the fleet down rather than hanging.

Elastic fleets (`repro.fleet`): when the spec sets
``train.snapshot_dir``/``snapshot_every``, each child saves *its own*
fleet snapshot slice every N local steps (params, optimizer, pool,
mailbox, stream positions — ``proc_r{rank}`` files, no cross-process
coordination), and ``launch_gossip(..., resume=True)`` restarts every
rank from its latest snapshot — the kill-and-restore path CI smokes
(`scripts/run_gossip_procs.py --churn-smoke`). ``die_at={rank: step}``
injects a hard crash (``os._exit``, no cleanup) for testing that path.

Failure detection: the launcher watches the whole fleet while waiting on
any one child. A child that dies without reporting — before port
rendezvous or mid-run — reaps the fleet *immediately* with the failed
rank and exit signal in the error, instead of stalling every peer until
the hard timeout.
"""
from __future__ import annotations

import contextlib
import dataclasses
import multiprocessing as mp
import os
import tempfile
import time
import traceback
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

_DRAIN_ALL = 1 << 60  # poll step high enough to release every held frame


def _child_run(spec_json: str, rank: int, conn, throttle_ms: float,
               die_at: Optional[int] = None, resume: bool = False,
               hard_timeout: float = 300.0) -> None:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax

    from repro.comm import SocketTransport
    from repro.exp import ExperimentSpec, make_algorithm
    from repro.exp.algorithm import Bindings
    from repro.exp.runner import (build_bundles, build_graph,
                                  build_optimizer, materialize_data)
    from repro.obs import trace

    # every rank compiles the same computations; one persistent cache
    # (seeded by the launcher, or pre-warmed by an in-process run — see
    # `launch_gossip`) turns K compilations into one compile + K-1 loads
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if cache_dir:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)

    t_start = time.perf_counter()
    spec = ExperimentSpec.from_json(spec_json).validate()
    sched = spec.schedule
    if sched.mode == "scoreboard":
        # the child's trainer hosts a single client, so the fleet-wide
        # scoreboard reduces to a per-process GossipPacer (built below);
        # neutralize the schedule block so the adapter does not wrap the
        # trainer in an in-process scheduler on top of it
        from repro.exp.spec import ScheduleSpec

        spec = dataclasses.replace(spec, schedule=ScheduleSpec())
    trace_dir = spec.train.trace_dir
    tracer = None
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        tracer = trace.enable(rank=rank, process_name=f"rank {rank}")
    t_spec = spec.transport
    ports = ({rank: t_spec.base_port + rank}
             if t_spec.base_port is not None else None)
    transport = SocketTransport(spec.num_clients, clients=[rank],
                                ports=ports, host=t_spec.host,
                                send_hard_timeout=hard_timeout,
                                wait_inflight=False)
    # rendezvous anchors: the timestamps of this two-way handshake are
    # what the parent's trace merge uses to map this process's
    # perf_counter clock onto its own (repro.obs.export)
    rv0 = time.perf_counter()
    trace.set_anchor("rendezvous_send")
    conn.send(("port", rank, transport.ports[rank]))
    ports = conn.recv()
    trace.set_anchor("rendezvous_recv")
    rendezvous_s = time.perf_counter() - rv0
    trace.complete("gossip/rendezvous", rv0, rank=rank)
    transport.set_ports(ports)
    graph = build_graph(spec)
    transport.connect_edges(graph)

    arrays, test_arrays, part = materialize_data(
        spec.data, spec.partition, spec.num_clients)
    algo = make_algorithm(spec)
    bindings = Bindings(
        spec=spec, arrays=arrays, test_arrays=test_arrays, partition=part,
        bundles=build_bundles(spec), optimizer=build_optimizer(spec),
        graph=graph, transport=transport, num_labels=spec.data.num_labels,
        local_clients=(rank,))
    algo.setup(bindings)
    trainer = algo.trainer

    pacer = None
    if sched.mode == "scoreboard":
        from repro.core import GossipPacer

        pace_ms = sched.pace_ms[rank] if sched.pace_ms else 0.0
        pacer = GossipPacer(trainer, rank, runahead=sched.runahead,
                            pace_s=pace_ms / 1000.0)

    snap_dir = spec.train.snapshot_dir
    snap_every = spec.train.snapshot_every
    start_step = 0
    if resume and snap_dir:
        from repro.fleet.snapshot import restore_fleet

        try:
            # this rank's own slice: proc_r{rank} + client_{rank} files
            start_step = restore_fleet(snap_dir, trainer, scheduler=pacer)
        except FileNotFoundError:
            start_step = 0  # never snapshotted: a fresh start

    distill_steps = 0
    last: Dict[str, float] = {}
    # close the setup span *before* stamping the training start so the
    # two spans nest instead of overlapping by the emit call's own cost
    trace.complete("gossip/setup", t_start, rank=rank)
    t0 = time.perf_counter()
    setup_s = t0 - t_start  # spec parse + transport + data + model build
    for t in range(start_step, spec.train.steps):
        if die_at is not None and t == die_at:
            os._exit(17)  # injected crash: no cleanup, no report
        if pacer is not None:
            pacer.gate(t)
        last = trainer.step(t)
        distill_steps += int(last.get(f"c{rank}/distill_active", 0.0))
        if snap_dir and snap_every and (t + 1) % snap_every == 0:
            from repro.fleet.snapshot import save_fleet

            save_fleet(snap_dir, t + 1, trainer, scheduler=pacer)
        if throttle_ms:
            time.sleep(throttle_ms / 1000.0)
    wall = time.perf_counter() - t0
    trace.complete("gossip/train", t0, rank=rank,
                   steps=spec.train.steps - start_step)
    ev = trainer.evaluate(test_arrays)

    # finish barrier: keep draining *through the bus* (so late arrivals
    # from slower peers are metered as delivered and never back up against
    # a full kernel buffer) until every client has finished sending. The
    # barrier is *count-based*: each rank reports how many frames it
    # successfully wrote per destination, the launcher aggregates them,
    # and every rank then drains until its transport has parsed exactly
    # that many inbound frames — a deterministic quiesce, not a timed
    # grace window. Frames held back by poll's no-delivery-before-tick
    # rule are released by the _DRAIN_ALL delivery, so on a lossless
    # localhost wire the fleet's delivered book equals its offered book
    # (asserted per edge by `launch_gossip`).
    bw0 = time.perf_counter()
    conn.send(("finished", rank,
               {"sent_to": {int(d): int(n)
                            for d, n in transport.sent_to.items()}}))
    while not conn.poll(0.05):
        trainer.bus.deliver(_DRAIN_ALL)
    expected_inbound = int(conn.recv()[1])  # ("all_finished", n_frames)
    if not resume:
        drain_deadline = time.monotonic() + transport.drain_timeout
        while transport.recv_count < expected_inbound:
            if time.monotonic() >= drain_deadline:
                break  # the launcher's per-edge check will name the gap
            trainer.bus.deliver(_DRAIN_ALL)
            time.sleep(0.002)
    # resumed fleets can't reconcile counts (per-rank snapshot counters
    # are uncoordinated cuts), so they rely on the settle-based quiesce
    # alone; fresh fleets use it to meter partial-frame leftovers
    transport.quiesce(settle=0.05, timeout=2.0)
    trainer.bus.deliver(_DRAIN_ALL)  # flush the last parsed frames
    barrier_wait_s = time.perf_counter() - bw0
    trace.complete("gossip/finish_barrier", bw0, rank=rank,
                   expected_inbound=expected_inbound,
                   received=transport.recv_count)

    trace_file = None
    if tracer is not None:
        from repro.obs import write_trace

        trace_file = os.path.join(trace_dir, f"trace_r{rank}.json")
        write_trace(trace_file, tracer,
                    meta={"steps": spec.train.steps,
                          "start_step": start_step,
                          "spec_name": spec.name})

    meter = trainer.meter
    conn.send(("result", rank, {
        "rank": rank,
        "steps": spec.train.steps,
        "start_step": start_step,
        "wall_seconds": wall,
        "setup_s": setup_s,
        "rendezvous_s": rendezvous_s,
        "barrier_wait_s": barrier_wait_s,
        "distill_steps": distill_steps,
        "final_loss": float(last.get(f"c{rank}/loss", float("nan"))),
        "eval": {k: float(v) for k, v in ev.items()},
        "offered_bytes": float(meter.total_bytes),
        "delivered_bytes": float(meter.delivered_bytes),
        "offered_messages": float(meter.num_messages),
        "delivered_messages": float(meter.delivered_messages),
        # this rank's per-edge books: edges it *sent on* (offered, booked
        # at publish) and edges it *received on* (delivered, booked at
        # deliver) — the launcher joins them into the fleet-wide
        # delivered == offered assertion
        "offered_by_edge": {f"{s}-{d}": int(b)
                            for (s, d), b in meter.by_edge.items()},
        "delivered_by_edge": {
            f"{s}-{d}": int(b)
            for (s, d), b in meter.by_edge_delivered.items()},
        "tombstoned_bytes": float(meter.tombstoned_bytes),
        "fresh_teachers": float(sum(meter.gate_fresh.values())),
        "stale_teachers": float(sum(meter.gate_stale.values())),
        "failed_sends": transport.failed_sends,
        "drain_stalls": transport.drain_stalls,
        "undrained_bytes": transport.undrained_bytes,
        "sched": (None if pacer is None
                  else {k: float(v) for k, v in pacer.stats.items()}),
        "trace_file": trace_file,
    }))
    conn.recv()  # "done": every result is in; sockets may now close
    transport.close()


def _child_main(spec_json: str, rank: int, conn,
                throttle_ms: float = 0.0, die_at: Optional[int] = None,
                resume: bool = False,
                hard_timeout: float = 300.0) -> None:
    try:
        _child_run(spec_json, rank, conn, throttle_ms, die_at, resume,
                   hard_timeout)
    except Exception:
        with contextlib.suppress(Exception):
            conn.send(("error", rank, traceback.format_exc()))
        raise


def _exit_desc(exitcode: Optional[int]) -> str:
    if exitcode is not None and exitcode < 0:
        return f"killed by signal {-exitcode}"
    return f"exit code {exitcode}"


class _FleetComms:
    """Receive messages from one child while watching the *whole* fleet:
    a child that dies without reporting fails the run immediately (rank +
    exit signal in the error), instead of stalling every live peer —
    which blocks on the dead one — until the hard timeout."""

    def __init__(self, conns: List[Any], procs: List[Any]):
        self.conns = conns
        self.procs = procs
        self._stash: Dict[int, List[Any]] = defaultdict(list)

    def recv(self, rank: int, timeout: float, phase: str) -> Any:
        deadline = time.monotonic() + max(timeout, 0.0)
        while True:
            if self._stash[rank]:
                return self._stash[rank].pop(0)
            if self.conns[rank].poll(0.1):
                try:
                    return self.conns[rank].recv()
                except EOFError:
                    raise RuntimeError(
                        f"gossip client {rank} died "
                        f"({_exit_desc(self.procs[rank].exitcode)}) "
                        f"during {phase} before reporting") from None
            self._watch(rank, phase)
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"gossip client {rank} sent nothing within "
                    f"{timeout:.0f}s during {phase} "
                    f"(alive={self.procs[rank].is_alive()})")

    def _watch(self, waiting_on: int, phase: str) -> None:
        """Sweep for silently dead children. A dead child's last words
        (an 'error' report, a stashed 'finished') are drained from its
        pipe first — a traceback beats a bare exit code."""
        for r, p in enumerate(self.procs):
            if r == waiting_on or p.is_alive():
                continue
            while True:
                try:
                    if not self.conns[r].poll(0):
                        break
                    msg = self.conns[r].recv()
                except (EOFError, OSError):
                    break
                if msg[0] == "error":
                    raise RuntimeError(
                        f"gossip client {msg[1]} failed during "
                        f"{phase}:\n{msg[2]}")
                self._stash[r].append(msg)
            if not self._stash[r]:
                raise RuntimeError(
                    f"gossip client {r} died "
                    f"({_exit_desc(p.exitcode)}) during {phase} without "
                    "reporting; reaping the fleet")


def launch_gossip(spec, timeout: float = 300.0,
                  start_timeout: float = 120.0,
                  throttle_ms: Optional[Dict[int, float]] = None,
                  die_at: Optional[Dict[int, int]] = None,
                  resume: bool = False,
                  check_delivery: bool = True,
                  ) -> Dict[int, Dict[str, Any]]:
    """Run ``spec`` as one OS process per client; returns per-rank results.

    ``throttle_ms`` sleeps that many milliseconds after each local step of
    the given ranks — a real (wall-clock) straggler. ``timeout`` bounds
    the whole run: on expiry every child is terminated and TimeoutError
    raised, so a hung socket can never wedge the caller (or CI).

    ``die_at={rank: step}`` makes those ranks crash hard (``os._exit``)
    at their given local step — the failure-injection hook behind the
    kill-and-restore smoke. ``resume=True`` restarts every rank from its
    latest fleet snapshot under ``spec.train.snapshot_dir`` (ranks with
    no snapshot start fresh).

    ``check_delivery`` (default on) asserts the lossless-localhost
    invariant after the finish barrier: every edge's delivered bytes
    equal its offered bytes, joined across the per-rank meter books.
    The check skips runs where delivered < offered is *expected* —
    resumed fleets (per-rank snapshots are uncoordinated cuts) and runs
    with failed sends or tombstoned mail (a peer actually went away)."""
    spec = spec.validate()
    if spec.transport.kind != "socket":
        raise ValueError(
            f"launch_gossip needs transport kind 'socket', got "
            f"{spec.transport.kind!r}")
    if spec.schedule.mode not in ("sync", "scoreboard"):
        raise ValueError(
            "launch_gossip drives each client's own local loop at real "
            "wall-clock speed — the simulated-tick modes (async/lockstep) "
            "would be silently ignored by a multi-process run; use mode "
            "'sync' (optionally "
            "throttle_ms for deliberate stragglers) or 'scoreboard' "
            "(pace_ms + runahead drive a per-process GossipPacer)")
    if spec.schedule.mode == "scoreboard" and \
            spec.schedule.rates is not None:
        raise ValueError(
            "schedule.rates are simulation wall ticks; a multi-process "
            "scoreboard run paces with real milliseconds — use "
            "schedule.pace_ms")
    throttle = {int(k): float(v) for k, v in (throttle_ms or {}).items()}
    crash = {int(k): int(v) for k, v in (die_at or {}).items()}
    K = spec.num_clients
    ctx = mp.get_context("spawn")
    spec_json = spec.to_json()
    # one persistent compilation cache for the whole fleet (children
    # inherit the env through spawn): rank 0 compiles, everyone else
    # loads — and later launches (or an in-process warm run, see
    # benchmarks/socket_gossip.py) skip compilation entirely
    os.environ.setdefault(
        "JAX_COMPILATION_CACHE_DIR",
        os.path.join(tempfile.gettempdir(), "repro_jit_cache"))
    conns, procs = [], []
    try:
        for rank in range(K):
            parent_conn, child_conn = ctx.Pipe()
            p = ctx.Process(target=_child_main,
                            args=(spec_json, rank, child_conn,
                                  throttle.get(rank, 0.0),
                                  crash.get(rank), resume, timeout),
                            daemon=True)
            p.start()
            child_conn.close()
            conns.append(parent_conn)
            procs.append(p)
        comms = _FleetComms(conns, procs)

        # phase 1: gather every child's listening port, broadcast the map.
        # The (p_recv, p_send) timestamps around each child's handshake are
        # the parent-side anchors of the trace merge's clock alignment
        # (repro.obs.export.rendezvous_offset).
        ports: Dict[int, int] = {}
        p_recv: Dict[int, float] = {}
        p_send: Dict[int, float] = {}
        start_deadline = time.monotonic() + start_timeout
        for rank in range(K):
            msg = comms.recv(rank, start_deadline - time.monotonic(),
                             "setup")
            if msg[0] == "error":
                raise RuntimeError(
                    f"gossip client {msg[1]} failed during setup:\n{msg[2]}")
            ports[msg[1]] = msg[2]
            p_recv[msg[1]] = time.perf_counter()
        for rank, conn in enumerate(conns):
            # a child may die between reporting and the broadcast; the
            # next recv sweep surfaces it with its exit status
            with contextlib.suppress(OSError):
                conn.send(ports)
                p_send[rank] = time.perf_counter()

        # phase 2: finish barrier — every child reports that it has sent
        # its last frame along with its per-destination frame counts; the
        # counts are aggregated into each rank's expected inbound total
        # and broadcast back, so every rank drains until it has *all* of
        # its mail (count-based quiesce) instead of hoping a grace window
        # was long enough
        deadline = time.monotonic() + timeout
        expected_inbound: Dict[int, int] = defaultdict(int)
        for rank in range(K):
            msg = comms.recv(rank, deadline - time.monotonic(), "training")
            if msg[0] == "error":
                raise RuntimeError(
                    f"gossip client {msg[1]} failed:\n{msg[2]}")
            assert msg[0] == "finished", msg
            for dst, n in ((msg[2] or {}).get("sent_to") or {}).items():
                expected_inbound[int(dst)] += int(n)
        for rank, conn in enumerate(conns):
            with contextlib.suppress(OSError):
                conn.send(("all_finished", expected_inbound.get(rank, 0)))

        # phase 3: collect results under the hard run deadline
        results: Dict[int, Dict[str, Any]] = {}
        for rank in range(K):
            msg = comms.recv(rank, deadline - time.monotonic(),
                             "finish barrier")
            if msg[0] == "error":
                raise RuntimeError(
                    f"gossip client {msg[1]} failed:\n{msg[2]}")
            results[msg[1]] = msg[2]

        # merge the per-rank trace files (each on its own perf_counter
        # clock) into one parent-clock-aligned Chrome trace; a merge
        # failure must never fail an otherwise-successful run
        if spec.train.trace_dir:
            try:
                from repro.obs import merge_traces

                rank_paths = {
                    r: res["trace_file"] for r, res in results.items()
                    if res.get("trace_file")
                    and os.path.exists(res["trace_file"])}
                if rank_paths:
                    merged = merge_traces(
                        rank_paths,
                        os.path.join(spec.train.trace_dir,
                                     "trace_merged.json"),
                        parent_anchors={
                            r: (p_recv[r], p_send[r]) for r in rank_paths
                            if r in p_recv and r in p_send},
                        meta={"spec_name": spec.name})
                    for r in rank_paths:
                        results[r]["trace_merged"] = merged
            except Exception:  # noqa: BLE001 — tracing is best-effort
                traceback.print_exc()

        # the lossless-localhost invariant, per edge: bytes offered by the
        # sender rank == bytes delivered at the receiver rank. Skipped
        # when a gap is *expected*: resumed fleets (uncoordinated
        # snapshot cuts) and runs with failed sends / tombstoned mail.
        lossy = any(r.get("failed_sends", 0) or r.get("tombstoned_bytes", 0)
                    for r in results.values())
        if check_delivery and not resume and not lossy:
            gaps = delivery_gaps(results)
            if gaps:
                raise RuntimeError(
                    "delivered != offered on a lossless localhost wire: "
                    + "; ".join(
                        f"edge {e}: offered {o} B, delivered {d} B"
                        for e, (o, d) in sorted(gaps.items())))

        # phase 4: exit barrier — only now may children close their sockets
        for conn in conns:
            with contextlib.suppress(OSError):
                conn.send("done")
        for p in procs:
            p.join(timeout=30)
        return results
    finally:
        for p in procs:
            if p.is_alive():
                p.terminate()
        for p in procs:
            if p.is_alive():
                p.join(timeout=10)
            if p.is_alive():
                p.kill()
        for conn in conns:
            conn.close()


def delivery_gaps(results: Dict[int, Dict[str, Any]]
                  ) -> Dict[str, Tuple[int, int]]:
    """Join the per-rank meter books into fleet-wide per-edge totals and
    return the edges where delivered != offered as
    ``{"src-dst": (offered_bytes, delivered_bytes)}`` (empty = the
    lossless invariant holds). An edge's offered bytes are booked only by
    its sender rank, its delivered bytes only by its receiver rank."""
    offered: Dict[str, int] = defaultdict(int)
    delivered: Dict[str, int] = defaultdict(int)
    for r in results.values():
        for edge, b in (r.get("offered_by_edge") or {}).items():
            offered[edge] += int(b)
        for edge, b in (r.get("delivered_by_edge") or {}).items():
            delivered[edge] += int(b)
    return {e: (offered[e], delivered[e])
            for e in set(offered) | set(delivered)
            if offered[e] != delivered[e]}


def fleet_summary(results: Dict[int, Dict[str, Any]]) -> Dict[str, float]:
    """Aggregate per-rank reports into the fleet-level view the
    acceptance criteria (and the smoke benchmark) read."""
    vals = list(results.values())
    return {
        "clients": float(len(vals)),
        "offered_bytes": sum(r["offered_bytes"] for r in vals),
        "delivered_bytes": sum(r["delivered_bytes"] for r in vals),
        "offered_messages": sum(r["offered_messages"] for r in vals),
        "delivered_messages": sum(r["delivered_messages"] for r in vals),
        "distill_steps_min": min(r["distill_steps"] for r in vals),
        "distill_steps_total": sum(r["distill_steps"] for r in vals),
        "fresh_teachers_min": min(r["fresh_teachers"] for r in vals),
        "failed_sends": sum(r["failed_sends"] for r in vals),
        "drain_stalls": sum(r.get("drain_stalls", 0) for r in vals),
        "undrained_bytes": sum(r.get("undrained_bytes", 0) for r in vals),
        "mismatched_edges": float(len(delivery_gaps(results))),
        "backpressure_events": sum(
            (r.get("sched") or {}).get("backpressure_events", 0.0)
            for r in vals),
        "backpressure_seconds": sum(
            (r.get("sched") or {}).get("backpressure_s", 0.0)
            for r in vals),
        "wall_seconds_max": max(r["wall_seconds"] for r in vals),
        # launcher-overhead breakdown (absent in pre-obs result dicts)
        "setup_seconds_max": max(r.get("setup_s", 0.0) for r in vals),
        "rendezvous_seconds_max": max(
            r.get("rendezvous_s", 0.0) for r in vals),
        "barrier_wait_seconds_max": max(
            r.get("barrier_wait_s", 0.0) for r in vals),
    }
