import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay first — jax locks the device count on
# first init, and the production meshes need 512 placeholder host devices.
# (This also means no `from __future__ import annotations` in this file.)

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers, compiles, and fits — without TPU hardware.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]

Per run: lower + compile the right step function, print
``compiled.memory_analysis()`` (fits-HBM proof) and ``cost_analysis()``
(FLOPs/bytes for §Roofline), parse collective bytes from the HLO, and write
a JSON artifact consumed by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.pytree import tree_size
from repro.configs import arch_ids, get_config
from repro.configs.shapes import INPUT_SHAPES, input_specs, supports_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (
    batch_shardings,
    cache_shardings,
    params_shardings,
)
from repro.launch.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    train_state_shapes,
)
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.roofline.hlo_cost import analyze_to_dict
from repro.roofline.hlo_parse import collective_bytes_from_hlo


def _memory_dict(ma) -> Dict[str, float]:
    if ma is None:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[f] = float(getattr(ma, f, 0) or 0)
    return out


def _cost_dict(ca) -> Dict[str, float]:
    keep = {}
    for k, v in (ca or {}).items():
        if "flops" in k or k == "bytes accessed" or "utilization" in k:
            try:
                keep[k] = float(v)
            except (TypeError, ValueError):
                pass
    return keep


def _apply_sharding_strategy(strategy: str):
    """§Perf lever: how the 'model' mesh axis is used.

    * "tp"   (default): tensor-parallel over 'model' + FSDP over 'data' —
      per-layer activation all-reduces (f32) dominate collectives.
    * "fsdp": the 'model' axis joins data parallelism — batch sharded over
      every chip, parameters fully sharded and all-gathered (bf16) per
      layer; collectives scale with parameter bytes, not activation bytes.
    """
    from repro.common.sharding import set_logical_rule
    from repro.launch import shardings as SH

    if strategy == "fsdp":
        set_logical_rule("batch", ("pod", "data", "model"))
        set_logical_rule("model", None)
        set_logical_rule("expert", "model")
        SH.DEFAULT_ROLES["batch"] = ("pod", "data", "model")
        SH.DEFAULT_ROLES["tp"] = ("model",)  # params still sharded over both
    elif strategy == "tp":
        set_logical_rule("batch", ("pod", "data"))
        set_logical_rule("model", "model")
        set_logical_rule("expert", "model")
        SH.DEFAULT_ROLES["batch"] = ("pod", "data")
        SH.DEFAULT_ROLES["tp"] = "model"
    else:
        raise ValueError(strategy)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               overrides: Optional[Dict[str, Any]] = None,
               save_hlo: Optional[str] = None,
               sharding: str = "tp",
               verbose: bool = True) -> Dict[str, Any]:
    """Lower+compile one (arch, shape, mesh) and return the artifact dict."""
    _apply_sharding_strategy(sharding)
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256

    skip = supports_shape(arch, cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "mode": shape.mode, "tokens": shape.global_batch * (
            1 if shape.mode == "decode" else shape.seq_len),
    }
    if skip:
        record["status"] = "skip"
        record["skip_reason"] = skip
        if verbose:
            print(f"[SKIP] {arch} × {shape_name} × {mesh_name}: {skip}")
        return record

    bundle = build_bundle(cfg, dtype=jnp.bfloat16)
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    with jax.set_mesh(mesh):
        specs = input_specs(cfg, shape_name)
        if shape.mode == "train":
            opt = make_optimizer(OptimizerConfig(
                name="sgd_momentum", init_lr=0.1, total_steps=60_000,
                state_dtype="bfloat16"))
            state_shapes = train_state_shapes(bundle, opt)
            state_spec = {
                "params": params_shardings(state_shapes["params"], mesh),
                "opt": {"momentum": params_shardings(
                    state_shapes["opt"]["momentum"], mesh)},
                "step": P(),
            }
            batch_spec = batch_shardings(specs, mesh)
            step = make_train_step(bundle, opt)

            def fn(state, batch):
                new_state, metrics = step(state, batch)
                return new_state, metrics["loss"]

            lowered = jax.jit(
                fn, in_shardings=(state_spec, batch_spec),
                out_shardings=(state_spec, P()),
            ).lower(state_shapes, specs)
        elif shape.mode == "prefill":
            params_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
            pspec = params_shardings(params_shapes, mesh)
            batch_spec = batch_shardings(specs, mesh)
            step = make_prefill_step(bundle)
            vocab_axis = "model" if cfg.vocab_size % 16 == 0 else None
            out_spec = P(batch_spec["tokens"][0], vocab_axis)
            lowered = jax.jit(
                step, in_shardings=(pspec, batch_spec),
                out_shardings=out_spec,
            ).lower(params_shapes, specs)
        else:  # decode
            params_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
            pspec = params_shardings(params_shapes, mesh)
            cache_spec = cache_shardings(specs["caches"], mesh)
            batch_spec = {
                "token": batch_shardings({"t": specs["token"]}, mesh)["t"],
                "caches": cache_spec,
            }
            step = make_serve_step(bundle)
            vocab_axis = "model" if cfg.vocab_size % 16 == 0 else None
            out_spec = (P(batch_spec["token"][0], vocab_axis), cache_spec)
            lowered = jax.jit(
                step, in_shardings=(pspec, batch_spec),
                out_shardings=out_spec,
            ).lower(params_shapes, specs)

        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()

    coll = collective_bytes_from_hlo(hlo)  # single-visit (no loop multipliers)
    hlo_cost = analyze_to_dict(hlo)  # loop-aware: flops/bytes/collectives
    params_shapes_tree = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    n_params = tree_size(params_shapes_tree)

    record.update({
        "status": "ok",
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "num_params": int(n_params),
        "memory": _memory_dict(ma),
        # raw XLA numbers (loop bodies counted ONCE — see roofline/hlo_cost.py)
        "cost_xla_raw": _cost_dict(ca),
        "collective_bytes_raw": coll,
        # loop-corrected per-device roofline inputs
        "hlo_cost": hlo_cost,
        "hlo_bytes": len(hlo),
    })
    if save_hlo:
        os.makedirs(os.path.dirname(save_hlo) or ".", exist_ok=True)
        with open(save_hlo, "w") as f:
            f.write(hlo)

    if verbose:
        print(f"[OK] {arch} × {shape_name} × {mesh_name} "
              f"(lower {lower_s:.1f}s, compile {compile_s:.1f}s, "
              f"params {n_params/1e9:.2f}B)")
        print(f"  memory_analysis: {ma}")
        print(f"  loop-corrected/device: flops={hlo_cost['flops']:.3e} "
              f"bytes={hlo_cost['bytes']:.3e} "
              f"coll={hlo_cost['collective_total']:.3e}")
    return record


def dryrun_mhd(arch: str, shape_name: str = "train_4k", *,
               exchange: str = "full", topk: int = 32,
               overrides: Optional[Dict[str, Any]] = None,
               save_hlo: Optional[str] = None,
               verbose: bool = True) -> Dict[str, Any]:
    """Lower+compile the PAPER-TECHNIQUE step: 2 MHD clients on the 2-pod
    mesh, teacher predictions exchanged over the pod interconnect
    (core/mhd_distributed.py). exchange="full" ships full-vocab logits;
    "topk" ships the sparsified wire format (§Perf)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.mhd import MHDConfig
    from repro.core.mhd_distributed import (
        DistributedMHDConfig,
        make_distributed_mhd_step,
    )

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    K = 2
    bundle = build_bundle(cfg, dtype=jnp.bfloat16)
    mesh = make_production_mesh(multi_pod=True)
    mhd = MHDConfig(nu_emb=1.0, nu_aux=3.0,
                    num_aux_heads=cfg.num_aux_heads, delta=1)
    dist = DistributedMHDConfig(num_clients=K, exchange=exchange, topk=topk)
    opt = make_optimizer(OptimizerConfig(
        name="sgd_momentum", init_lr=0.1, total_steps=60_000,
        state_dtype="bfloat16"))
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16-mhd",
        "chips": 512, "mode": "mhd_train",
        "exchange": exchange, "topk": topk,
        "tokens": shape.global_batch * shape.seq_len,
    }

    t0 = time.time()
    with jax.set_mesh(mesh):
        # per-client batch: split the global batch across the K pods;
        # the public distillation batch is 16 shared sequences (the paper
        # distills on a modest public batch each step, §4.1)
        B = shape.global_batch // K
        B_pub = 16
        T = shape.seq_len
        specs = {
            "private_tokens": jax.ShapeDtypeStruct((K, B, T), jnp.int32),
            "public_tokens": jax.ShapeDtypeStruct((B_pub, T), jnp.int32),
        }
        batch_spec = {
            "private_tokens": P("pod", "data", None),
            "public_tokens": P("data", None),
        }

        params_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        stacked_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((K,) + s.shape, s.dtype),
            params_shapes)
        base_spec = params_shardings(params_shapes, mesh)
        stacked_spec = jax.tree.map(
            lambda sp: P("pod", *sp), base_spec,
            is_leaf=lambda x: isinstance(x, P))
        opt_shapes = jax.eval_shape(opt.init, stacked_shapes)
        state_shapes = {"params": stacked_shapes, "opt": opt_shapes,
                        "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_spec = {"params": stacked_spec,
                      "opt": {"momentum": stacked_spec}, "step": P()}

        step = make_distributed_mhd_step(bundle, opt, mhd, dist)

        def fn(state, batch):
            s, m = step(state, batch)
            return s, m["loss"]

        lowered = jax.jit(fn, in_shardings=(state_spec, batch_spec),
                          out_shardings=(state_spec, P())).lower(
            state_shapes, specs)
        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()

    hlo_cost = analyze_to_dict(hlo)
    record.update({
        "status": "ok",
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "num_params": int(tree_size(params_shapes) * K),
        "memory": _memory_dict(ma),
        "hlo_cost": hlo_cost,
        "hlo_bytes": len(hlo),
    })
    if save_hlo:
        os.makedirs(os.path.dirname(save_hlo) or ".", exist_ok=True)
        with open(save_hlo, "w") as f:
            f.write(hlo)
    if verbose:
        print(f"[OK] MHD({exchange}) {arch} × {shape_name} × 2x16x16 "
              f"(lower {lower_s:.1f}s, compile {compile_s:.1f}s)")
        print(f"  memory_analysis: {ma}")
        print(f"  loop-corrected/device: flops={hlo_cost['flops']:.3e} "
              f"bytes={hlo_cost['bytes']:.3e} "
              f"coll={hlo_cost['collective_total']:.3e}")
    return record


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    p.add_argument("--all", action="store_true",
                   help="run every (arch, shape) for the chosen mesh")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--save-hlo", action="store_true")
    p.add_argument("--step", default="auto", choices=["auto", "mhd"],
                   help="'mhd' lowers the 2-client pod-exchange step")
    p.add_argument("--exchange", default="full", choices=["full", "topk"])
    args = p.parse_args(argv)

    if args.step == "mhd":
        os.makedirs(args.out, exist_ok=True)
        arch = args.arch or "gemma3-12b"
        shape_name = args.shape or "train_4k"
        tag = f"mhd_{args.exchange}__{arch}__{shape_name}".replace("/", "_")
        rec = dryrun_mhd(arch, shape_name, exchange=args.exchange,
                         save_hlo=os.path.join(args.out, tag + ".hlo")
                         if args.save_hlo else None)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
        return 0

    os.makedirs(args.out, exist_ok=True)
    combos = []
    archs = arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    failures = 0
    for arch, shape_name, mp in combos:
        mesh_name = "2x16x16" if mp else "16x16"
        tag = f"{arch}__{shape_name}__{mesh_name}".replace("/", "_")
        out_json = os.path.join(args.out, tag + ".json")
        hlo_path = os.path.join(args.out, tag + ".hlo") if args.save_hlo else None
        try:
            rec = dryrun_one(arch, shape_name, multi_pod=mp,
                             save_hlo=hlo_path)
        except Exception as e:  # a dry-run failure is a bug in the system
            failures += 1
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "status": "fail", "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {arch} × {shape_name} × {mesh_name}: {rec['error']}")
        with open(out_json, "w") as f:
            json.dump(rec, f, indent=2)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
