"""Training launcher.

Two modes:
  * ``--mode supervised`` — train one architecture on synthetic token data
    (the production path for the assigned archs; on a real cluster the data
    pipeline feeds tokenized shards through the same BatchIterator API).
  * ``--mode mhd`` — the paper's decentralized run: K clients, private
    shards with skew s, public pool, checkpoint pools, a communication
    topology, and multi-headed distillation (core/runtime.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode mhd --clients 4 \
      --steps 200 --skew 100 --topology complete --aux-heads 3
  PYTHONPATH=src python -m repro.launch.train --mode supervised \
      --arch qwen2.5-32b --reduced --steps 20
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_supervised(args) -> None:
    from repro.configs import get_config, get_reduced
    from repro.launch.steps import init_train_state, make_train_step
    from repro.models.zoo import build_bundle
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_bundle(cfg)
    opt = make_optimizer(OptimizerConfig(
        name=args.optimizer, init_lr=args.lr, total_steps=args.steps))
    state = init_train_state(bundle, opt, seed=args.seed)
    step_fn = jax.jit(make_train_step(bundle, opt))

    rng = np.random.default_rng(args.seed)
    B, T = args.batch_size, args.seq_len
    vocab = cfg.vocab_size
    t0 = time.time()
    for t in range(args.steps):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, vocab, size=(B, T), dtype=np.int32))}
        if getattr(cfg, "vision", None) is not None:
            batch["vision_embeds"] = jnp.asarray(rng.standard_normal(
                (B, cfg.vision.num_patches, cfg.vision.embed_dim)), jnp.float32)
        if getattr(cfg, "audio", None) is not None:
            batch = {
                "tokens": jnp.asarray(rng.integers(
                    0, vocab, size=(B, cfg.audio.decoder_len), dtype=np.int32)),
                "audio_frames": jnp.asarray(rng.standard_normal(
                    (B, T, cfg.audio.frame_dim)), jnp.float32),
            }
        state, metrics = step_fn(state, batch)
        if t % max(args.steps // 10, 1) == 0:
            print(f"step {t}: loss {float(metrics['loss']):.4f}")
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"final loss {float(metrics['loss']):.4f}")


def run_mhd(args) -> None:
    from repro.core import (
        MHDConfig, DecentralizedTrainer, RunConfig,
        complete_graph, cycle_graph, islands_graph, chain_graph,
    )
    from repro.core.graph import random_regular_graph_fn
    from repro.data import make_synthetic_vision, partition_dataset, PartitionConfig
    from repro.models.resnet import resnet_tiny, resnet_tiny34
    from repro.models.zoo import build_bundle
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    K = args.clients
    ds = make_synthetic_vision(num_labels=args.labels,
                               samples_per_label=args.samples_per_label,
                               image_size=8, noise=args.noise, seed=args.seed)
    test = make_synthetic_vision(num_labels=args.labels, samples_per_label=20,
                                 image_size=8, noise=args.noise,
                                 seed=args.seed + 999,
                                 prototype_seed=args.seed)
    pcfg = PartitionConfig(
        num_clients=K, num_labels=args.labels,
        labels_per_client=max(args.labels // K, 1) * 2,
        assignment="random", skew=args.skew, gamma_pub=0.1, seed=args.seed)
    part = partition_dataset(ds.labels, pcfg)
    arrays = {"images": ds.images, "labels": ds.labels}

    if args.topology == "random":
        graph = random_regular_graph_fn(K, degree=1, seed=args.seed,
                                        reshuffle_every=args.pool_every)
    else:
        topo = {"complete": complete_graph, "cycle": cycle_graph,
                "chain": chain_graph}.get(args.topology)
        graph = topo(K) if topo else islands_graph(K, 2)

    maker = resnet_tiny34 if args.big_clients else resnet_tiny
    bundles = [build_bundle(maker(args.labels, num_aux_heads=args.aux_heads))
               for _ in range(K)]
    opt = make_optimizer(OptimizerConfig(init_lr=args.lr,
                                         total_steps=args.steps,
                                         grad_clip_norm=1.0))
    mhd = MHDConfig(nu_emb=args.nu_emb, nu_aux=args.nu_aux,
                    num_aux_heads=args.aux_heads, delta=args.delta,
                    confidence=args.confidence,
                    pool_size=min(K, 8), pool_update_every=args.pool_every)
    trainer = DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=args.steps, batch_size=args.batch_size,
                  public_batch_size=args.batch_size,
                  eval_every=args.eval_every, seed=args.seed),
        arrays, part.client_indices, part.public_indices, graph, args.labels)
    history = trainer.train(
        eval_arrays={"images": test.images, "labels": test.labels},
        log_every=max(args.steps // 10, 1))
    final = trainer.evaluate({"images": test.images, "labels": test.labels})
    print(json.dumps({k: round(v, 4) for k, v in final.items()
                      if k.startswith("mean/")}, indent=2))


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=["supervised", "mhd"], default="mhd")
    p.add_argument("--arch", default="qwen2.5-32b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch-size", type=int, default=16)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=0.05)
    p.add_argument("--optimizer", default="sgd_momentum")
    p.add_argument("--seed", type=int, default=0)
    # mhd options (paper §4.1 defaults scaled to CPU)
    p.add_argument("--clients", type=int, default=4)
    p.add_argument("--labels", type=int, default=16)
    p.add_argument("--samples-per-label", type=int, default=60)
    p.add_argument("--noise", type=float, default=1.0)
    p.add_argument("--skew", type=float, default=100.0)
    p.add_argument("--topology", default="complete",
                   choices=["complete", "cycle", "islands", "chain",
                            "random"])
    p.add_argument("--confidence", default="max",
                   choices=["max", "entropy", "margin", "random"])
    p.add_argument("--aux-heads", type=int, default=3)
    p.add_argument("--delta", type=int, default=1)
    p.add_argument("--nu-emb", type=float, default=1.0)
    p.add_argument("--nu-aux", type=float, default=1.0)
    p.add_argument("--pool-every", type=int, default=20)
    p.add_argument("--eval-every", type=int, default=0)
    p.add_argument("--big-clients", action="store_true")
    args = p.parse_args(argv)
    if args.mode == "supervised":
        run_supervised(args)
    else:
        run_mhd(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
