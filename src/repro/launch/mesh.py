"""Production mesh definitions (TPU v5e target).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
dryrun.py sees 512 forced host devices).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)  # 256 chips
MULTI_POD = (2, 16, 16)  # 2 pods × 256 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def required_devices(multi_pod: bool) -> int:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    return n
