"""Parameter / optimizer-state / cache PartitionSpec rules.

Strategy (DESIGN.md §4): tensor-parallel over 'model' (heads, d_ff, experts,
vocab) + FSDP over 'data' (the other matmul dim), replicated over 'pod'
(gradients all-reduce across pods). Every rule is divisibility-checked
against the mesh and falls back to replication per-dim, so the same rules
serve full configs on the 256/512-chip meshes and reduced configs on tiny
test meshes.

Leaf rules are *name-based* — we own every parameter name (models/*.py) —
with ndim disambiguation for stacked (scanned) stage parameters.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# role -> mesh axis name(s); "fsdp" may be retargeted (a §Perf lever)
DEFAULT_ROLES = {
    "fsdp": "data",
    "tp": "model",
    "batch": ("pod", "data"),
}

# (leaf name, base ndim) -> role template. None entries replicate.
_RULES: Dict[Tuple[str, int], Tuple[Optional[str], ...]] = {
    ("embed", 2): ("tp", "fsdp"),
    ("lm_head", 2): ("fsdp", "tp"),
    ("aux_heads", 3): (None, "fsdp", "tp"),
    ("wq", 2): ("fsdp", "tp"),
    ("wk", 2): ("fsdp", "tp"),
    ("wv", 2): ("fsdp", "tp"),
    ("wo", 2): ("tp", "fsdp"),
    ("bq", 1): ("tp",),
    ("bk", 1): ("tp",),
    ("bv", 1): ("tp",),
    ("w_up", 2): ("fsdp", "tp"),
    ("w_gate", 2): ("fsdp", "tp"),
    ("w_down", 2): ("tp", "fsdp"),
    ("router", 2): (None, None),  # tiny; replicated for the manual-EP path
    ("w_up", 3): ("tp", "fsdp", None),
    ("w_gate", 3): ("tp", "fsdp", None),
    ("w_down", 3): ("tp", None, "fsdp"),
    ("in_proj", 2): ("fsdp", "tp"),
    ("out_proj", 2): ("tp", "fsdp"),
    ("w_dq", 2): ("fsdp", "tp"),
    ("w_uq", 2): ("fsdp", "tp"),
    ("w_dkv", 2): ("fsdp", "tp"),
    ("w_uk", 3): ("fsdp", "tp", None),
    ("w_uv", 3): ("fsdp", "tp", None),
    ("vision_proj", 2): ("fsdp", "tp"),
    ("audio_proj", 2): ("fsdp", "tp"),
    ("pos_embed", 2): (None, "tp"),
    ("proj", 2): ("fsdp", "tp"),
}


def _key_name(p) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _resolve(axis_role: Optional[str], dim: int, mesh_axis_sizes,
             roles) -> Optional[Any]:
    if axis_role is None:
        return None
    axes = roles[axis_role]
    if isinstance(axes, str):
        axes = (axes,)
    kept = tuple(a for a in axes if a in mesh_axis_sizes)
    size = int(np.prod([mesh_axis_sizes[a] for a in kept])) if kept else 1
    if not kept or size <= 1 or dim % size != 0:
        return None
    return kept if len(kept) > 1 else kept[0]


def _mesh_sizes(mesh):
    shape = getattr(mesh, "shape", None)
    if shape is not None and hasattr(shape, "values"):
        return dict(zip(mesh.axis_names, shape.values()))
    devices = getattr(mesh, "devices", None)
    if devices is not None and hasattr(devices, "shape"):
        return dict(zip(mesh.axis_names, devices.shape))
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def param_pspec(path, leaf, mesh, roles=None) -> P:
    roles = roles or DEFAULT_ROLES
    sizes = _mesh_sizes(mesh)
    names = [_key_name(p) for p in path]
    leaf_name = names[-1]
    # conv params are nested under a "conv" dict with generic w/b leaves
    if len(names) >= 2 and names[-2] == "conv":
        base = (None, "tp") if leaf_name == "w" else ("tp",)
        tmpl = base
        base_ndim = len(base)
    else:
        stacked_guess = any(n.startswith("stage") for n in names[:-1])
        ndim = len(leaf.shape)
        base_ndim = ndim - 1 if stacked_guess else ndim
        tmpl = _RULES.get((leaf_name, base_ndim))
        if tmpl is None:
            return P()  # replicate (norm scales, biases, scalars, resnet, ...)
    ndim = len(leaf.shape)
    pad = ndim - len(tmpl)
    full = (None,) * pad + tuple(tmpl)
    spec = tuple(_resolve(r, leaf.shape[i], sizes, roles)
                 for i, r in enumerate(full))
    return P(*spec)


def params_shardings(param_shapes, mesh, roles=None):
    """Map an eval_shape'd params (or optimizer-state) pytree to PartitionSpecs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, mesh, roles), param_shapes)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def _sizes(mesh):
    return _mesh_sizes(mesh)


def _batch_axes(mesh, batch_dim: int):
    sizes = _sizes(mesh)
    roles = DEFAULT_ROLES["batch"]
    if isinstance(roles, str):
        roles = (roles,)
    axes = tuple(a for a in roles if a in sizes)
    total = int(np.prod([sizes[a] for a in axes])) if axes else 1
    if axes and total > 1 and batch_dim % total == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


def batch_shardings(batch_shapes, mesh):
    """tokens/images: batch dim over (pod, data); rest replicated."""
    def spec(path, leaf):
        b = _batch_axes(mesh, leaf.shape[0]) if leaf.ndim >= 1 else None
        return P(b, *([None] * (leaf.ndim - 1))) if leaf.ndim else P()

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_shardings(cache_shapes, mesh):
    """Decode caches: batch over (pod,data) when divisible, else sequence over
    'data' (the long_500k batch=1 case); kv-heads / latent dims over 'model'
    when divisible. Stacked leading (repeats) dim replicated."""
    sizes = _sizes(mesh)
    model = sizes.get("model", 1)

    def spec(path, leaf):
        names = [_key_name(p) for p in path]
        name = names[-1]
        if name == "index" or leaf.ndim <= 1:
            return P()
        stacked = any(n.startswith("stage") for n in names[:-1])
        off = 1 if stacked else 0
        dims: list = [None] * leaf.ndim
        if leaf.ndim <= off:
            return P()
        bdim = off  # batch dim position
        b_axes = _batch_axes(mesh, leaf.shape[bdim])
        dims[bdim] = b_axes
        if name in ("k", "v") and leaf.ndim - off == 4:
            # (B, S, KV, hd): shard S on data when batch isn't; KV on model
            if b_axes is None and "data" in sizes and \
                    leaf.shape[off + 1] % sizes["data"] == 0:
                dims[off + 1] = "data"
            if leaf.shape[off + 2] % model == 0 and model > 1:
                dims[off + 2] = "model"
        elif name in ("c_kv", "k_rope") and leaf.ndim - off == 3:
            # (B, S, R): shard S on data when batch isn't; latent on model
            if b_axes is None and "data" in sizes and \
                    leaf.shape[off + 1] % sizes["data"] == 0:
                dims[off + 1] = "data"
            if leaf.shape[off + 2] % model == 0 and model > 1:
                dims[off + 2] = "model"
        elif name == "ssm" and leaf.ndim - off == 4:
            # (B, H, P, N): heads on model
            if leaf.shape[off + 1] % model == 0 and model > 1:
                dims[off + 1] = "model"
        elif name == "conv" and leaf.ndim - off == 3:
            # (B, W, C): channels on model
            if leaf.shape[off + 2] % model == 0 and model > 1:
                dims[off + 2] = "model"
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
